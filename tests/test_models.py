"""Per-architecture smoke tests: reduced same-family config, one forward +
train step on CPU, shape and NaN checks; decode-vs-prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model

B, S = 2, 64


def _batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.n_prefix:
        batch["prefix_embed"] = jax.random.normal(
            k, (B, cfg.n_prefix, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return model.loss_fn(p, batch, rules={})

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert loss.shape == ()
    assert not jnp.isnan(loss), metrics
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn)
    # one SGD step reduces loss on the same batch (sanity of gradients)
    lr = 0.02
    p2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    loss2, _ = model.loss_fn(p2, batch, rules={})
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).smoke()
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    if cfg.moe:  # capacity drops are train-time semantics; disable here
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab_size)
    P = cfg.n_prefix
    pre = {}
    if P:
        pre["prefix_embed"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, P, cfg.d_model), jnp.float32) * 0.02
    lg_full, _ = model.prefill(params, toks, rules={}, **pre)
    lg_pre, cache = model.prefill(params, toks[:, :S], rules={},
                                  max_len=S + P + 8, **pre)
    lg_dec, _ = model.decode_step(params, toks[:, S:S + 1],
                                  jnp.full((B,), S + P, jnp.int32),
                                  cache, rules={})
    rel = float(jnp.max(jnp.abs(lg_full - lg_dec)) /
                (jnp.max(jnp.abs(lg_full)) + 1e-9))
    assert rel < 2e-4, f"{arch}: decode/prefill mismatch rel={rel}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_specs(arch):
    """Analytic 6ND param count ~ materialized spec sizes (±2%)."""
    cfg = get_config(arch)
    model = Model(cfg)
    total = sum(int(np.prod(s.shape)) for s in
                jax.tree.leaves(model.param_specs(),
                                is_leaf=lambda x: hasattr(x, "logical_axes")))
    analytic = cfg.param_count()
    assert abs(total - analytic) / analytic < 0.02, (total, analytic)


def test_multi_token_decode_matches_prefill():
    """Decode 4 tokens sequentially == prefill of the longer sequence."""
    cfg = dataclasses.replace(get_config("gemma2-27b").smoke(),
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    n_new = 4
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, S + n_new), 0,
                              cfg.vocab_size)
    _, cache = model.prefill(params, toks[:, :S], rules={},
                             max_len=S + n_new)
    for t in range(n_new):
        lg_dec, cache = model.decode_step(
            params, toks[:, S + t:S + t + 1],
            jnp.full((1,), S + t, jnp.int32), cache, rules={})
    lg_full, _ = model.prefill(params, toks, rules={})
    rel = float(jnp.max(jnp.abs(lg_full - lg_dec)) /
                (jnp.max(jnp.abs(lg_full)) + 1e-9))
    assert rel < 2e-4, rel


def test_loss_mask_respected():
    cfg = get_config("musicgen-medium").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss_all, _ = model.loss_fn(params, batch, rules={})
    batch2 = dict(batch, loss_mask=batch["loss_mask"].at[:, S // 2:].set(0.0))
    loss_half, _ = model.loss_fn(params, batch2, rules={})
    assert not np.isclose(float(loss_all), float(loss_half))
    batch3 = dict(batch, targets=batch["targets"].at[:, S // 2:].set(0),
                  loss_mask=batch2["loss_mask"])
    loss_half2, _ = model.loss_fn(params, batch3, rules={})
    assert np.isclose(float(loss_half), float(loss_half2))  # masked targets ignored
