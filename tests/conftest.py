"""Tier-1 test harness hooks.

When ``REPRO_LOCKCHECK=1``, install the runtime lock-order sanitizer
(repro.lint.runtime) before any test module imports threading users,
and fail the session if any lock-order inversion was recorded.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.lint import runtime  # noqa: E402

_LOCKCHECK = runtime.install()  # no-op unless REPRO_LOCKCHECK=1


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not _LOCKCHECK:
        return
    inv = runtime.inversions()
    rep = runtime.report()
    terminalreporter.write_line(
        f"repro.lint.runtime: {len(rep.edges)} lock-order edge(s) observed, "
        f"{len(inv)} inversion(s)"
    )
    for i in inv:
        terminalreporter.write_line(f"  INVERSION: {i['first']}  vs  {i['second']}")


def pytest_sessionfinish(session, exitstatus):
    if _LOCKCHECK and runtime.inversions():
        session.exitstatus = 3
        print(
            "repro.lint.runtime: lock-order inversion(s) recorded — failing "
            "the session (REPRO_LOCKCHECK=1)",
            file=sys.stderr,
        )
