"""Multi-tenant staging gateway (DESIGN.md §12).

Covers the whole subsystem: consistent-hash placement (unit + property
tests, including the exact only-moves-to-the-joiner invariant and
cross-process determinism), tenancy + typed quota rejections, stats
merge classmethods, StagingServer stop() hardening under health probes,
and the N=3 end-to-end acceptance scenario — ring-correct landing for
every ingest path, byte-identical scatter-gather parity with an N=1
run, backend failure remap with no lost acked datasets, and
gateway-vs-backend accounting parity.
"""
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.analysis.session import AnalysisStats
from repro.core import wire
from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer
from repro.gateway import (AuthError, GatewayClient, GatewayServer,
                           QuotaExceededError, HashRing, RingNode,
                           RouterSession, StagingPool, Tenant, TenantRegistry,
                           error_from_reply, error_reply, merge_histograms)
from repro.transport import TransferSession, TransferStats, TransportConfig

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _nodes(n, weights=None):
    return [RingNode(f"b{i}", f"127.0.0.1:{9000 + i}",
                     weight=(weights[i] if weights else 1.0))
            for i in range(n)]


# ---------------------------------------------------------------------------
# ring units
# ---------------------------------------------------------------------------


def test_ring_placement_is_deterministic_and_total():
    r = HashRing(_nodes(3))
    for key in (f"ds{i}" for i in range(100)):
        assert r.place(key).name == r.place(key).name
        assert r.place(key).name in r
    # every node owns something at 64 vnodes / 100 keys
    owners = {r.place(f"ds{i}").name for i in range(100)}
    assert owners == {"b0", "b1", "b2"}


def test_ring_rejects_bad_input():
    with pytest.raises(ValueError):
        HashRing(_nodes(2) + [RingNode("b0", "x:1")])   # duplicate name
    with pytest.raises(ValueError):
        HashRing([RingNode("a", "x:1", weight=0.0)])    # nonpositive weight
    with pytest.raises(RuntimeError):
        HashRing([]).place("k")                          # empty ring


def test_ring_encode_decode_roundtrip_and_epoch():
    r = HashRing(_nodes(3, weights=[1.0, 2.0, 0.5]), vnodes=32)
    r2 = HashRing.decode(r.encode())
    assert r2.epoch == r.epoch
    assert [n.as_dict() for n in r2.nodes] == [n.as_dict() for n in r.nodes]
    for i in range(50):
        assert r.place(f"k{i}").name == r2.place(f"k{i}").name
    # epoch moves with membership, weights and vnodes
    assert r.with_node(RingNode("b9", "x:9")).epoch != r.epoch
    assert r.without_node("b1").epoch != r.epoch
    assert HashRing(r.nodes, vnodes=64).epoch != r.epoch
    # a tampered wire form is rejected, not silently adopted
    d = r.encode()
    d["nodes"][0]["weight"] = 9.0
    with pytest.raises(ValueError):
        HashRing.decode(d)


def test_ring_pure_membership_ops():
    r = HashRing(_nodes(3))
    grown = r.with_node(RingNode("b3", "127.0.0.1:9003"))
    assert len(r) == 3 and len(grown) == 4       # original untouched
    shrunk = grown.without_node("b0")
    assert "b0" in r and "b0" not in shrunk


def test_ring_cross_process_determinism():
    """Placement must not depend on PYTHONHASHSEED or process identity
    (BLAKE2b, not ``hash()``) — the client-side cached ring and the
    gateway must agree exactly."""
    keys = [f"ds{i}" for i in range(30)]
    r = HashRing(_nodes(3), vnodes=32)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    prog = (
        "import sys, json; sys.path.insert(0, {src!r});"
        "from repro.gateway import HashRing, RingNode;"
        "r = HashRing([RingNode(f'b{{i}}', f'127.0.0.1:{{9000+i}}')"
        " for i in range(3)], vnodes=32);"
        "print(json.dumps([r.epoch] + [r.place(k).name for k in {keys!r}]))"
    ).format(src=src, keys=keys)
    env = dict(os.environ, PYTHONHASHSEED="12345")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, check=True)
    got = json.loads(out.stdout)
    assert got[0] == r.epoch
    assert got[1:] == [r.place(k).name for k in keys]


# ---------------------------------------------------------------------------
# ring properties (hypothesis)
# ---------------------------------------------------------------------------


@given(st.integers(min_value=2, max_value=8),
       st.integers(min_value=0, max_value=10_000))
def test_ring_join_moves_keys_only_to_joiner(n, seed):
    """The consistent-hashing contract, exactly: adding a node may only
    move keys *onto* the new node, never between existing nodes."""
    r = HashRing(_nodes(n), vnodes=32)
    grown = r.with_node(RingNode("newbie", "127.0.0.1:9999"))
    keys = [f"k{seed}_{i}" for i in range(200)]
    moved = 0
    for k in keys:
        before, after = r.place(k).name, grown.place(k).name
        if before != after:
            assert after == "newbie"
            moved += 1
    # ≈ K/(N+1) expected; generous slack for hash variance at 32 vnodes
    assert moved <= len(keys) * 3.0 / (n + 1) + 10


@given(st.integers(min_value=3, max_value=8),
       st.integers(min_value=0, max_value=10_000))
def test_ring_leave_moves_only_the_leavers_keys(n, seed):
    r = HashRing(_nodes(n), vnodes=32)
    shrunk = r.without_node("b0")
    for i in range(200):
        k = f"k{seed}_{i}"
        before, after = r.place(k).name, shrunk.place(k).name
        if before != "b0":
            assert after == before    # survivors keep everything they had
        else:
            assert after != "b0"


@given(st.integers(min_value=0, max_value=10_000))
def test_ring_weights_shift_load_proportionally(seed):
    r = HashRing([RingNode("heavy", "x:1", weight=3.0),
                  RingNode("light", "x:2", weight=1.0)], vnodes=96)
    heavy = sum(r.place(f"k{seed}_{i}").name == "heavy" for i in range(600))
    # expectation 450/600; allow wide hash variance but require dominance
    assert 330 <= heavy <= 570


# ---------------------------------------------------------------------------
# tenancy
# ---------------------------------------------------------------------------


def test_tenant_auth_modes():
    reg = TenantRegistry([Tenant("acme", token="s3cret"),
                          Tenant("open-team")])
    assert reg.authenticate(None).name == "default"
    assert reg.authenticate("s3cret").name == "acme"
    assert reg.authenticate("open-team").name == "open-team"
    with pytest.raises(AuthError):
        reg.authenticate("acme")      # named tenant requires its token
    with pytest.raises(AuthError):
        reg.authenticate("nope")
    strict = TenantRegistry([Tenant("a", token="t")], require_auth=True)
    with pytest.raises(AuthError):
        strict.authenticate(None)


def test_tenant_quota_all_or_nothing():
    reg = TenantRegistry([Tenant("t", quota_bytes=100, quota_datasets=3)])
    reg.charge("t", 60)
    with pytest.raises(QuotaExceededError) as ei:
        reg.charge("t", 60)           # would cross the byte budget
    assert ei.value.tenant == "t"
    u = reg.usage("t")
    assert u == {"bytes": 60, "datasets": 1, "rejects": 1}   # no partial
    reg.charge("t", 10, datasets=2)
    with pytest.raises(QuotaExceededError):
        reg.charge("t", 1)            # dataset budget now exhausted
    snap = reg.snapshot()
    assert snap["t"]["rejects"] == 2 and snap["t"]["quota_bytes"] == 100


def test_typed_error_wire_roundtrip():
    for exc, cls in ((QuotaExceededError("over", tenant="t"),
                      QuotaExceededError),
                     (AuthError("who"), AuthError),
                     (RuntimeError("boom"), RuntimeError)):
        back = error_from_reply(error_reply(exc))
        assert type(back) is cls


# ---------------------------------------------------------------------------
# stats merge
# ---------------------------------------------------------------------------


def test_transfer_stats_merge_semantics():
    assert TransferStats.merge([]).nbytes == 0
    one = TransferStats("rdma_staged", nbytes=10, n_datasets=1,
                        to_staging_s=1.0, end_to_end_s=2.0,
                        write_wait_s=0.5, peak_inflight_bytes=7,
                        channels=[{"id": 0}])
    m1 = TransferStats.merge([one])
    assert (m1.nbytes, m1.engine) == (10, "rdma_staged")
    two = TransferStats("rdma_staged", nbytes=30, n_datasets=2,
                        to_staging_s=0.5, end_to_end_s=3.0,
                        write_wait_s=0.25, peak_inflight_bytes=5,
                        channels=[{"id": 1}], gateway={"epoch": "e"})
    m = TransferStats.merge([one, two])
    assert m.nbytes == 40 and m.n_datasets == 3        # flows sum
    assert m.write_wait_s == 0.75
    assert m.to_staging_s == 1.0 and m.end_to_end_s == 3.0   # walls max
    assert m.peak_inflight_bytes == 7                  # high-water max
    assert [c["id"] for c in m.channels] == [0, 1]
    assert m.gateway == {"epoch": "e"}
    other = TransferStats("scp_mem", nbytes=1)
    assert TransferStats.merge([one, other]).engine == "merged"


def test_analysis_stats_merge_semantics():
    assert AnalysisStats.merge([]).n_queries == 0
    a = AnalysisStats(endpoint="x", n_queries=2, query_s=1.0,
                      result_bytes=10, by_kind={"select": 2})
    b = AnalysisStats(endpoint="y", n_queries=3, n_retries=1,
                      query_s=0.5, result_bytes=5,
                      by_kind={"select": 1, "aggregate": 2})
    m = AnalysisStats.merge([a, b])
    assert m.endpoint == "x+y"
    assert m.n_queries == 5 and m.n_retries == 1      # everything sums
    assert m.query_s == 1.5 and m.result_bytes == 15
    assert m.by_kind == {"select": 3, "aggregate": 2}
    assert m.mean_query_s == pytest.approx(0.3)


def test_merge_histograms():
    h1 = {"counts": [1, 2], "edges": [0, 1, 2], "total": 3}
    h2 = {"counts": [3, 4], "edges": [0, 1, 2], "total": 7}
    m = merge_histograms([h1, h2])
    assert m == {"counts": [4, 6], "edges": [0, 1, 2], "total": 10}
    with pytest.raises(ValueError):
        merge_histograms([h1, {"counts": [1], "edges": [0, 9], "total": 1}])


# ---------------------------------------------------------------------------
# staging stop() hardening under health probes
# ---------------------------------------------------------------------------


def test_staging_stop_joins_cleanly_under_probes():
    sv = SavimeServer().start()
    st_srv = StagingServer(sv.addr, mem_capacity=1 << 20).start()
    stop_probing = threading.Event()

    def probe_loop():
        while not stop_probing.is_set():
            try:
                s = wire.connect(st_srv.addr, timeout=1.0)
                wire.request(s, {"op": "ping"})
                wire.request(s, {"op": "stats"})
                s.close()
            except OSError:
                return            # server went down mid-probe: expected

    probers = [threading.Thread(target=probe_loop, daemon=True)
               for _ in range(4)]
    for t in probers:
        t.start()
    time.sleep(0.15)              # let probes overlap the accept loop
    # probe-only connections must not count as data connections
    s = wire.connect(st_srv.addr)
    h, _ = wire.request(s, {"op": "stats"})
    assert h["conns"] == 0
    assert h["free_fraction"] == 1.0 and h["mem_capacity"] == 1 << 20
    wire.request(s, {"op": "hello"})      # first real op: now counted
    h, _ = wire.request(s, {"op": "stats"})
    assert h["conns"] == 1
    s.close()
    st_srv.stop()
    stop_probing.set()
    for t in probers:
        t.join(2.0)
    assert not any(t.is_alive() for t in probers)
    assert st_srv.live_threads() == 0     # no half-open serve threads
    sv.stop()


# ---------------------------------------------------------------------------
# gateway units
# ---------------------------------------------------------------------------


def test_fleet_credits_follow_worst_backend():
    gw = GatewayServer(_nodes(3))         # never started: pure unit
    try:
        backends = list(gw.backends.values())
        assert gw._fleet_credits(8, 8) == 8
        backends[1].free_fraction = 0.25  # one pressured backend...
        assert gw.fleet_free_fraction() == 0.25
        assert gw._fleet_credits(8, 8) == 2   # ...caps the whole fleet
        assert gw._fleet_credits(8, 1) == 1   # backend grant still binds
        backends[1].free_fraction = 0.0
        assert gw._fleet_credits(8, 8) == 1   # never zero
        backends[1].alive = False             # dead backends don't cap
        assert gw.fleet_free_fraction() == 1.0
    finally:
        gw.stop()


def test_gateway_client_typed_rejections():
    with StagingPool(2, mem_capacity=1 << 20,
                     tenants=[Tenant("tiny", quota_bytes=100)]) as pool:
        cli = GatewayClient(pool.addr, tenant="tiny")
        try:
            cli.admit("d0", 60)
            with pytest.raises(QuotaExceededError):
                cli.admit("d1", 60)
            with pytest.raises(QuotaExceededError):
                cli.admit_batch([("d2", 30), ("d3", 30)])   # all-or-nothing
            assert cli.admit("d4", 40)      # budget still has exactly 40
        finally:
            cli.close()


def test_gateway_rejects_unknown_token():
    with StagingPool(1, mem_capacity=1 << 20, require_auth=True,
                     tenants=[Tenant("a", token="tok")]) as pool:
        with pytest.raises(AuthError):
            GatewayClient(pool.addr, tenant="wrong").admit("d", 1)
        cli = GatewayClient(pool.addr, tenant="tok")
        try:
            assert cli.admit("d", 1)
        finally:
            cli.close()


# ---------------------------------------------------------------------------
# end-to-end: the N=3 acceptance scenario
# ---------------------------------------------------------------------------


RNG = np.random.default_rng(7)


def _stage_all(sess, arrays):
    for name, arr in arrays.items():
        sess.write(name, arr)
    sess.sync()
    sess.drain()


def _load_all(sess, tar, arrays, width, first=0):
    for i, name in enumerate(arrays):
        sess.run_savime(f'load_subtar({tar}, {name}, '
                        f'"{width * (first + i)}", "{width}", v)')


def test_e2e_pool_matches_single_server_bit_for_bit():
    """Block, striped-bin1 and coalesced datasets land ring-correctly
    across N=3 backends, and every aggregate/select answered through the
    gateway is byte-identical to the same data on one server."""
    width = 300
    arrays = {f"par_s{i}": RNG.standard_normal(width) for i in range(9)}
    ddl = f'create_tar(par, "x:0:{width * 9 - 1}", "v:float64")'
    ops = ("sum", "mean", "std", "min", "max", "count")

    # -- N=1 reference --------------------------------------------------
    sv1 = SavimeServer().start()
    st1 = StagingServer(sv1.addr, mem_capacity=64 << 20).start()
    ref = {}
    with TransferSession("rdma_staged",
                         TransportConfig(staging_addr=st1.addr)) as sess:
        sess.run_savime(ddl)
        _stage_all(sess, arrays)
        _load_all(sess, "par", arrays, width)
        for op in ops:
            ref[op] = sess.run_savime(f'aggregate("par", "v", "{op}")')
        ref["select"] = np.asarray(sess.run_savime('select("par", "v")'))
    st1.stop()
    sv1.stop()

    # -- N=3 pool, a different ingest path per third of the data --------
    with StagingPool(3, mem_capacity=64 << 20) as pool:
        base = TransportConfig(gateway_addr=pool.addr, block_size=1 << 20)
        variants = [
            base,                                             # block path
            base.replace(n_channels=2, stripe_bytes=1 << 10,
                         wire_format="bin1"),                 # striped bin1
            base.replace(coalesce_bytes=1 << 20),             # coalesced
        ]
        names = list(arrays)
        sessions = []
        try:
            for v, chunk in zip(variants,
                                (names[0:3], names[3:6], names[6:9])):
                sess = TransferSession("rdma_staged", v).open()
                if not sessions:
                    sess.run_savime(ddl)   # DDL fans out via the gateway
                sessions.append(sess)
                _stage_all(sess, {n: arrays[n] for n in chunk})
            ctl = sessions[0]
            _load_all(ctl, "par", arrays, width)

            # ring-correct landing: per-backend staged byte totals must
            # equal what the placement ring predicts, dataset by dataset
            gc = GatewayClient(pool.addr)
            ring = gc.ring
            gc.close()
            predicted = {f"backend{i}": 0 for i in range(3)}
            for n, a in arrays.items():
                predicted[ring.place(n).name] += a.nbytes
            landed = {k: v["bytes_in"]
                      for k, v in pool.backend_stats().items()}
            assert landed == predicted
            assert all(v > 0 for v in landed.values())   # data did spread

            # scatter-gather answers: byte-identical to the single server
            for op in ops:
                got = ctl.run_savime(f'aggregate("par", "v", "{op}")')
                assert got == ref[op], (op, got, ref[op])
            got_sel = np.asarray(ctl.run_savime('select("par", "v")'))
            assert got_sel.tobytes() == ref["select"].tobytes()

            # accounting parity: gateway admissions == Σ backend ingress
            gw_stats = ctl.server_stats()
            assert gw_stats["totals"]["admitted_bytes"] == \
                sum(landed.values())
            assert gw_stats["totals"]["admitted_datasets"] == len(arrays)
            assert gw_stats["live_backends"] == 3
        finally:
            for sess in sessions:
                sess.close()
        assert sessions[0].stats.gateway["n_backends"] == 3


def test_e2e_quota_rejection_is_typed_and_isolated():
    """A tenant over quota gets QuotaExceededError on both the block and
    the striped ingest path, while another tenant's traffic proceeds."""
    with StagingPool(2, mem_capacity=32 << 20,
                     tenants=[Tenant("capped", quota_bytes=10 << 10),
                              Tenant("roomy")]) as pool:
        base = TransportConfig(gateway_addr=pool.addr, tenant="capped")
        capped = TransferSession("rdma_staged", base).open()
        try:
            capped.write("q_s0", np.ones(1 << 10)).wait(10)    # 8 KiB: fits
            fut = capped.write("q_big", np.ones(1 << 14))      # 128 KiB: no
            with pytest.raises(QuotaExceededError):
                fut.wait(10)
            # striped path rejects with the same typed error
            striped = TransferSession("rdma_staged", base.replace(
                n_channels=2, stripe_bytes=512)).open()
            try:
                with pytest.raises(QuotaExceededError):
                    striped.write("q_big2", np.ones(1 << 14)).wait(10)
            finally:
                striped.close()
            # the other tenant is unaffected
            with TransferSession("rdma_staged", base.replace(
                    tenant="roomy")) as roomy:
                roomy.write("r_s0", np.ones(1 << 14)).wait(10)
        finally:
            capped.close()
        snap = capped.stats.gateway["tenants"]
        assert snap["capped"]["rejects"] >= 2
        assert snap["capped"]["bytes"] == (1 << 10) * 8
        assert snap["roomy"]["bytes"] == (1 << 14) * 8


def test_e2e_backend_death_remaps_without_losing_acked_data():
    width = 200
    with StagingPool(3, mem_capacity=32 << 20,
                     health_interval=0.05) as pool:
        cfg = TransportConfig(gateway_addr=pool.addr)
        with TransferSession("rdma_staged", cfg) as sess:
            sess.run_savime(
                f'create_tar(fx, "x:0:{width * 8 - 1}", "v:float64")')
            first = {f"fx_s{i}": RNG.standard_normal(width)
                     for i in range(4)}
            _stage_all(sess, first)
            _load_all(sess, "fx", first, width)
            # hard-kill one staging backend (its SAVIME — already holding
            # its subtars — stays up); health probes must fail it out
            pool.kill_backend(0)
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline:
                if sess.server_stats()["live_backends"] == 2:
                    break
                time.sleep(0.05)
            gw = sess.server_stats()
            assert gw["live_backends"] == 2
            assert gw["remaps"] >= 1

            # every acked dataset is still queryable through the gateway
            got = sess.run_savime('aggregate("fx", "v", "sum")')
            assert got == float(np.sum(np.concatenate(
                list(first.values()))))

            # new writes remap onto the shrunken ring and land
            more = {f"fx_s{i}": RNG.standard_normal(width)
                    for i in range(4, 8)}
            _stage_all(sess, more)
            _load_all(sess, "fx", more, width, first=4)
            total = sess.run_savime('aggregate("fx", "v", "sum")')
            assert total == float(np.sum(np.concatenate(
                list(first.values()) + list(more.values()))))


def test_e2e_watch_multiplexes_backends():
    width = 64
    with StagingPool(2, mem_capacity=16 << 20) as pool:
        cfg = TransportConfig(gateway_addr=pool.addr)
        with TransferSession("rdma_staged", cfg) as sess:
            sess.run_savime(
                f'create_tar(w, "x:0:{width * 4 - 1}", "v:float64")')
            arrays = {f"w_s{i}": RNG.standard_normal(width)
                      for i in range(4)}
            _stage_all(sess, arrays)
            with RouterSession(gateway_addr=pool.addr) as rs:
                with rs.watch("w", timeout=5.0, max_events=4) as sub:
                    _load_all(sess, "w", arrays, width)
                    events = list(sub)
        assert len(events) == 4
        assert all(ev.tar == "w" for ev in events)
        assert {ev.origin[0] for ev in events} == \
            {width * i for i in range(4)}


def test_gateway_proxies_legacy_clients():
    """A client that knows nothing about gateways (``staging_addr``
    pointed at the gateway) still works on every ingest path: write_req
    / stripe / batch ops are proxied with placement and fleet-capped
    credits."""
    width = 256
    with StagingPool(2, mem_capacity=32 << 20) as pool:
        legacy = TransportConfig(staging_addr=pool.addr)  # NOT gateway_addr
        with TransferSession("rdma_staged", legacy) as sess:
            sess.run_savime(
                f'create_tar(lg, "x:0:{width * 12 - 1}", "v:float64")')
            arrays = {f"lg_s{i}": RNG.standard_normal(width)
                      for i in range(6)}
            _stage_all(sess, arrays)
            _load_all(sess, "lg", arrays, width)
            total = sess.run_savime('aggregate("lg", "v", "sum")')
            assert total == float(np.sum(np.concatenate(
                list(arrays.values()))))
        # striped legacy client (ctrl + stripe conns all hit the gateway)
        with TransferSession("rdma_staged", legacy.replace(
                n_channels=2, stripe_bytes=1 << 10)) as sess2:
            more = {f"lg_s{i}": RNG.standard_normal(width)
                    for i in range(6, 9)}
            _stage_all(sess2, more)
            _load_all(sess2, "lg", more, width, first=6)
            got = sess2.run_savime('aggregate("lg", "v", "count")')
            assert got == width * 9
        # coalesced legacy client (batch_open/batch_write scatter relay)
        with TransferSession("rdma_staged", legacy.replace(
                coalesce_bytes=1 << 20)) as sess3:
            batch = {f"lg_s{i}": RNG.standard_normal(width)
                     for i in range(9, 12)}
            _stage_all(sess3, batch)
        landed = pool.backend_stats()
        assert sum(v["bytes_in"] for v in landed.values()) == width * 8 * 12
        assert all(v["bytes_in"] > 0 for v in landed.values())
