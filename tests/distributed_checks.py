import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# Multi-device checks, run as a subprocess from test_distributed.py so the
# main pytest process keeps the default single-device view.
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM, device_put_batch
from repro.launch.mesh import make_debug_mesh
from repro.models import Model
from repro.train import TrainConfig, TrainSetup


def batch_for(cfg, B, S, rules, mesh, seed=0):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                    seed=seed, n_prefix=cfg.n_prefix, d_model=cfg.d_model)
    return device_put_batch(next(SyntheticLM(dc).batches()), mesh, rules)


def check_sharded_equals_single():
    """Train step on a 2x2 mesh == single-device step (same math)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma2-27b").smoke(),
                              compute_dtype="float32",
                              param_dtype="float32")
    model = Model(cfg)
    B, S = 4, 64

    mesh1 = make_debug_mesh(1, 1)
    mesh2 = make_debug_mesh(2, 2)
    tc = TrainConfig(egress="none")
    s1 = TrainSetup(model, mesh1, tc)
    s2 = TrainSetup(model, mesh2, tc)
    st1 = s1.init_state(jax.random.PRNGKey(7))
    # same initial params on the other mesh
    st2 = jax.device_put(jax.tree.map(np.asarray, st1),
                         s2.state_shardings())
    b = next(SyntheticLM(DataConfig(cfg.vocab_size, 64, 4, seed=1)
                         if False else
             DataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                        global_batch=B, seed=1)).batches())
    b1 = device_put_batch(b, mesh1, s1.rules)
    b2 = device_put_batch(b, mesh2, s2.rules)
    with jax.set_mesh(mesh1):
        n1, m1, _ = jax.jit(s1.step_fn())(st1, b1)
    with jax.set_mesh(mesh2):
        n2, m2, _ = jax.jit(s2.step_fn())(st2, b2)
    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert abs(l1 - l2) / abs(l1) < 1e-5, (l1, l2)
    g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
    assert abs(g1 - g2) / abs(g1) < 1e-4, (g1, g2)
    # updated params equal
    p1 = jax.tree.leaves(jax.tree.map(np.asarray, n1["params"]))
    p2 = jax.tree.leaves(jax.tree.map(np.asarray, n2["params"]))
    worst = max(np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
                for a, b in zip(p1, p2))
    assert worst < 1e-4, worst
    print("check_sharded_equals_single OK", l1, l2)


def check_compressed_pod_reduce():
    """int8 EF cross-pod reduce ~= exact mean; error feedback shrinks bias."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen2-72b").smoke(),
                              compute_dtype="float32",
                              param_dtype="float32")
    model = Model(cfg)
    mesh = make_debug_mesh(2, 2, pod=2)
    tc = TrainConfig(egress="none", compress_pods=True)
    setup = TrainSetup(model, mesh, tc)
    assert setup.compress
    st = setup.init_state(jax.random.PRNGKey(3))
    B, S = 4, 32
    b = device_put_batch(
        next(SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                                    global_batch=B, seed=2)).batches()),
        mesh, setup.rules)
    with jax.set_mesh(mesh):
        step = jax.jit(setup.step_fn())
        losses = []
        for i in range(4):
            st, m, _ = step(st, b)
            losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses  # learns on the fixed batch
    print("check_compressed_pod_reduce OK", [round(l, 4) for l in losses])


def check_compressed_reduce_nondivisible():
    """Regression: compressed_pod_allreduce at ceil(n/QBLOCK) % n_pods != 0.

    error_state row-pads to a multiple of n_pods; _flatten historically did
    not, so `g + e` inside the shard_map body shape-mismatched whenever the
    block-row count was not divisible by the pod count.
    """
    from repro.optim import grad_compress as gc
    mesh = make_debug_mesh(2, 2, pod=2)
    n_pods = mesh.shape["pod"]
    rng = np.random.default_rng(7)
    # 2*QBLOCK + 12 elements -> 3 block rows; 3 % 2 != 0 hits the bug.
    tree = {"w": jnp.asarray(rng.standard_normal(2 * gc.QBLOCK + 5),
                             jnp.float32),
            "b": jnp.asarray(rng.standard_normal(7), jnp.float32)}
    st = gc.error_state(tree, n_pods)
    assert st.shape[0] % n_pods == 0 and st.shape[0] == 4
    flat, pad = gc._flatten(tree, n_pods)
    assert flat.shape == st.shape, (flat.shape, st.shape)
    err = jnp.zeros(st.shape, st.dtype)
    red, new_err = gc.compressed_pod_allreduce(tree, err, mesh)
    assert new_err.shape == st.shape
    # replicated input -> mean over pods == double-quantized round-trip
    for k in tree:
        x, y = np.asarray(tree[k]), np.asarray(red[k])
        atol = 2.1 * np.abs(x).max() / 127.0   # RS + AG quant stages
        assert np.allclose(x, y, rtol=0, atol=atol), k
    print("check_compressed_reduce_nondivisible OK")


def check_reshard_restore():
    """Checkpoint on a (1,4) mesh, restore on (4,1) and (2,2) — elastic."""
    import dataclasses
    import tempfile
    from repro.checkpoint import CheckpointManager
    cfg = dataclasses.replace(get_config("falcon-mamba-7b").smoke(),
                              compute_dtype="float32")
    model = Model(cfg)
    tc = TrainConfig(egress="none")
    mA = make_debug_mesh(1, 4)
    sA = TrainSetup(model, mA, tc)
    stA = sA.init_state(jax.random.PRNGKey(9))
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, async_writes=False)
        ck.save(stA, 1)
        for shape in ((4, 1), (2, 2)):
            mB = make_debug_mesh(*shape)
            sB = TrainSetup(model, mB, tc)
            stB = ck.restore(sB.abstract_state(),
                             shardings=sB.state_shardings())
            a = jax.tree.leaves(jax.tree.map(np.asarray, stA["params"]))
            b = jax.tree.leaves(jax.tree.map(np.asarray, stB["params"]))
            assert all(np.array_equal(x, y) for x, y in zip(a, b))
    print("check_reshard_restore OK")


def check_seq_sharded_decode():
    """SP decode: seq-sharded KV cache == replicated-cache decode."""
    import dataclasses
    from repro.train.serve_step import ServeSetup
    cfg = dataclasses.replace(get_config("gemma3-4b").smoke(),
                              compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(11))
    B, S = 1, 64
    toks = jax.random.randint(jax.random.PRNGKey(12), (B, S + 1), 0,
                              cfg.vocab_size)
    # reference on default device
    _, cache = model.prefill(params, toks[:, :S], rules={}, max_len=S + 8)
    ref_lg, _ = model.decode_step(params, toks[:, S:S + 1],
                                  jnp.full((B,), S, jnp.int32), cache,
                                  rules={})
    mesh = make_debug_mesh(4, 2)
    setup = ServeSetup(model, mesh, seq_shard_kv=True, global_batch=B)
    ps = jax.device_put(jax.tree.map(np.asarray, params),
                        setup.param_shardings())
    cs = jax.device_put(jax.tree.map(np.asarray, cache),
                        setup.cache_shardings(B, S + 8))
    with jax.set_mesh(mesh):
        lg, _ = jax.jit(setup.decode_fn())(
            ps, cs, {"tokens": toks[:, S:S + 1],
                     "pos": jnp.full((B,), S, jnp.int32)})
    rel = float(jnp.max(jnp.abs(lg - ref_lg)) /
                (jnp.max(jnp.abs(ref_lg)) + 1e-9))
    assert rel < 1e-4, rel
    print("check_seq_sharded_decode OK", rel)


CHECKS = {f.__name__: f for f in (
    check_sharded_equals_single, check_compressed_pod_reduce,
    check_compressed_reduce_nondivisible,
    check_reshard_restore, check_seq_sharded_decode)}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()
