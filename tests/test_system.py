"""End-to-end behaviour tests for the in-transit staging system (the paper's
Listing-1 flow), fault tolerance, and the transfer-engine baselines."""
import os
import time

import numpy as np
import pytest

from repro.core import (
    Dataset, InTransitConfig, InTransitSink, SavimeClient, SavimeServer,
    StagingClient, StagingServer,
)
from repro.transport import TransportConfig, run_engine


@pytest.fixture()
def savime():
    srv = SavimeServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def staging(savime):
    srv = StagingServer(savime.addr, mem_capacity=64 << 20,
                        send_threads=2).start()
    yield srv
    srv.stop()


def test_paper_listing1_flow(savime, staging):
    """create_tar -> dataset.write -> sync -> load_subtar -> query."""
    cli = StagingClient(staging.addr, io_threads=2, block_size=256 << 10)
    try:
        cli.run_savime('create_tar(vel, "x:0:15, y:0:31, z:0:31", "v:float64")')
        v = np.random.default_rng(0).standard_normal((16, 32, 32))
        Dataset("D", "float64", cli).write(v)
        cli.sync()          # paper: block until writes reach staging
        cli.drain()         # staging -> SAVIME finished
        cli.run_savime('load_subtar(vel, D, "0,0,0", "16,32,32", v)')
        assert np.isclose(cli.run_savime("aggregate(vel, v, mean)"), v.mean())
        direct = SavimeClient(savime.addr)
        got = direct.run('select(vel, v, "0,0,0", "3,3,3")')
        assert np.array_equal(got, v[:4, :4, :4])
    finally:
        cli.close()


def test_multi_client_concurrent_ingest(savime, staging):
    """Several 'compute nodes' writing concurrently (paper's 5 clients)."""
    clients = [StagingClient(staging.addr, io_threads=2,
                             block_size=128 << 10) for _ in range(3)]
    rng = np.random.default_rng(1)
    try:
        for i, cli in enumerate(clients):
            for j in range(3):
                Dataset(f"n{i}_f{j}", "float64", cli).write(
                    rng.standard_normal(4096))
        for cli in clients:
            cli.sync()
        clients[0].drain()
        assert clients[0].stats()["datasets"] == 9
        assert SavimeClient(savime.addr).stats()["datasets"] == 9
    finally:
        for cli in clients:
            cli.close()


def test_disk_fallback(savime):
    """Paper §3.1: if the in-memory FS is full, disk is the fallback."""
    staging_srv = StagingServer(savime.addr, mem_capacity=1 << 10,  # 1 KiB
                                send_threads=1).start()
    cli = StagingClient(staging_srv.addr, io_threads=1, block_size=1 << 20)
    try:
        Dataset("big", "float64", cli).write(np.ones(65536))
        cli.sync()
        assert cli.stats()["disk_fallbacks"] >= 1
        cli.drain()
    finally:
        cli.close()
        staging_srv.stop()


def test_block_registration_on_demand(savime, staging):
    cli = StagingClient(staging.addr, io_threads=1, block_size=16 << 10)
    try:
        Dataset("d", "float64", cli).write(np.ones(16384))  # 128 KiB
        cli.sync()
        assert cli.stats()["registrations"] == 8  # 128K / 16K blocks
    finally:
        cli.close()


def test_intransit_sink_roundtrip(savime, staging):
    sink = InTransitSink(staging.addr, InTransitConfig(io_threads=2))
    field = np.random.default_rng(2).standard_normal((4, 8, 8)).astype(np.float32)
    for step in range(3):
        sink.stage_array("field", field * (step + 1), step=step)
    sink.flush()
    got = SavimeClient(savime.addr).run('select(run_field, v, "1,0,0,0", "1,3,7,7")')
    assert np.allclose(got[0], field * 2)
    sink.close()


def test_intransit_sink_quantized(savime, staging):
    from repro.core.intransit import dequantize_int8_np
    sink = InTransitSink(staging.addr,
                         InTransitConfig(quantize="int8", tar_prefix="q"))
    x = np.random.default_rng(3).standard_normal((32, 32)).astype(np.float32)
    sink.stage_array("act", x, step=0)
    sink.flush()
    direct = SavimeClient(savime.addr)
    q = direct.run("select(q_act, v)")
    s = direct.run("select(q_act__scale, s)")
    deq = dequantize_int8_np(q[0], s[0][: max(q[0].size // 4096, 1)],
                             x.shape, 4096)
    assert np.abs(deq - x).max() <= np.abs(x).max() / 127 + 1e-6
    sink.close()


# ---------------------------------------------------------------------------
# Baseline engines (paper Fig 6 at test scale: all deliver, bytes conserved)
# ---------------------------------------------------------------------------


def test_engines_all_deliver(savime):
    """Engines are named only via the transport registry."""
    rng = np.random.default_rng(4)
    bufs = [rng.standard_normal(1 << 14) for _ in range(4)]
    results = []
    for tag, engine in (("a", "rdma_staged"), ("b", "scp_mem"),
                        ("c", "ssh_direct")):
        cfg = TransportConfig(savime_addr=savime.addr, block_size=64 << 10,
                              io_threads=2)
        results.append(run_engine(engine, bufs,
                                  [f"{tag}{i}" for i in range(4)], cfg))
    assert SavimeClient(savime.addr).stats()["datasets"] == 12
    assert min(r.nbytes for r in results) == sum(b.nbytes for b in bufs)


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def test_straggler_speculation():
    from repro.core.queues import FCFSPool
    slow_once = {"done": False}

    def work(i):
        if i == 0 and not slow_once["done"]:
            slow_once["done"] = True
            time.sleep(1.0)       # straggler
        return i

    pool = FCFSPool(2, "t", straggler_timeout=0.2)
    hs = [pool.submit(work, i, name=f"w{i}") for i in range(4)]
    for h in hs:
        h.wait(5)
    assert any(h.speculative for h in hs)
    pool.stop()


def test_pool_retry_then_fail():
    from repro.core.queues import FCFSPool
    pool = FCFSPool(1, "t", max_retries=2)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert pool.submit(flaky, name="flaky").wait(5) == "ok"

    def always_fails():
        raise OSError("hard")

    h = pool.submit(always_fails, name="hard")
    with pytest.raises(OSError):
        h.wait(5)
    pool.stop()


def test_supervisor_restores_from_checkpoint(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager
    from repro.runtime import Supervisor, SupervisorConfig

    def step_fn(state, batch):
        new = {"w": state["w"] + batch["x"], "step": state["step"] + 1}
        return new, {"loss": jnp.sum(new["w"])}, {}

    ckpt = CheckpointManager(str(tmp_path), async_writes=False)
    sup = Supervisor(step_fn, ckpt, SupervisorConfig(ckpt_every=2,
                                                     max_restarts=2))
    state = {"w": jnp.zeros(4), "step": jnp.zeros((), jnp.int32)}
    batches = iter(lambda: {"x": jnp.ones(4)}, None)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    out = sup.run(state, batches, 7, abstract_state=abstract, fail_at={5})
    assert int(out["step"]) == 7
    assert sup.restarts == 1
    assert np.allclose(np.asarray(out["w"]), 7.0)


def test_supervisor_restart_budget_exceeded(tmp_path):
    """Burning through max_restarts raises the typed error, and the
    message carries the last committed checkpoint step (enough to resume
    the run by hand)."""
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager
    from repro.runtime import (InjectedFailure, RestartBudgetExceeded,
                               Supervisor, SupervisorConfig)

    def step_fn(state, batch):
        if int(state["step"]) >= 4:
            raise InjectedFailure("poisoned step")
        new = {"w": state["w"] + batch["x"], "step": state["step"] + 1}
        return new, {"loss": jnp.sum(new["w"])}, {}

    ckpt = CheckpointManager(str(tmp_path), async_writes=False)
    sup = Supervisor(step_fn, ckpt, SupervisorConfig(ckpt_every=2,
                                                     max_restarts=2))
    state = {"w": jnp.zeros(4), "step": jnp.zeros((), jnp.int32)}
    batches = iter(lambda: {"x": jnp.ones(4)}, None)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    with pytest.raises(RestartBudgetExceeded) as ei:
        sup.run(state, batches, 8, abstract_state=abstract)
    assert sup.restarts == 3
    assert ei.value.last_checkpoint_step == 4
    assert "step 4" in str(ei.value)
    assert "max_restarts=2" in str(ei.value)


def test_supervisor_fail_at_composes_with_staging_checkpoint(
        tmp_path, savime, staging):
    """fail_at injection + a staging-path (sink-backed) checkpoint: the
    run restores from the analyzable checkpoint and finishes, and the
    checkpoint shards are queryable at SAVIME."""
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager
    from repro.runtime import Supervisor, SupervisorConfig

    sink = InTransitSink(staging.addr, InTransitConfig(tar_prefix="ckpt"))
    try:
        def step_fn(state, batch):
            new = {"w": state["w"] + batch["x"],
                   "step": state["step"] + 1}
            return new, {"loss": jnp.sum(new["w"])}, {}

        ckpt = CheckpointManager(str(tmp_path), sink=sink,
                                 async_writes=False)
        sup = Supervisor(step_fn, ckpt, SupervisorConfig(ckpt_every=2,
                                                         max_restarts=2))
        state = {"w": jnp.zeros(4), "step": jnp.zeros((), jnp.int32)}
        batches = iter(lambda: {"x": jnp.ones(4)}, None)
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        out = sup.run(state, batches, 5, abstract_state=abstract,
                      fail_at={3})
        assert int(out["step"]) == 5
        assert sup.restarts == 1
        assert np.allclose(np.asarray(out["w"]), 5.0)
        sink.flush()
        direct = SavimeClient(savime.addr)
        tars = str(direct.run("list_tars()"))
        assert "ckpt_" in tars, "staged checkpoint shards should be queryable"
    finally:
        sink.close()


def test_checkpoint_reshard_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import CheckpointManager
    ckpt = CheckpointManager(str(tmp_path), async_writes=True)
    state = {"a": jnp.arange(16.0).reshape(4, 4),
             "nested": {"b": jnp.ones((8,), jnp.int32)},
             "step": jnp.int32(3)}
    ckpt.save(state, 3)
    ckpt.wait()
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    back = ckpt.restore(abstract)
    assert all(np.array_equal(x, y) for x, y in
               zip(jax.tree.leaves(state), jax.tree.leaves(back)))


def test_elastic_mesh_plan():
    from repro.runtime import plan_mesh
    assert plan_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256) == ((16, 16), ("data", "model"))
    # degraded: 480 chips -> single-pod mesh of the remainder
    assert plan_mesh(480) == ((30, 16), ("data", "model"))
    with pytest.raises(ValueError):
        plan_mesh(100, model_parallel=16)
