"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# staging_pack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,tile", [
    ((256, 128), (256, 128)),
    ((512, 256), (256, 128)),
    ((64, 384), (8, 128)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("out_dtype", [None, jnp.int8, jnp.bfloat16])
def test_staging_pack_vs_ref(shape, tile, dtype, out_dtype):
    from repro.kernels.staging_pack import kernel, ref
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    bp, sp = kernel.pack_blocks(x, tile=tile, out_dtype=out_dtype,
                                interpret=True)
    br, sr = ref.pack_blocks_ref(x, tile=tile, out_dtype=out_dtype)
    assert bp.dtype == br.dtype and bp.shape == br.shape
    if out_dtype == jnp.int8:
        # amax reduction order may differ by 1 ulp -> round-half ties can
        # flip by one quantization step
        diff = np.abs(np.asarray(bp, np.int32) - np.asarray(br, np.int32))
        assert diff.max() <= 1 and (diff != 0).mean() < 1e-3
    else:
        np.testing.assert_array_equal(np.asarray(bp), np.asarray(br))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-6)


def test_pack_roundtrip_lossless_and_quantized():
    from repro.kernels.staging_pack import ops
    y = jax.random.normal(jax.random.PRNGKey(1), (3, 1000, 7), jnp.float32)
    b, s = ops.pack(y, block_bytes=64 << 10, impl="xla")
    assert bool(jnp.array_equal(ops.unpack(b, s, y.shape), y))
    bq, sq = ops.pack(y, block_bytes=64 << 10, out_dtype=jnp.int8,
                      impl="pallas", interpret=True)
    yr = ops.unpack(bq, sq, y.shape)
    rel = float(jnp.max(jnp.abs(yr - y)) / jnp.max(jnp.abs(y)))
    assert rel < 1e-2


def test_unpack_respects_non_default_block_bytes():
    # regression: unpack hardcoded tc=128 instead of asking tile_for_block
    # for the dtype's lane width; round-trips must survive any block_bytes
    # (geometry is recovered from the packed shape, not the default knob)
    from repro.kernels.staging_pack import ops
    y = jax.random.normal(jax.random.PRNGKey(2), (64, 192), jnp.bfloat16)
    for block_bytes in (8 << 10, 16 << 10, 64 << 10):
        b, s = ops.pack(y, block_bytes=block_bytes, impl="xla")
        assert b.shape[1] * jnp.dtype(y.dtype).itemsize == block_bytes
        out = ops.unpack(b, s, y.shape, block_bytes=block_bytes)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(y))
        # unpack with a *different* block_bytes still round-trips: the
        # packed shape carries the real geometry
        out2 = ops.unpack(b, s, y.shape)
        np.testing.assert_array_equal(np.asarray(out2), np.asarray(y))
    # blocks whose width is not a multiple of the lane count are rejected
    with pytest.raises(ValueError):
        ops.unpack(jnp.zeros((2, 100), jnp.float32),
                   jnp.ones((2,), jnp.float32), (200,))


@pytest.mark.parametrize("n", [0, 1, 4096, 5000, 3 * 4096 + 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_blocks_bound_and_shapes(n, dtype):
    from repro.kernels.staging_pack import ops
    x = jax.random.normal(jax.random.PRNGKey(3), (n,), dtype) * 3.0
    q, s = ops.quantize_blocks(x, block_elems=4096, impl="xla")
    nb = -(-n // 4096)
    assert q.shape == (nb, 4096) and q.dtype == jnp.int8
    assert s.shape == (nb,) and s.dtype == jnp.float32
    back = ops.dequantize_blocks(q, s, n, dtype=dtype)
    xf = np.asarray(x, np.float32)
    err = np.abs(np.asarray(back, np.float32) - xf)
    # |x - dq| <= scale/2 per block (+ dtype rounding slack)
    bound = np.repeat(np.asarray(s), 4096)[:n] * 0.5 + \
        (1e-6 if dtype == jnp.float32 else 0.05)
    assert n == 0 or bool((err <= bound + np.abs(xf) * 0.01).all())


def test_quantize_blocks_pallas_matches_xla():
    from repro.kernels.staging_pack import ops
    x = jax.random.normal(jax.random.PRNGKey(4), (2 * 4096 + 100,),
                          jnp.float32)
    qx, sx = ops.quantize_blocks(x, impl="xla")
    qp, sp = ops.quantize_blocks(x, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sx), rtol=1e-6)
    diff = np.abs(np.asarray(qp, np.int32) - np.asarray(qx, np.int32))
    assert diff.max() <= 1 and (diff != 0).mean() < 1e-3


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cfg", [
    dict(B=2, S=256, Hq=4, Hkv=2, D=64, window=0, cap=0.0, causal=True),
    dict(B=1, S=512, Hq=8, Hkv=1, D=128, window=0, cap=50.0, causal=True),
    dict(B=2, S=256, Hq=4, Hkv=4, D=64, window=128, cap=0.0, causal=True),
    dict(B=1, S=256, Hq=2, Hkv=2, D=64, window=0, cap=0.0, causal=False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(cfg, dtype):
    from repro.kernels.flash_attention import ops
    B, S, Hq, Hkv, D = cfg["B"], cfg["S"], cfg["Hq"], cfg["Hkv"], cfg["D"]
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    kw = dict(softcap=cfg["cap"], causal=cfg["causal"], window=cfg["window"])
    o_ref = ops.gqa_attention_ref(q, k, v, **kw)
    o_pl = ops.gqa_attention(q, k, v, impl="pallas", block_q=128,
                             block_k=128, interpret=True, **kw)
    o_xla = ops.gqa_attention(q, k, v, impl="xla", block_q=128, block_k=128,
                              **kw)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(o_xla, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,di,N,chunk,dtile", [
    (2, 64, 256, 16, 16, 128),
    (1, 100, 300, 8, 32, 128),     # padding paths
    (2, 128, 512, 16, 64, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_vs_ref(B, S, di, N, chunk, dtile, dtype):
    from repro.kernels.ssm_scan import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    xi = jax.random.normal(ks[0], (B, S, di), dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.1).astype(dtype)
    Bm = jax.random.normal(ks[2], (B, S, N), dtype)
    Cm = jax.random.normal(ks[3], (B, S, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.2)
    h0 = jax.random.normal(ks[5], (B, di, N), jnp.float32)
    y0, h_ref = ref.ssm_scan_ref(xi, dt, Bm, Cm, A, h0)
    yp, hp = ops.selective_scan(xi, dt, Bm, Cm, A, h0, chunk=chunk,
                                d_tile=dtile, impl="pallas", interpret=True)
    yx, hx = ops.selective_scan(xi, dt, Bm, Cm, A, h0, chunk=chunk,
                                impl="xla")
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(yp, np.float32),
                               np.asarray(y0, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(h_ref), atol=tol)
    np.testing.assert_allclose(np.asarray(yx, np.float32),
                               np.asarray(y0, np.float32), atol=tol)
