"""Negotiated egress reduction codecs (DESIGN.md §13).

Covers: per-codec property round-trips (lossless codecs byte-exact,
int8-block within its scale/2 error bound, empty payloads, sizes off the
4096-element block grid), delta-rle chain semantics (ordering, reset on
size change, out-of-order parking at the server), codec↔no-codec hello
negotiation fallback in both directions, byte-identity of the default
``codec="none"`` path, end-to-end content parity for ingest and lazy
query-time decode (flat and paged staging), accounting parity between
client ``codec_stats`` and server counters, and the guard that the
copy-emulation baselines are structurally pinned to raw bytes.
"""
from __future__ import annotations

import socket
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro import codec as codec_mod
from repro.core import wire
from repro.core.client import Communicator
from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer
from repro.transport import TransferSession, TransportConfig

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")

BLOCK = 4096


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _rand_bytes(rng: np.random.Generator, n: int, sparse: bool) -> np.ndarray:
    if sparse:
        buf = np.zeros(n, np.uint8)
        if n:
            k = max(1, n // 50)
            idx = rng.integers(0, n, k)
            buf[idx] = rng.integers(1, 255, k)
        return buf
    return rng.integers(0, 256, n, dtype=np.uint8,
                        endpoint=False).astype(np.uint8)


@st.composite
def _byte_payload(draw):
    n = draw(st.sampled_from(
        [0, 1, 7, 64, 65, 4096, 4097, 3 * 4096 + 13, 100_000]))
    sparse = draw(st.sampled_from([True, False]))
    seed = draw(st.integers(0, 2 ** 16))
    return _rand_bytes(np.random.default_rng(seed), n, sparse)


# ---------------------------------------------------------------------------
# per-codec properties
# ---------------------------------------------------------------------------


def test_registry_has_the_three_codecs():
    names = codec_mod.available()
    for name in ("none", "delta-rle", "int8-block"):
        assert name in names
    with pytest.raises(codec_mod.UnknownCodecError):
        codec_mod.get("zstd-unheard-of")
    # create() returns fresh instances: chain state must not be shared
    assert codec_mod.create("delta-rle") is not codec_mod.create("delta-rle")


@given(name=st.sampled_from(["none", "delta-rle", "int8-block"]),
       buf=_byte_payload())
def test_uint8_roundtrip_byte_exact(name, buf):
    # uint8 payloads must round-trip exactly through every codec —
    # int8-block passes non-float dtypes through rather than corrupt them
    enc = codec_mod.create(name)
    dec = codec_mod.create(name)
    payload, meta = enc.encode(buf, dtype="uint8", key="k")
    out = dec.decode(payload, meta, key="k")
    np.testing.assert_array_equal(np.asarray(out, np.uint8).reshape(-1), buf)


@given(seed=st.integers(0, 2 ** 16),
       n=st.sampled_from([0, 1, BLOCK - 1, BLOCK, BLOCK + 1,
                          2 * BLOCK + 300, 50_000]),
       dtype=st.sampled_from(["float32", "float64", "float16"]))
def test_int8_block_error_bound(seed, n, dtype):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) *
         10.0 ** float(rng.integers(-2, 3))).astype(dtype)
    c = codec_mod.create("int8-block")
    payload, meta = c.encode(x.view(np.uint8), dtype=dtype, key="k")
    out = np.asarray(codec_mod.create("int8-block").decode(
        payload, meta, key="k")).view(dtype)
    assert out.shape == x.shape
    if n == 0:
        return
    nb = -(-n // BLOCK)
    scales = np.asarray(codec_mod.as_bytes_array(payload))[
        :nb * 4].view(np.float32)
    bound = np.repeat(scales, BLOCK)[:n] * 0.5
    # float16 storage adds half-ulp on both legs of the round-trip
    slack = np.finfo(dtype).eps * (np.abs(x.astype(np.float64)) + 1)
    err = np.abs(out.astype(np.float64) - x.astype(np.float64))
    assert (err <= bound + slack + 1e-12).all()
    # and the payload actually shrank (scales + int8 vs full floats)
    raw = codec_mod.as_bytes_array(payload)
    if n >= BLOCK:
        assert raw.size < x.nbytes


@given(seed=st.integers(0, 2 ** 16),
       n=st.sampled_from([1, 64, 4096, 100_000]),
       steps=st.integers(2, 5))
def test_delta_rle_chain_roundtrip(seed, n, steps):
    rng = np.random.default_rng(seed)
    enc = codec_mod.create("delta-rle")
    dec = codec_mod.create("delta-rle")
    buf = _rand_bytes(rng, n, sparse=False)
    total_wire = 0
    for _ in range(steps):
        # sparse perturbation: the xor-delta is mostly zeros
        buf = buf.copy()
        k = max(1, n // 100)
        buf[rng.integers(0, n, k)] ^= 0xA5
        payload, meta = enc.encode(buf, dtype="uint8", key="d")
        total_wire += int(codec_mod.as_bytes_array(payload).size)
        out = dec.decode(payload, meta, key="d")
        np.testing.assert_array_equal(
            np.asarray(out, np.uint8).reshape(-1), buf)
    if n >= 4096:
        assert total_wire < steps * n   # deltas compressed


def test_delta_rle_resets_on_size_change_and_tracks_keys():
    enc = codec_mod.create("delta-rle")
    dec = codec_mod.create("delta-rle")
    a = np.arange(100, dtype=np.uint8)
    b = np.arange(200, dtype=np.uint8)
    for buf, key in ((a, "x"), (b, "y"), (b[:100], "x"), (a, "y")):
        p, m = enc.encode(buf.copy(), dtype="uint8", key=key)
        out = dec.decode(p, m, key=key)
        np.testing.assert_array_equal(np.asarray(out).reshape(-1), buf)
    # size change resets the chain: base must be None again
    p, m = enc.encode(np.zeros(77, np.uint8), dtype="uint8", key="x")
    assert m["base"] is None


def test_delta_rle_out_of_order_decode_raises():
    enc = codec_mod.create("delta-rle")
    p1, m1 = enc.encode(np.zeros(64, np.uint8), dtype="uint8", key="k")
    p2, m2 = enc.encode(np.ones(64, np.uint8), dtype="uint8", key="k")
    dec = codec_mod.create("delta-rle")
    with pytest.raises(codec_mod.CodecOrderError) as ei:
        dec.decode(p2, m2, key="k")
    assert ei.value.base == m2["base"]
    # delivering the base first unblocks the chain
    dec.decode(p1, m1, key="k")
    out = dec.decode(p2, m2, key="k")
    np.testing.assert_array_equal(np.asarray(out).reshape(-1),
                                  np.ones(64, np.uint8))


# ---------------------------------------------------------------------------
# hello negotiation
# ---------------------------------------------------------------------------


def _hello_server(reply_codecs):
    """One-shot hello server; returns (addr, captured_offers, thread)."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    offers = []

    def run():
        conn, _ = srv.accept()
        h, _ = wire.recv_frame(conn)
        offers.append(h)
        wire.send_frame(conn, wire.hello_reply(h, codecs=reply_codecs))
        conn.recv(1)   # linger until the client closes
        conn.close()
        srv.close()

    t = threading.Thread(target=run, daemon=True)
    t.start()
    return srv.getsockname(), offers, t


def test_negotiate_codec_accepted_when_both_sides_support():
    addr, offers, t = _hello_server(("delta-rle", "int8-block", "none"))
    sock = socket.create_connection(addr)
    wire.negotiate(sock, codecs=("int8-block",))
    assert wire.negotiated_codecs(sock) == ("int8-block",)
    assert offers[0].get("codecs") == ["int8-block"]
    sock.close()
    t.join(2)


def test_negotiate_codec_vs_old_server_falls_back():
    # "old server": replies without a codecs field at all
    addr, offers, t = _hello_server(())
    sock = socket.create_connection(addr)
    wire.negotiate(sock, codecs=("int8-block",))
    assert wire.negotiated_codecs(sock) == ()
    sock.close()
    t.join(2)


def test_old_client_offer_has_no_codecs_field():
    # codec="none" must be wire-byte-identical to the pre-codec client:
    # the hello offer carries no codecs key, and the reply omits it too
    addr, offers, t = _hello_server(("delta-rle",))
    sock = socket.create_connection(addr)
    wire.negotiate(sock)
    assert "codecs" not in offers[0]
    assert wire.negotiated_codecs(sock) == ()
    sock.close()
    t.join(2)
    assert "codecs" not in wire.hello_reply({"op": "hello"},
                                            codecs=("delta-rle",))


# ---------------------------------------------------------------------------
# end-to-end through staging
# ---------------------------------------------------------------------------


@pytest.fixture()
def stack():
    sav = SavimeServer()
    sav.start()
    st_ = StagingServer(sav.addr, mem_capacity=1 << 26).start()
    yield sav, st_
    st_.stop()
    sav.stop()


@pytest.mark.parametrize("wire_format", ["json", "bin1"])
def test_e2e_int8_block_ingest_decode(stack, wire_format):
    sav, st_ = stack
    comm = Communicator(st_.addr, 1, 1 << 20, wire_format=wire_format,
                        codec="int8-block")
    x = np.linspace(-3, 3, 5000, dtype=np.float32)
    comm.submit("d1", "float", x.view(np.uint8))
    comm.sync()
    st_.drain(5)
    got = np.frombuffer(sav.engine.datasets["d1"], dtype=np.float32)
    assert np.abs(got - x).max() <= np.abs(x).max() / 254 + 1e-7
    cs = comm.codec_stats()
    assert cs["wire_bytes"] < cs["raw_bytes"] == x.nbytes
    assert cs["fallbacks"] == 0
    # accounting parity: server bytes_in counts wire bytes, raw_bytes_in
    # the decoded size, and the SAVIME hop ships raw bytes
    assert st_.stats["bytes_in"] == cs["wire_bytes"]
    assert st_.stats["raw_bytes_in"] == x.nbytes
    assert st_.stats["bytes_to_savime"] == x.nbytes
    assert st_.stats["codec_datasets"] == 1
    comm.stop()


def test_e2e_codec_none_default_is_raw(stack):
    sav, st_ = stack
    comm = Communicator(st_.addr, 1, 1 << 20)
    x = np.arange(4000, dtype=np.float32)
    comm.submit("plain", "float", x.view(np.uint8))
    comm.sync()
    st_.drain(5)
    got = np.frombuffer(sav.engine.datasets["plain"], dtype=np.float32)
    np.testing.assert_array_equal(got, x)
    assert comm.codec_stats() == {}
    assert st_.stats["bytes_in"] == x.nbytes
    assert st_.stats["raw_bytes_in"] == x.nbytes   # raw == wire, no codec
    assert st_.stats["codec_datasets"] == 0
    comm.stop()


def test_e2e_codec_vs_non_advertising_server_ships_raw(stack, monkeypatch):
    # "old server" emulation: the staging hello stops advertising codecs;
    # a codec-configured client must silently fall back to raw bytes
    sav, st_ = stack
    monkeypatch.setattr(codec_mod, "available", lambda: ())
    comm = Communicator(st_.addr, 1, 1 << 20, wire_format="bin1",
                        codec="int8-block")
    x = np.linspace(0, 1, 3000, dtype=np.float64)
    comm.submit("raw1", "double", x.view(np.uint8))
    comm.sync()
    st_.drain(5)
    got = np.frombuffer(sav.engine.datasets["raw1"], dtype=np.float64)
    np.testing.assert_array_equal(got, x)     # byte-exact: nothing encoded
    cs = comm.codec_stats()
    assert cs["fallbacks"] == 1 and cs["datasets"] == 0
    assert st_.stats["bytes_in"] == x.nbytes
    comm.stop()


def test_e2e_delta_rle_chain_and_query_decode(stack):
    sav, st_ = stack
    comm = Communicator(st_.addr, 1, 1 << 20, wire_format="bin1",
                        codec="delta-rle")
    buf = np.zeros(50_000, np.uint8)
    for i in range(4):
        buf = buf.copy()
        buf[i * 7] = i + 1
        comm.submit("chain", "uint8", buf)
        comm.sync()
    st_.drain(5)
    got = np.frombuffer(sav.engine.datasets["chain"], dtype=np.uint8)
    np.testing.assert_array_equal(got, buf)
    cs = comm.codec_stats()
    assert cs["wire_bytes"] < cs["raw_bytes"]
    comm.stop()

    # decode_at="query": stored compressed, decoded on the forward hop
    comm2 = Communicator(st_.addr, 1, 1 << 20, wire_format="bin1",
                         codec="int8-block", decode_at="query")
    y = np.sin(np.arange(20_000, dtype=np.float64))
    comm2.submit("lazy", "double", y.view(np.uint8))
    comm2.sync()
    st_.drain(5)
    got2 = np.frombuffer(sav.engine.datasets["lazy"], dtype=np.float64)
    assert np.abs(got2 - y).max() <= 1.0 / 254 + 1e-9
    assert st_.stats["bytes_to_savime"] >= y.nbytes   # raw on the last hop
    comm2.stop()


def test_e2e_query_decode_composes_with_paged_store():
    sav = SavimeServer()
    sav.start()
    st_ = StagingServer(sav.addr, mem_capacity=1 << 24,
                        page_bytes=1 << 16, dedup=True).start()
    comm = Communicator(st_.addr, 1, 1 << 20, wire_format="bin1",
                        codec="int8-block", decode_at="query")
    y = np.cos(np.arange(50_000, dtype=np.float64))
    comm.submit("pq", "double", y.view(np.uint8))
    comm.sync()
    st_.drain(5)
    got = np.frombuffer(sav.engine.datasets["pq"], dtype=np.float64)
    assert np.abs(got - y).max() <= 1.0 / 254 + 1e-9
    comm.stop()
    st_.stop()
    sav.stop()


def _deliver_inprocess(st_, name, payload, cinfo):
    """Land one pre-encoded dataset via the server's own op methods
    (deterministic arrival order — no client threads involved)."""
    pv = codec_mod.as_bytes_array(payload)
    rep = st_._op_write_req(dict(
        {"op": "write_req", "name": name, "dtype": "uint8",
         "size": int(pv.size)}, **cinfo))
    ds = st_._datasets[rep["file_id"]]
    off = 0
    for seg in ds.region.segments(0, ds.nbytes):
        ln = int(getattr(seg, "nbytes", None) or len(seg))
        seg[:] = pv[off:off + ln]
        off += ln
    st_._finish_dataset(ds)


def test_server_parks_out_of_order_chain_links(stack):
    # striping/io_threads can reorder chained datasets; the server must
    # park the successor until its base lands, then forward both in order
    sav, st_ = stack
    enc = codec_mod.create("delta-rle")
    b1 = np.zeros(8192, np.uint8)
    b2 = b1.copy()
    b2[7] = 99
    p1, m1 = enc.encode(b1, dtype="uint8", key="ooo")
    p2, m2 = enc.encode(b2, dtype="uint8", key="ooo")

    def cinfo(m, raw):
        return {"codec": "delta-rle", "cmeta": m, "raw_size": raw,
                "decode_at": "staging"}

    _deliver_inprocess(st_, "ooo", p2, cinfo(m2, b2.nbytes))   # out of order
    assert st_.stats["codec_parked"] == 1
    assert st_.stats["codec_datasets"] == 0
    _deliver_inprocess(st_, "ooo", p1, cinfo(m1, b1.nbytes))   # base arrives
    st_.drain(5)
    assert st_.stats["codec_datasets"] == 2
    got = np.frombuffer(sav.engine.datasets["ooo"], dtype=np.uint8)
    np.testing.assert_array_equal(got, b2)    # last write wins, in order


def test_write_req_rejects_unknown_codec(stack):
    _, st_ = stack
    with pytest.raises(codec_mod.UnknownCodecError):
        st_._op_write_req({"op": "write_req", "name": "x", "dtype": "uint8",
                           "size": 10, "codec": "nope"})
    # nothing reserved: a bad codec must not leak capacity
    assert st_._mem_used == 0 and not st_._datasets


# ---------------------------------------------------------------------------
# transport / session / baselines
# ---------------------------------------------------------------------------


def test_session_surfaces_codec_stats():
    sav = SavimeServer()
    sav.start()
    cfg = TransportConfig(savime_addr=sav.addr, wire_format="bin1",
                          codec="int8-block", mem_capacity=1 << 26)
    x = np.linspace(-1, 1, 9000, dtype=np.float32)
    with TransferSession("rdma_staged", cfg) as sess:
        sess.write("s1", x.view(np.uint8), dtype="float")
        sess.sync()
        sess.drain()
    stats = sess.stats
    assert stats.codec["name"] == "int8-block"
    assert 0 < stats.codec["wire_bytes"] < stats.codec["raw_bytes"]
    merged = type(stats).merge([stats, stats])
    assert merged.codec["raw_bytes"] == 2 * stats.codec["raw_bytes"]
    sav.stop()


@pytest.mark.parametrize("engine", ["scp_mem", "ssh_direct"])
def test_copy_baselines_are_pinned_to_raw(engine):
    # the baselines never touch the Communicator: cfg.codec is inert and
    # data lands byte-exact whatever codec the config asks for
    sav = SavimeServer()
    sav.start()
    cfg = TransportConfig(savime_addr=sav.addr, codec="int8-block",
                          mem_capacity=1 << 26)
    x = np.linspace(-2, 2, 6000, dtype=np.float32)
    with TransferSession(engine, cfg) as sess:
        sess.write("b1", x.view(np.uint8), dtype="float")
        sess.sync()
        sess.drain()
    got = np.frombuffer(sav.engine.datasets["b1"], dtype=np.float32)
    np.testing.assert_array_equal(got, x)     # byte-exact: no quantization
    assert sess.stats.codec == {}             # and no codec accounting
    sav.stop()


# ---------------------------------------------------------------------------
# grad_compress regression (mesh-free shape parity)
# ---------------------------------------------------------------------------


def test_grad_compress_flatten_pads_rows_to_pod_multiple():
    import jax.numpy as jnp
    from repro.optim import grad_compress as gc
    tree = {"w": jnp.zeros((2 * gc.QBLOCK + 5,)), "b": jnp.zeros((7,))}
    for n_pods in (1, 2, 3, 4):
        flat, pad = gc._flatten(tree, n_pods)
        assert flat.shape[0] % n_pods == 0
        assert flat.shape[0] * gc.QBLOCK == \
            (2 * gc.QBLOCK + 5 + 7) + pad
        err = gc.error_state(tree, n_pods)
        # the error buffer rides _pod_reduce's per-pod split: same rows
        assert err.shape == flat.shape
        back = gc._unflatten(flat, pad, tree)
        assert {k: v.shape for k, v in back.items()} == \
            {k: v.shape for k, v in tree.items()}
