"""The roofline numbers all flow through launch/hlo_analysis — pin its
semantics against closed-form probes (XLA cost_analysis counts loop bodies
once; the analyzer must not)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo, multiplicities


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def f(x):
        def body(c, _):
            return c @ c, None
        return jax.lax.scan(body, x, None, length=10)[0]

    r = analyze(_hlo(f, jax.ShapeDtypeStruct((128, 128), jnp.float32)))
    assert r["flops"] == pytest.approx(10 * 2 * 128 ** 3, rel=1e-3)


def test_nested_scan_trip_counts_compose():
    def g(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            return jax.lax.scan(inner, c, None, length=4)[0], None

        return jax.lax.scan(outer, x, None, length=5)[0]

    r = analyze(_hlo(g, jax.ShapeDtypeStruct((64, 64), jnp.float32)))
    assert r["flops"] == pytest.approx(20 * 2 * 64 ** 3, rel=1e-3)


def test_raw_cost_analysis_undercounts_loops():
    """Documents WHY the analyzer exists."""
    def f(x):
        def body(c, _):
            return c @ c, None
        return jax.lax.scan(body, x, None, length=10)[0]

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32))
    raw = lowered.compile().cost_analysis()["flops"]
    assert raw < 2 * 2 * 128 ** 3  # counts the body once


def test_dot_flops_batched_and_contracting_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    r = analyze(_hlo(f, jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                     jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)))
    assert r["flops"] == pytest.approx(2 * 4 * 32 * 64 * 16, rel=1e-3)


def test_vpu_elementwise_counted_with_multiplicity():
    def f(x):
        def body(c, _):
            return jnp.exp(c) * 2.0 + 1.0, None
        return jax.lax.scan(body, x, None, length=7)[0]

    r = analyze(_hlo(f, jax.ShapeDtypeStruct((256, 128), jnp.float32)))
    # 3 elementwise ops (exp, mul, add) x 7 trips x 256*128 elems
    expect = 3 * 7 * 256 * 128
    assert r["vpu_flops"] == pytest.approx(expect, rel=0.35)  # fusion slack


def test_multiplicity_parsing_structure():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        return jax.lax.scan(body, x, None, length=9)[0]

    text = _hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    comps = parse_hlo(text)
    mult = multiplicities(comps)
    assert len(comps) >= 2              # entry + loop body at minimum
    assert max(mult.values()) >= 9.0    # the body runs 9x


def test_collectives_empty_on_single_device():
    def f(a, b):
        return a @ b

    r = analyze(_hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32)))
    assert r["collective_total"] == 0.0
    assert r["hbm_bytes"] >= 3 * 64 * 64 * 4  # operands + result at least
