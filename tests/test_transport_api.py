"""Transport-API tests: registry round-trip, TransferSession semantics,
backpressure bound, TransferStats parity across all four engines, the
legacy shims' deprecation, and connection hygiene."""
import socket
import threading

import numpy as np
import pytest

from repro.core import SavimeClient, SavimeServer, StagingClient, StagingServer
from repro.core import wire
from repro import transport
from repro.transport import (TransferSession, TransferStats, TransportConfig,
                             UnknownTransportError, run_engine)

ALL_ENGINES = ("rdma_staged", "scp_mem", "scp_disk", "ssh_direct")


@pytest.fixture()
def savime():
    srv = SavimeServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def staging(savime):
    srv = StagingServer(savime.addr, mem_capacity=64 << 20,
                        send_threads=2).start()
    yield srv
    srv.stop()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lists_all_engines():
    names = transport.available()
    for engine in ALL_ENGINES:
        assert engine in names


def test_registry_create_roundtrip(savime):
    cfg = TransportConfig(savime_addr=savime.addr)
    for engine in ALL_ENGINES:
        t = transport.create(engine, cfg)
        assert t.name == engine
        assert transport.get(engine) is type(t)


def test_registry_unknown_name_error():
    with pytest.raises(UnknownTransportError) as ei:
        transport.create("carrier_pigeon", TransportConfig())
    msg = str(ei.value)
    assert "carrier_pigeon" in msg and "rdma_staged" in msg


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        @transport.register_transport("rdma_staged")
        class Impostor(transport.Transport):  # pragma: no cover - decorator raises
            def open(self): ...
            def write(self, name, dtype, buf): ...
            def sync(self, timeout=None): ...
            def drain(self, timeout=None): ...
            def close(self): ...


# ---------------------------------------------------------------------------
# TransferSession semantics
# ---------------------------------------------------------------------------


def test_session_context_manager_semantics(staging):
    cfg = TransportConfig(staging_addr=staging.addr, io_threads=1,
                          block_size=64 << 10)
    sess = TransferSession("rdma_staged", cfg)
    with pytest.raises(RuntimeError):          # not opened yet
        sess.write("x", np.ones(8))
    with sess:
        fut = sess.write("x", np.ones(1024))
        assert fut.name == "x" and fut.nbytes == 1024 * 8
    # clean exit synced + drained + closed
    assert fut.done()
    assert sess.stats.n_datasets == 1
    assert sess.stats.nbytes == 1024 * 8
    assert sess.stats.end_to_end_s >= sess.stats.to_staging_s > 0
    with pytest.raises(RuntimeError):          # closed: no further writes
        sess.write("y", np.ones(8))


def test_session_metrics_hooks(staging):
    events = []
    cfg = TransportConfig(staging_addr=staging.addr)
    with TransferSession("rdma_staged", cfg, on_event=events.append) as sess:
        sess.write("m", np.ones(64))
        sess.sync()
    kinds = [e["event"] for e in events]
    for expected in ("open", "write", "sync", "drain", "close"):
        assert expected in kinds


def test_backpressure_bounds_inflight_bytes(staging):
    nbuf, size = 8, 64 << 10
    bound = 2 * size * 8                     # two float64 buffers in flight
    cfg = TransportConfig(staging_addr=staging.addr, io_threads=1,
                          block_size=16 << 10, max_inflight_bytes=bound)
    with TransferSession("rdma_staged", cfg) as sess:
        for i in range(nbuf):
            sess.write(f"bp{i}", np.ones(size))
        sess.sync()
    assert sess.stats.peak_inflight_bytes <= bound
    assert sess.stats.n_datasets == nbuf


def test_backpressure_admits_oversized_buffer_alone(staging):
    cfg = TransportConfig(staging_addr=staging.addr,
                          max_inflight_bytes=1024)   # << buffer size
    with TransferSession("rdma_staged", cfg) as sess:
        fut = sess.write("big", np.ones(64 << 10))   # must not deadlock
        sess.sync()
        assert fut.done()


def test_exit_does_not_overwrite_phase_timings(staging):
    """The redundant sync/drain on clean __exit__ must not inflate the
    recorded phase timings (fig6's slowdown ratios depend on them)."""
    cfg = TransportConfig(staging_addr=staging.addr, block_size=64 << 10)
    with TransferSession("rdma_staged", cfg) as sess:
        sess.write("t0", np.ones(1 << 14))
        sess.sync()
        to_staging = sess.stats.to_staging_s
        sess.drain()
        end_to_end = sess.stats.end_to_end_s
    assert sess.stats.to_staging_s == to_staging
    assert sess.stats.end_to_end_s == end_to_end


def test_close_without_sync_completes_inflight_write(staging):
    """stop() joins in-flight transfers before closing their sockets: a
    write that was going to succeed still succeeds when the client closes
    immediately (the old facade allowed exactly this)."""
    cli = StagingClient(staging.addr, io_threads=1, block_size=1 << 20)
    fut = cli.session.write("eager_close", np.ones(1 << 20))  # 8 MiB
    cli.close()                 # no sync(): join must let the write finish
    assert fut.done()
    assert fut.wait(1) == 8 << 20


def test_stats_parity_across_transports(savime):
    """All four engines report the same TransferStats contract."""
    rng = np.random.default_rng(7)
    bufs = [rng.standard_normal(1 << 12) for _ in range(3)]
    total = sum(b.nbytes for b in bufs)
    for engine in ALL_ENGINES:
        cfg = TransportConfig(savime_addr=savime.addr, block_size=32 << 10,
                              io_threads=2)
        stats = run_engine(engine, bufs,
                           [f"{engine}_p{i}" for i in range(3)], cfg)
        assert isinstance(stats, TransferStats)
        assert stats.engine == engine
        assert stats.nbytes == total
        assert stats.n_datasets == 3
        assert stats.to_staging_s > 0
        assert stats.end_to_end_s >= stats.to_staging_s
        assert stats.staging_gbps > 0
    assert SavimeClient(savime.addr).stats()["datasets"] == 4 * 3


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------


def test_legacy_engine_shims_are_gone():
    # the deprecation shims (kept "for one release") are retired: the
    # module must fail to import cleanly, and the real API must not have
    # grown accidental aliases of the old names
    with pytest.raises(ImportError):
        import repro.core.transfer  # noqa: F401
    import repro.core as core
    for old in ("run_rdma_staged", "run_scp", "run_ssh_direct",
                "ENGINES", "TransferResult"):
        assert not hasattr(core, old)


# ---------------------------------------------------------------------------
# emulation-path hardening (frame validation + connection hygiene)
# ---------------------------------------------------------------------------


def test_tunnel_hop_rejects_unknown_op(savime):
    from repro.transport.copyemu import _CopyServerFwdToSavime
    hop = _CopyServerFwdToSavime(savime.addr)
    try:
        sock = wire.connect(hop.addr)
        try:
            # frame without op=fwd must be rejected, not silently sunk
            h, _ = wire.request(sock, {"name": "evil", "dtype": "uint8"},
                                b"\x00" * 64)
            assert h["ok"] is False and "fwd" in h["error"]
            # well-formed fwd frame still lands
            h, _ = wire.request(sock, {"op": "fwd", "name": "good",
                                       "dtype": "uint8"}, b"\x01" * 64)
            assert h["ok"] is True
        finally:
            sock.close()
        stats = SavimeClient(savime.addr).stats()
        assert stats["datasets"] == 1
    finally:
        hop.stop()


def test_communicator_sockets_closed_on_stop(staging):
    cli = StagingClient(staging.addr, io_threads=2, block_size=32 << 10)
    for i in range(3):
        cli.session.write(f"s{i}", np.ones(2048))
    cli.sync()
    comm = cli.comm
    socks = list(comm._socks._all)
    assert socks, "I/O threads should have opened per-thread sockets"
    cli.close()
    assert all(s.fileno() == -1 for s in socks)


@pytest.mark.parametrize("engine", ["scp_mem", "ssh_direct"])
def test_copy_engine_sockets_closed_on_close(savime, engine):
    cfg = TransportConfig(savime_addr=savime.addr, io_threads=2)
    sess = TransferSession(engine, cfg).open()
    for i in range(3):
        sess.write(f"h{i}", np.ones(2048))
    sess.sync()
    sess.drain()
    socks = list(sess.transport._socks._all)
    assert socks, "emulation clients should have opened per-thread sockets"
    sess.close()
    assert all(s.fileno() == -1 for s in socks)


def test_pool_stop_runs_cleanup_callbacks():
    from repro.core.queues import FCFSPool
    closed = threading.Event()
    pool = FCFSPool(1, "cleanup-test")
    pool.add_stop_callback(closed.set)
    pool.submit(lambda: None, name="noop").wait(5)
    pool.stop()
    assert closed.is_set()


# ---------------------------------------------------------------------------
# sink over a non-default transport (the API opens new workloads)
# ---------------------------------------------------------------------------


def test_intransit_sink_over_copy_transport(savime):
    from repro.core import InTransitConfig, InTransitSink
    sink = InTransitSink(savime.addr,
                         InTransitConfig(transport="scp_mem",
                                         tar_prefix="alt"))
    x = np.arange(64, dtype=np.float32).reshape(8, 8)
    sink.stage_array("field", x, step=0)
    sink.flush()
    got = SavimeClient(savime.addr).run("select(alt_field, v)")
    assert np.allclose(got[0], x)
    sink.close()
