"""Striped multi-channel transfers: reassembly under reordered / duplicate
/ missing stripes, credit-based backpressure, per-channel stats parity,
connection/thread hygiene under repeated sessions, and the wire/queue
correctness fixes that ride along (ConnCache addr keying, header-length
cap, sendfile stall timeout, no requeue-after-stop, bounded completions).
"""
import os
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.core import SavimeClient, SavimeServer, StagingServer
from repro.core import wire
from repro.core.queues import FCFSPool
from repro.transport import ChannelGroup, TransferSession, TransportConfig

pytestmark = pytest.mark.filterwarnings("ignore::pytest.PytestUnraisableExceptionWarning")


@pytest.fixture()
def savime():
    srv = SavimeServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def staging(savime):
    srv = StagingServer(savime.addr, mem_capacity=256 << 20,
                        send_threads=2).start()
    yield srv
    srv.stop()


# ---------------------------------------------------------------------------
# striped end-to-end integrity
# ---------------------------------------------------------------------------


def test_striped_rdma_roundtrip(savime, staging):
    cfg = TransportConfig(staging_addr=staging.addr, n_channels=3,
                          block_size=1 << 20, stripe_bytes=64 << 10,
                          io_threads=2)
    rng = np.random.default_rng(0)
    bufs = {f"d{i}": rng.standard_normal(40_000) for i in range(5)}
    with TransferSession("rdma_staged", cfg) as sess:
        futs = [sess.write(n, b, dtype="float64") for n, b in bufs.items()]
        sess.sync()
        assert all(f.done() for f in futs)
    for n, b in bufs.items():
        got = np.frombuffer(savime.engine.datasets[n], dtype=np.float64)
        assert np.array_equal(got, b), n
    assert staging.stats["stripes"] > 0


@pytest.mark.parametrize("engine", ["scp_mem", "ssh_direct"])
def test_striped_copyemu_roundtrip(savime, engine):
    cfg = TransportConfig(savime_addr=savime.addr, n_channels=2,
                          stripe_bytes=32 << 10, io_threads=2)
    rng = np.random.default_rng(1)
    bufs = {f"{engine}_d{i}": rng.standard_normal(20_000) for i in range(3)}
    with TransferSession(engine, cfg) as sess:
        for n, b in bufs.items():
            sess.write(n, b, dtype="float64")
        sess.sync()
        sess.drain()
    for n, b in bufs.items():
        got = np.frombuffer(savime.engine.datasets[n], dtype=np.float64)
        assert np.array_equal(got, b), n
    assert sess.stats.channels and len(sess.stats.channels) == 2


def test_striped_empty_dataset_completes(savime, staging):
    cfg = TransportConfig(staging_addr=staging.addr, n_channels=2)
    with TransferSession("rdma_staged", cfg) as sess:
        fut = sess.write("empty", np.empty(0, dtype=np.uint8))
        sess.sync()
        assert fut.done()


# ---------------------------------------------------------------------------
# stripe protocol: reordering, duplicates, missing stripes, bad offsets
# ---------------------------------------------------------------------------


def _stripe_open(sock, name, payload, n_stripes):
    h, _ = wire.request(sock, {"op": "stripe_open", "name": name,
                               "dtype": "uint8", "size": len(payload),
                               "n_stripes": n_stripes, "credits": 4})
    assert h["ok"], h
    return h


def _send_stripe(sock, file_id, idx, n_stripes, offset, chunk):
    h, _ = wire.request(sock, {"op": "stripe", "file_id": file_id,
                               "stripe_idx": idx, "n_stripes": n_stripes,
                               "offset": offset}, chunk)
    return h


def test_reassembly_reordered_and_duplicate_stripes(savime, staging):
    rng = np.random.default_rng(2)
    payload = rng.integers(0, 255, 3 * 1024, dtype=np.uint8).tobytes()
    s1 = wire.connect(staging.addr)
    s2 = wire.connect(staging.addr)
    try:
        h = _stripe_open(s1, "reorder", payload, 3)
        fid = h["file_id"]
        chunks = [payload[0:1024], payload[1024:2048], payload[2048:3072]]
        # out of order, across two connections
        a = _send_stripe(s2, fid, 2, 3, 2048, chunks[2])
        assert a["ok"] and not a["done"] and not a["dup"]
        a = _send_stripe(s1, fid, 0, 3, 0, chunks[0])
        assert a["ok"] and not a["done"]
        # duplicate of an already-received stripe: idempotent ack
        a = _send_stripe(s2, fid, 0, 3, 0, chunks[0])
        assert a["ok"] and a["dup"] and not a["done"]
        before = staging.stats["datasets"]
        a = _send_stripe(s1, fid, 1, 3, 1024, chunks[1])
        assert a["ok"] and a["done"]
        assert staging.stats["datasets"] == before + 1
        assert staging.stats["stripe_dups"] >= 1
        staging.drain(10)
        got = bytes(savime.engine.datasets["reorder"].view(np.uint8))
        assert got == payload
    finally:
        s1.close()
        s2.close()


def test_missing_stripe_keeps_dataset_pending(savime, staging):
    payload = b"\x07" * 2048
    s = wire.connect(staging.addr)
    try:
        h = _stripe_open(s, "partial", payload, 2)
        before = staging.stats["datasets"]
        a = _send_stripe(s, h["file_id"], 0, 2, 0, payload[:1024])
        assert a["ok"] and not a["done"]
        staging.drain(5)
        assert staging.stats["datasets"] == before      # not complete
        assert "partial" not in savime.engine.datasets
        a = _send_stripe(s, h["file_id"], 1, 2, 1024, payload[1024:])
        assert a["ok"] and a["done"]
        staging.drain(10)
        assert bytes(savime.engine.datasets["partial"].view(np.uint8)) \
            == payload
    finally:
        s.close()


def test_bad_stripe_rejected_and_stream_stays_framed(savime, staging):
    payload = b"\x01" * 1024
    s = wire.connect(staging.addr)
    try:
        h = _stripe_open(s, "bad", payload, 1)
        # offset outside the region: rejected, but the payload must be
        # drained so the connection keeps working
        a = _send_stripe(s, h["file_id"], 0, 1, 4096, payload)
        assert not a["ok"] and "outside" in a["error"]
        a = _send_stripe(s, "no-such-file", 0, 1, 0, payload)
        assert not a["ok"]
        # a sided (control-only) frame must not smuggle payload bytes —
        # the mixed form would bypass the extent check and desync framing
        a, _ = wire.request(s, {"op": "stripe", "file_id": h["file_id"],
                                "stripe_idx": 0, "n_stripes": 1,
                                "offset": 0, "sided": 1, "size": 1024},
                            payload)
        assert not a["ok"] and "payload" in a["error"]
        a = _send_stripe(s, h["file_id"], 0, 1, 0, payload)  # still works
        assert a["ok"] and a["done"]
    finally:
        s.close()


# ---------------------------------------------------------------------------
# credit-based flow control
# ---------------------------------------------------------------------------


def test_credit_grant_shrinks_under_memory_pressure(savime):
    st = StagingServer(savime.addr, mem_capacity=1 << 20).start()
    try:
        assert st._credit_grant(8) == 8          # empty tmpfs: full grant
        ctrl = wire.connect(st.addr)
        h, _ = wire.request(ctrl, {"op": "write_req", "name": "fill",
                                   "size": (1 << 20) - 1024})
        assert h["ok"]
        assert st._credit_grant(8) == 1          # nearly full: minimum
        # protocol level: stripe_open acks carry the shrunken grant
        h2 = _stripe_open(ctrl, "pressed", b"\x00" * 512, 1)
        assert h2["credits"] == 1
        ctrl.close()
    finally:
        st.stop()


class _SlowAckServer:
    """Minimal stripe endpoint: grants a window of 1 and acks slowly."""

    def __init__(self, ack_delay=0.03):
        self.ack_delay = ack_delay
        self.max_seen_inflight = 0
        self._srv = socket.socket()
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(8)
        self.addr = f"127.0.0.1:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        threading.Thread(target=self._accept, daemon=True).start()

    def _accept(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn):
        with conn:
            while True:
                try:
                    h, _ = wire.recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                if h.get("op") == "stripe_open":
                    reply = {"ok": True, "file_id": "f1", "credits": 1}
                else:
                    time.sleep(self.ack_delay)
                    reply = {"ok": True, "stripe_idx": h.get("stripe_idx"),
                             "credits": 1, "done": False, "dup": False}
                try:
                    wire.send_frame(conn, reply)
                except OSError:
                    return

    def stop(self):
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass


def test_credit_exhaustion_backpressure():
    srv = _SlowAckServer()
    group = ChannelGroup(srv.addr, n_channels=1, stripe_bytes=1 << 10,
                         credits=4).open()
    try:
        group.send_dataset("slow", "uint8",
                           np.zeros(6 << 10, dtype=np.uint8), timeout=30)
        st = group.channel_stats()[0]
        # the receiver granted a window of 1: the sender never had more
        # than one unacked stripe in flight and spent time blocked on
        # credits while acks trickled in
        assert st["window"] == 1
        assert st["peak_unacked"] == 1
        assert st["credit_wait_s"] > 0
        assert st["n_stripes"] == 6
    finally:
        group.close()
        srv.stop()


# ---------------------------------------------------------------------------
# per-channel stats parity
# ---------------------------------------------------------------------------


def test_per_channel_stats_match_session_totals(savime, staging):
    cfg = TransportConfig(staging_addr=staging.addr, n_channels=4,
                          stripe_bytes=128 << 10, io_threads=1)
    with TransferSession("rdma_staged", cfg) as sess:
        for i in range(4):
            sess.write(f"p{i}", np.ones(64 << 10))   # 512 KiB each
        sess.sync()
    chans = sess.stats.channels
    assert len(chans) == 4
    assert sum(c["nbytes"] for c in chans) == sess.stats.nbytes
    assert sum(c["n_stripes"] for c in chans) == staging.stats["stripes"]
    assert all(c["n_stripes"] > 0 for c in chans)    # round-robined


def test_single_channel_uses_legacy_path(savime, staging):
    cfg = TransportConfig(staging_addr=staging.addr, n_channels=1)
    with TransferSession("rdma_staged", cfg) as sess:
        sess.write("legacy", np.ones(1024))
        sess.sync()
        assert sess.transport.comm._channels is None
    assert sess.stats.channels == []


# ---------------------------------------------------------------------------
# soak: no thread / socket growth across repeated striped sessions
# ---------------------------------------------------------------------------


def _fd_count():
    return len(os.listdir("/proc/self/fd"))


def test_soak_no_thread_or_socket_growth(savime, staging):
    def one_session(tag):
        cfg = TransportConfig(staging_addr=staging.addr, n_channels=3,
                              stripe_bytes=32 << 10)
        with TransferSession("rdma_staged", cfg) as sess:
            for i in range(3):
                sess.write(f"{tag}_{i}", np.ones(8 << 10))
            sess.sync()

    one_session("warmup")           # populate lazy per-thread state
    time.sleep(0.2)
    threads0, fds0 = threading.active_count(), _fd_count()
    for r in range(4):
        one_session(f"soak{r}")
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline:
        if threading.active_count() <= threads0 and _fd_count() <= fds0 + 2:
            break
        time.sleep(0.1)
    assert threading.active_count() <= threads0, \
        f"thread leak: {threads0} -> {threading.active_count()}"
    assert _fd_count() <= fds0 + 2, f"fd leak: {fds0} -> {_fd_count()}"


# ---------------------------------------------------------------------------
# wire fixes: ConnCache addr keying, header cap, sendfile stall timeout
# ---------------------------------------------------------------------------


def test_conncache_keyed_by_addr(savime, staging):
    cache = wire.ConnCache()
    a = cache.get(savime.addr)
    b = cache.get(staging.addr)
    assert a is not b, "one thread talking to two addrs must get two conns"
    assert cache.get(savime.addr) is a          # still cached per addr
    cache.close_all()
    assert a.fileno() == -1 and b.fileno() == -1


def test_recv_frame_header_length_capped():
    a, b = socket.socketpair()
    try:
        # a corrupt 8-byte prefix claiming a gigantic header must raise,
        # not allocate gigabytes
        a.sendall(struct.pack(">Q", 1 << 40) + b"junk")
        with pytest.raises(wire.ProtocolError, match="header length"):
            wire.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_sendfile_raises_timeout_on_stalled_peer(tmp_path):
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    cli = socket.create_connection(srv.getsockname())
    peer, _ = srv.accept()
    path = tmp_path / "payload.bin"
    path.write_bytes(b"\x00" * (8 << 20))
    fd = os.open(path, os.O_RDONLY)
    try:
        cli.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 16 << 10)
        peer.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 16 << 10)
        cli.settimeout(0.05)          # internally non-blocking sendfile
        with pytest.raises(TimeoutError, match="not writable"):
            # the peer never reads: the buffers fill and writability never
            # arrives — this used to spin in the EAGAIN loop forever
            wire.send_frame_from_file(cli, {"op": "x"}, fd, 8 << 20,
                                      timeout=0.3)
    finally:
        os.close(fd)
        cli.close()
        peer.close()
        srv.close()


# ---------------------------------------------------------------------------
# queue fixes: no requeue after stop, bounded completion history
# ---------------------------------------------------------------------------


def test_failed_task_not_requeued_after_stop():
    release = threading.Event()

    def fails_late():
        release.wait(5)
        raise RuntimeError("boom")

    pool = FCFSPool(1, "stop-retry-test", max_retries=5)
    h = pool.submit(fails_late, name="failer")
    pool._stop.set()                 # stop initiated while task in flight
    release.set()
    with pytest.raises(RuntimeError, match="boom"):
        h.wait(5)
    # without the fix the failure is re-enqueued behind the shutdown
    # sentinels: _pending never drains and sync() hangs forever
    pool.sync(timeout=2)
    assert pool.pending() == 0
    pool.stop()


def test_completed_history_bounded_with_aggregate_stats():
    pool = FCFSPool(2, "ring-test", completed_cap=16)
    for i in range(100):
        pool.submit(lambda: None, name=f"t{i}")
    pool.sync(timeout=30)
    assert len(pool.completed) == 16           # capped ring
    assert pool.n_completed == 100             # aggregate keeps counting
    stats = pool.latency_stats()
    assert stats["count"] == 100
    assert stats["mean_s"] >= 0
    assert stats["failed"] == 0
    assert len(pool.latencies()) <= 16
    pool.stop()
