"""Multi-device distribution tests — run in subprocesses with 8 fake CPU
devices (XLA_FLAGS must be set before jax init, and the main pytest process
must keep its single-device view)."""
import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)


def _run(check: str, timeout: int = 560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_HERE, "..", "src")
    r = subprocess.run(
        [sys.executable, os.path.join(_HERE, "distributed_checks.py"), check],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"{check}:\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}"
    assert f"{check} OK" in r.stdout


def test_sharded_train_step_matches_single_device():
    _run("check_sharded_equals_single")


def test_compressed_cross_pod_gradient_reduce():
    _run("check_compressed_pod_reduce")


def test_compressed_reduce_at_nondivisible_block_rows():
    try:
        _run("check_compressed_reduce_nondivisible")
    except AssertionError as e:
        if "has no attribute 'AxisType'" in str(e):
            # same pre-existing jax-version gap that fails the other
            # debug-mesh checks in old environments; don't double-count it
            pytest.skip("jax too old for make_debug_mesh")
        raise


def test_checkpoint_reshard_across_meshes():
    _run("check_reshard_restore")


def test_sequence_sharded_decode_matches_replicated():
    _run("check_seq_sharded_decode")
