"""Thread/fd lifecycle soak (ISSUE 9 satellite: daemon-thread audit).

Every server in the stack tracks the threads it starts and joins them
(bounded) from its stop()/close(); sockets close on all paths.  The
observable contract: repeatedly starting and stopping the full stack
returns the process to its thread-count and fd-count baseline — no
accumulating daemon threads, no leaked descriptors.
"""
import os
import threading
import time

from repro.core import wire
from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer
from repro.gateway import GatewayClient, GatewayServer, RingNode

CYCLES = 4


def _fd_count() -> int:
    return len(os.listdir("/proc/self/fd"))


def _settle(baseline: int, timeout: float = 5.0) -> int:
    """Wait for bounded-join stragglers to finish dying."""
    deadline = time.monotonic() + timeout
    n = threading.active_count()
    while n > baseline and time.monotonic() < deadline:
        time.sleep(0.05)
        n = threading.active_count()
    return n


def _one_cycle() -> None:
    sv = SavimeServer().start()
    st = StagingServer(sv.addr, mem_capacity=1 << 20).start()
    gw = GatewayServer([RingNode("b0", st.addr, savime_addr=sv.addr)],
                       health_interval=0.05).start()
    try:
        cli = GatewayClient(gw.addr)
        assert cli.admit("soak-ds", 1024) == st.addr
        cli.close()
        s = wire.connect(st.addr)
        h, _ = wire.request(s, {"op": "ping"})
        assert h["ok"]
        s.close()
    finally:
        gw.stop()
        st.stop()
        sv.stop()


def test_stack_start_stop_soak_no_thread_or_fd_leak():
    _one_cycle()                       # warmup: thread-locals, imports
    thread_base = _settle(threading.active_count())
    fd_base = _fd_count()
    for _ in range(CYCLES):
        _one_cycle()
    threads = _settle(thread_base)
    # identical stack, identical teardown: counts return to baseline
    # (+1 slack for a bounded-join straggler mid-death)
    assert threads <= thread_base + 1, (
        f"thread leak: {threads} live after soak vs baseline {thread_base}: "
        f"{[t.name for t in threading.enumerate()]}")
    assert _fd_count() <= fd_base + 2, (
        f"fd leak: {_fd_count()} open after soak vs baseline {fd_base}")
    # the servers' own accounting agrees: no half-open serve threads
    assert not [t for t in threading.enumerate()
                if t.name.startswith(("staging-", "gateway-", "savime-"))]
