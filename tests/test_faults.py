"""Chaos suite for the fault-injection harness (DESIGN.md §15).

Deterministic, seeded fault plans drive the wire layer (drop / delay /
dup / corrupt / partition) and the process scheduler (kill), and every
test asserts the durability contract end to end: acked datasets are
bit-identical at SAVIME, replays never double-count (server (name,
epoch) dedup), and sessions finish within their deadline instead of
hanging. Also covers the shared RetryPolicy, the bin1->JSON degradation
ladder, ChannelGroup single-channel death (survivors finish, stats
record the failover, drain does not deadlock), gateway re-homing after
a backend fail-out, and the typed Subscription / AnalysisSession
errors.
"""
import json
import time

import numpy as np
import pytest

from repro.analysis import AnalysisSession, SubscriptionClosed, tar
from repro.core import SavimeServer, StagingServer, wire
from repro.core.retry import RetryExhausted, RetryPolicy
from repro.faults import (FaultInjector, FaultPlan, FaultRule, injected)
from repro.gateway import GatewayClient, StagingPool
from repro.transport import ChannelGroup, TransferSession, TransportConfig
from repro.transport import channels as channels_mod

pytestmark = pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnraisableExceptionWarning")


@pytest.fixture()
def savime():
    srv = SavimeServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def staging(savime):
    srv = StagingServer(savime.addr, mem_capacity=256 << 20,
                        send_threads=2).start()
    yield srv
    srv.stop()


@pytest.fixture()
def payload_stripes(monkeypatch):
    """Force the payload data plane: without a locally-mappable region
    the stripes carry their bytes on the socket (where the injector can
    corrupt them) instead of the one-sided mmap store."""
    monkeypatch.setattr(channels_mod, "writer_for_reply", lambda h, n: None)


# ---------------------------------------------------------------------------
# RetryPolicy: the shared backoff engine
# ---------------------------------------------------------------------------


def test_retry_policy_exhaustion_is_typed():
    pol = RetryPolicy(retries=2, base_s=0.0, seed=1)
    tries = 0
    with pytest.raises(RetryExhausted) as ei:
        for attempt in pol.attempts("flaky op"):
            tries += 1
            attempt.backoff(OSError("boom"))
    assert tries == 3                      # retries=2 -> 3 attempts
    assert isinstance(ei.value, ConnectionError)   # catchable as the base
    assert isinstance(ei.value.last, OSError)      # root cause preserved
    assert "flaky op" in str(ei.value)


def test_retry_policy_deadline_budget():
    pol = RetryPolicy(retries=1000, base_s=0.2, cap_s=0.2,
                      deadline_s=0.05, seed=1)
    t0 = time.monotonic()
    with pytest.raises(RetryExhausted) as ei:
        for attempt in pol.attempts("stuck op"):
            attempt.backoff(ConnectionError("down"))
    assert time.monotonic() - t0 < 2.0     # budget, not 1000 retries
    assert "deadline" in str(ei.value)


# ---------------------------------------------------------------------------
# FaultPlan: the schedule DSL
# ---------------------------------------------------------------------------


def test_fault_plan_dsl_and_json_roundtrip(tmp_path):
    spec = "seed=42;drop:op=stripe,prob=0.01;kill:target=staging:0,at_s=0.5"
    plan = FaultPlan.parse(spec)
    assert plan.seed == 42
    drop, kill = plan.rules
    assert (drop.kind, drop.op, drop.prob) == ("drop", "stripe", 0.01)
    assert (kill.kind, kill.target, kill.at_s) == ("kill", "staging:0", 0.5)
    assert plan.wire_rules == [drop] and plan.kill_rules == [kill]
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(plan.encode()))
    assert FaultPlan.parse(str(p)).encode() == plan.encode()


def test_fault_plan_rejects_bad_specs():
    with pytest.raises(ValueError):
        FaultPlan.parse("explode:prob=1.0")          # unknown kind
    with pytest.raises(ValueError):
        FaultPlan.parse("kill:at_s=1.0")             # kill needs target=
    with pytest.raises(ValueError):
        FaultPlan.parse("drop:bogus=3")              # unknown rule key


def test_injector_is_deterministic_per_seed():
    def firing_pattern(spec):
        inj = FaultInjector(FaultPlan.parse(spec))
        return [i for i in range(200)
                if inj._decide("peer:1", {"op": "stripe"}) is not None]

    spec9 = "seed=9;corrupt:op=stripe,prob=0.3"
    pattern = firing_pattern(spec9)
    assert pattern                                   # fires at all
    assert pattern == firing_pattern(spec9)          # same seed: identical
    assert pattern != firing_pattern("seed=10;corrupt:op=stripe,prob=0.3")


# ---------------------------------------------------------------------------
# drop: connection-level retry, then the in-flight journal
# ---------------------------------------------------------------------------


def test_drop_absorbed_by_connection_retry(savime, staging):
    """A single injected link death is absorbed inside the write's own
    retry loop — no journal replay, no data loss, no duplicates."""
    plan = FaultPlan.parse("seed=3;drop:op=write_req,nth=2")
    rng = np.random.default_rng(3)
    bufs = {f"dr{i}": rng.standard_normal(2048) for i in range(4)}
    with injected(plan, scope=[staging.addr]) as inj:
        cfg = TransportConfig(staging_addr=staging.addr, io_threads=2)
        with TransferSession("rdma_staged", cfg) as sess:
            futs = [sess.write(n, b, dtype="float64")
                    for n, b in bufs.items()]
            sess.sync(timeout=30)
            assert all(f.done() for f in futs)
    assert inj.fired.get("drop") == 1
    assert sess.stats.replay_dups == 0
    for n, b in bufs.items():
        got = np.frombuffer(savime.engine.datasets[n], dtype=np.float64)
        assert np.array_equal(got, b), n


def test_journal_replays_after_retries_exhausted(savime, staging):
    """With the per-write retry budget at zero, three consecutive link
    deaths exhaust the transport's attempts — the session's in-flight
    journal then replays the pinned buffer and the write still lands."""
    # rule order matters: _decide stops at the first firing rule, so only
    # rules *before* it keep counting that frame — listing nth=3,2,1 makes
    # the three rules fire on three consecutive write_req frames
    plan = FaultPlan(seed=4, rules=[
        FaultRule("drop", op="write_req", nth=k) for k in (3, 2, 1)])
    rng = np.random.default_rng(4)
    buf = rng.standard_normal(4096)
    with injected(plan, scope=[staging.addr]) as inj:
        cfg = TransportConfig(staging_addr=staging.addr, io_threads=1,
                              retry=0)
        with TransferSession("rdma_staged", cfg) as sess:
            fut = sess.write("journaled", buf, dtype="float64")
            sess.sync(timeout=30)
            assert fut.done()
    assert inj.fired.get("drop") == 3
    assert sess.stats.replays >= 1
    got = np.frombuffer(savime.engine.datasets["journaled"],
                        dtype=np.float64)
    assert np.array_equal(got, buf)


def test_server_dedups_replayed_epochs(savime, staging):
    """The receiver's (name, epoch) log: a replay of an already-acked
    write acks `dup` without ingesting a second copy."""
    payload = bytes(range(256)) * 8
    open_req = {"op": "stripe_open", "name": "epoch_d", "dtype": "uint8",
                "size": len(payload), "n_stripes": 1, "credits": 4,
                "epoch": "aa-1"}
    s = wire.connect(staging.addr)
    try:
        h, _ = wire.request(s, open_req)
        assert h["ok"] and not h.get("dup")
        a, _ = wire.request(s, {"op": "stripe", "file_id": h["file_id"],
                                "stripe_idx": 0, "n_stripes": 1,
                                "offset": 0}, payload)
        assert a["ok"] and a["done"]
        before = staging.stats["datasets"]
        h2, _ = wire.request(s, open_req)       # the replay
        assert h2["ok"] and h2["dup"]
        assert staging.stats["datasets"] == before      # not double-counted
        assert staging.stats["replay_dups"] >= 1
        staging.drain(10)
        got = bytes(savime.engine.datasets["epoch_d"].view(np.uint8))
        assert got == payload
    finally:
        s.close()


def test_partition_blocks_connects_then_heals(savime, staging):
    plan = FaultPlan(seed=2)
    with injected(plan, scope=[staging.addr]) as inj:
        inj.partition(None, duration_s=30)
        with pytest.raises((ConnectionError, OSError)):
            wire.connect(staging.addr)
        inj.heal()
        s = wire.connect(staging.addr)
        try:
            h, _ = wire.request(s, {"op": "stats"})
            assert h["ok"]
        finally:
            s.close()
    assert inj.fired.get("partition") == 1


# ---------------------------------------------------------------------------
# corrupt: CRC rejection, resend, and the bin1 -> JSON degradation ladder
# ---------------------------------------------------------------------------


def test_corrupt_stripes_detected_and_resent(savime, staging,
                                             payload_stripes):
    """~5% random frame corruption on a striped bin1 transfer: every
    mangled stripe is CRC-rejected and resent; the data that lands is
    bit-identical."""
    plan = FaultPlan.parse("seed=11;corrupt:op=stripe,prob=0.05,flips=3")
    cfg = TransportConfig(staging_addr=staging.addr, n_channels=2,
                          wire_format="bin1", stripe_bytes=8 << 10,
                          io_threads=2)
    rng = np.random.default_rng(11)
    bufs = {f"cr{i}": rng.standard_normal(8192) for i in range(10)}
    with injected(plan, scope=[staging.addr]) as inj:
        with TransferSession("rdma_staged", cfg) as sess:
            for n, b in bufs.items():
                sess.write(n, b, dtype="float64")
            sess.sync(timeout=60)
    assert inj.fired.get("corrupt", 0) >= 1
    assert staging.stats["crc_errors"] == inj.fired["corrupt"]
    assert sum(c["crc_retries"] for c in sess.stats.channels) == \
        staging.stats["crc_errors"]
    for n, b in bufs.items():
        got = np.frombuffer(savime.engine.datasets[n], dtype=np.float64)
        assert np.array_equal(got, b), n


def test_bin1_falls_back_to_json_after_persistent_crc(savime, staging,
                                                      payload_stripes):
    """Three consecutive CRC rejections mark the binary path itself as
    suspect: the channel degrades to JSON frames and the transfer still
    completes intact (DESIGN.md §15 degradation ladder)."""
    # nth=3,2,1 ordering (see the journal test) + a credit window of one:
    # the first three stripe frames on the wire are mangled back-to-back,
    # so the rejections are guaranteed consecutive
    plan = FaultPlan(seed=1, rules=[
        FaultRule("corrupt", op="stripe", nth=k) for k in (3, 2, 1)])
    rng = np.random.default_rng(12)
    arr = rng.integers(0, 255, 8192, dtype=np.uint8)
    with injected(plan, scope=[staging.addr]) as inj:
        group = ChannelGroup(staging.addr, n_channels=1,
                             stripe_bytes=2 << 10, credits=1,
                             wire_format="bin1").open()
        try:
            assert group.send_dataset("fallback_d", "uint8", arr,
                                      timeout=30) == arr.nbytes
            stats = group.channel_stats()
        finally:
            group.close()
    assert inj.fired.get("corrupt") == 3
    assert staging.stats["crc_errors"] == 3
    assert sum(c["crc_retries"] for c in stats) == 3
    assert sum(c["wire_fallbacks"] for c in stats) == 1
    staging.drain(10)
    got = bytes(savime.engine.datasets["fallback_d"].view(np.uint8))
    assert got == arr.tobytes()


# ---------------------------------------------------------------------------
# channel death: survivors adopt the orphans, no drain deadlock
# ---------------------------------------------------------------------------


def test_channel_death_survivors_finish(savime, staging):
    """One of three channels dies mid-stripe: the orphaned stripes are
    adopted by the survivors, stats record the failover on both sides,
    the data is intact, and drain() completes without deadlocking."""
    plan = FaultPlan(seed=5, rules=[FaultRule("drop", op="stripe", nth=4)])
    rng = np.random.default_rng(5)
    arr = rng.integers(0, 255, 96 * 1024, dtype=np.uint8)   # 24 stripes
    with injected(plan, scope=[staging.addr]) as inj:
        group = ChannelGroup(staging.addr, n_channels=3,
                             stripe_bytes=4 << 10, credits=2).open()
        try:
            assert group.send_dataset("failover_d", "uint8", arr,
                                      timeout=30) == arr.nbytes
            stats = group.channel_stats()
        finally:
            group.close()
    assert inj.fired.get("drop") == 1
    assert sum(c["failed_over"] for c in stats) >= 1
    assert sum(c["adopted"] for c in stats) >= 1
    staging.drain(10)                       # must not deadlock
    got = bytes(savime.engine.datasets["failover_d"].view(np.uint8))
    assert got == arr.tobytes()


# ---------------------------------------------------------------------------
# gateway: backend death mid-session, zero-loss re-homing
# ---------------------------------------------------------------------------


def test_gateway_backend_death_rehoming_zero_loss():
    """Kill one staging backend mid-session: unacked writes re-admit onto
    the rebuilt ring and land on the survivor; everything previously
    drained stays queryable (the dead backend's SAVIME survives); the
    gateway's parity totals never double-charge a replayed epoch."""
    pool = StagingPool(2, health_interval=0.05).start()
    try:
        rng = np.random.default_rng(7)
        phase1 = {f"gwA{i}": rng.standard_normal(2048) for i in range(8)}
        phase2 = {f"gwB{i}": rng.standard_normal(2048) for i in range(12)}
        plan = FaultPlan(seed=0, rules=[
            FaultRule("kill", target="staging:0", at_s=0.05)])
        cfg = TransportConfig(gateway_addr=pool.addr, io_threads=2,
                              retry=8)
        with pool.with_faults(plan) as harness:
            with TransferSession("rdma_staged", cfg) as sess:
                for n, b in phase1.items():
                    sess.write(n, b, dtype="float64")
                sess.sync(timeout=30)
                sess.drain(timeout=30)
                deadline = time.monotonic() + 5
                while not harness.scheduler.killed and \
                        time.monotonic() < deadline:
                    time.sleep(0.02)
                assert harness.scheduler.killed == ["staging:0"]
                for n, b in phase2.items():
                    sess.write(n, b, dtype="float64")
                sess.sync(timeout=60)
                sess.drain(timeout=60)
            gw = sess.stats.gateway
        union = {}
        for sv in pool.savimes:
            union.update(sv.engine.datasets)
        for n, b in {**phase1, **phase2}.items():
            got = np.frombuffer(union[n], dtype=np.float64)
            assert np.array_equal(got, b), n
        assert gw["live_backends"] == 1
        assert gw["totals"]["admitted_datasets"] == len(phase1) + len(phase2)
        assert gw["readmits"] >= 1          # retried writes re-admitted
    finally:
        pool.stop()


def test_gateway_readmit_accounting():
    """A re-admit of the same (name, epoch) is dedup'd: no double charge
    in the parity totals, and the reply is flagged dup."""
    pool = StagingPool(2).start()
    try:
        gc = GatewayClient(pool.addr)
        try:
            a1 = gc.admit("ds_x", 1024, epoch="aa-1")
            a2 = gc.admit("ds_x", 1024, epoch="aa-1")
            assert a1 == a2
            st = gc.stats()
            assert st["totals"]["admitted_datasets"] == 1
            assert st["totals"]["admitted_bytes"] == 1024
            assert st["readmits"] == 1
        finally:
            gc.close()
    finally:
        pool.stop()


# ---------------------------------------------------------------------------
# analysis side: typed server-gone errors
# ---------------------------------------------------------------------------


def test_subscription_closed_vs_timeout(savime):
    """poll() returning None means quiet; a dead server raises the typed
    SubscriptionClosed and latches .closed."""
    with AnalysisSession(savime.addr) as an:
        sub = an.watch("")
        try:
            assert sub.poll(0.05) is None       # timeout: just quiet
            assert not sub.closed
            savime.stop()
            with pytest.raises(SubscriptionClosed):
                sub.poll(5.0)                   # EOF, not a 5s wait
            assert sub.closed
            with pytest.raises(SubscriptionClosed):
                sub.poll(0.01)                  # latched
            assert list(sub) == []              # iteration ends cleanly
        finally:
            sub.close()


def test_analysis_session_retry_exhausted(savime):
    """Idempotent queries against a dead server surface the typed
    RetryExhausted after the shared policy's jittered attempts."""
    an = AnalysisSession(savime.addr, retries=2, retry_backoff_s=0.01).open()
    try:
        an.execute('create_tar(rt, "x:0:3", "v:float64")')
        savime.stop()
        with pytest.raises(RetryExhausted):
            an.execute(tar("rt").attr("v").select())
        assert an.stats.n_retries == 3          # retries=2 -> 3 attempts
    finally:
        an.close()
