"""Binary wire fast path + small-dataset coalescing (DESIGN.md §10).

Covers: property-based round-trips of the packed bin1 headers,
binary↔JSON negotiation fallback in both directions (old client vs new
server and vice versa), vectored scatter-gather sends, the receive
buffer pool, coalescer flush-on-size / flush-on-linger / flush-on-close,
batched reservation rollback on partial failure, end-to-end content
parity on every path combination, proactive credit pushes, and the
guard that the copy-emulation baselines never negotiate the binary path.
"""
from __future__ import annotations

import socket
import struct
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.core import wire
from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer
from repro.transport import TransferSession, TransportConfig, create
from repro.transport.channels import ChannelGroup
from repro.transport.coalesce import Coalescer

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _stack(**kw):
    sv = SavimeServer().start()
    stg = StagingServer(sv.addr, mem_capacity=kw.pop("mem_capacity", 1 << 30),
                        **kw).start()
    return sv, stg


def _roundtrip(header, payload=None):
    a, b = socket.socketpair()
    try:
        wire.send_frame_bin(a, header, payload)
        return wire.recv_frame(b)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# packed-header round-trips (property-based)
# ---------------------------------------------------------------------------


@st.composite
def _hot_headers(draw):
    op = draw(st.sampled_from(["stripe", "reg_block", "ack", "credit"]))
    ident = "".join(f"{draw(st.integers(0, 255)):02x}"
                    for _ in range(draw(st.integers(1, 8))))
    if op == "stripe":
        h = {"op": "stripe", "file_id": ident,
             "stripe_idx": draw(st.integers(0, 1 << 31)),
             "n_stripes": draw(st.integers(0, 1 << 31)),
             "offset": draw(st.integers(0, 1 << 62))}
        if draw(st.sampled_from([0, 1])):
            h["sided"] = 1
            h["size"] = draw(st.integers(0, 1 << 62))
    elif op == "reg_block":
        h = {"op": "reg_block", "file_id": ident,
             "offset": draw(st.integers(0, 1 << 62)),
             "size": draw(st.integers(0, 1 << 62))}
    elif op == "ack":
        h = {"op": "ack", "ok": bool(draw(st.sampled_from([0, 1]))),
             "dup": bool(draw(st.sampled_from([0, 1]))),
             "done": bool(draw(st.sampled_from([0, 1]))),
             "stripe_idx": draw(st.integers(0, 1 << 31)),
             "credits": draw(st.integers(0, 1 << 31)),
             "offset": draw(st.integers(0, 1 << 62)),
             "size": draw(st.integers(0, 1 << 62))}
        if draw(st.sampled_from([0, 1])):
            h["rkey"] = ident
    else:
        h = {"op": "credit", "credits": draw(st.integers(0, 1 << 31))}
    return h


@given(header=_hot_headers(), nbytes=st.integers(0, 1 << 16))
def test_bin_header_roundtrip(header, nbytes):
    """Every hot op survives pack -> unpack with its semantic fields
    intact — including identifiers whose raw bytes end in 0x00 (the
    padding must not eat them)."""
    hb = wire.encode_bin_header(header, nbytes)
    assert hb is not None and len(hb) == wire.BIN_HEADER_LEN
    assert hb[0] == wire.BIN_MAGIC
    dec = wire.decode_bin_header(hb)
    assert dec.pop("_bin") is True
    assert dec.pop("nbytes") == nbytes
    for k, v in header.items():
        if header.get("op") == "ack" and k in ("ok", "dup", "done"):
            assert dec[k] == bool(v)
        elif k == "sided":
            assert dec[k] == 1
        else:
            assert dec[k] == v, (k, header, dec)


def test_bin_header_trailing_zero_id_exact():
    h = {"op": "stripe", "file_id": "ab00cd0000000000", "stripe_idx": 1,
         "n_stripes": 2, "offset": 0}
    dec = wire.decode_bin_header(wire.encode_bin_header(h, 0))
    assert dec["file_id"] == "ab00cd0000000000"


def test_bin_header_falls_back_for_non_hot_ops():
    assert wire.encode_bin_header({"op": "write_req", "size": 4}, 0) is None
    assert wire.encode_bin_header({"op": "batch_open", "items": []}, 0) is None
    # oversized identifier cannot ride the fixed layout either
    assert wire.encode_bin_header(
        {"op": "stripe", "file_id": "ab" * 9, "stripe_idx": 0,
         "n_stripes": 1, "offset": 0}, 0) is None


def test_bin_version_and_magic_rejected():
    hb = bytearray(wire.encode_bin_header(
        {"op": "credit", "credits": 1}, 0))
    hb[1] = 99                                 # unsupported version
    with pytest.raises(wire.ProtocolError, match="version"):
        wire.decode_bin_header(bytes(hb))
    hb[1] = wire.BIN_VERSION
    hb[2] = 200                                # unknown op
    with pytest.raises(wire.ProtocolError, match="unknown binary op"):
        wire.decode_bin_header(bytes(hb))


def test_bin_error_ack_carries_message_as_payload():
    h, _ = _roundtrip({"op": "ack", "ok": False, "error": "kaboom"})
    assert h["ok"] is False and h["error"] == "kaboom"


def test_bin_and_json_frames_interleave_on_one_stream():
    a, b = socket.socketpair()
    try:
        wire.send_frame_bin(a, {"op": "stripe", "file_id": "aa" * 8,
                                "stripe_idx": 0, "n_stripes": 1,
                                "offset": 0}, b"pay")
        wire.send_frame(a, {"op": "stats"})
        wire.send_frame_bin(a, {"op": "credit", "credits": 3})
        h1, p1 = wire.recv_frame(b)
        h2, _ = wire.recv_frame(b)
        h3, _ = wire.recv_frame(b)
        assert h1["op"] == "stripe" and bytes(p1) == b"pay"
        assert h2 == {"op": "stats", "nbytes": 0}
        assert h3["op"] == "credit" and h3["credits"] == 3
    finally:
        a.close()
        b.close()


def test_legacy_json_frame_bytes_identical():
    """wire_format=json must stay byte-identical to the pre-bin1 wire."""
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, {"op": "ping"}, b"xy")
        import json
        hb = json.dumps({"op": "ping", "nbytes": 2}).encode()
        expect = struct.pack(">Q", len(hb)) + hb + b"xy"
        got = b.recv(1024)
        assert got == expect
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# vectored sends + buffer pool
# ---------------------------------------------------------------------------


def test_send_frames_vectored_parity_and_partial_sends():
    """Many frames (binary + JSON fallback, multi-buffer payloads) pushed
    through one vectored call arrive frame-for-frame identical, even when
    a tiny send buffer forces partial sendmsg continuation."""
    a, b = socket.socketpair()
    a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 16 << 10)
    payload = np.arange(512 << 10, dtype=np.uint8)
    frames = [({"op": "stripe", "file_id": "ab" * 8, "stripe_idx": i,
                "n_stripes": 4, "offset": i * 100}, payload[i::4])
              for i in range(4)]
    frames.append(({"op": "batch_write", "count": 2},
                   [b"left", bytearray(b"right")]))
    frames.append(({"op": "credit", "credits": 9}, None))
    got = []
    rx = threading.Thread(
        target=lambda: [got.append(wire.recv_frame(b)) for _ in frames])
    rx.start()
    # non-contiguous numpy slices are not iovec-able; hand contiguous ones
    contiguous = [(h, np.ascontiguousarray(p) if isinstance(p, np.ndarray)
                   else p) for h, p in frames]
    n = wire.send_frames_vectored(a, contiguous, fmt=wire.WIRE_BIN1)
    rx.join(10)
    assert n == len(frames) and len(got) == len(frames)
    for (h, p), (rh, rp) in zip(contiguous, got):
        assert rh["op"] == h["op"]
        if h["op"] == "stripe":
            assert bytes(rp) == p.tobytes()
    assert bytes(got[4][1]) == b"leftright"
    assert got[5][0]["credits"] == 9
    a.close()
    b.close()


def test_buffer_pool_reuses_released_buffers():
    pool = wire.BufferPool(max_per_bucket=2)
    v1 = pool.acquire(1000)
    assert len(v1) == 1000
    backing = v1.obj
    pool.release(v1)
    v2 = pool.acquire(900)          # same pow2 bucket (1024)
    assert v2.obj is backing
    # unreleased leases degrade to plain allocation, never corruption
    v3 = pool.acquire(900)
    assert v3.obj is not backing
    # bucket bound holds
    pool.release(v2)
    pool.release(v3)
    extra = pool.acquire(900)
    pool.release(extra)
    assert len(pool._buckets[1024]) <= 2


def test_recv_header_uses_scratch_not_fresh_allocations():
    """Headers of any size parse from the per-thread scratch buffer; the
    old double-materialization (bytes(bytearray)) is gone, behavior is
    unchanged."""
    a, b = socket.socketpair()
    try:
        big = {"op": "x", "blob": "y" * 5000}
        wire.send_frame(a, big)
        wire.send_frame(a, {"op": "small"})
        h1 = wire.recv_header(b)
        assert h1["blob"] == "y" * 5000
        wire.drain_payload(b, h1)
        assert wire.recv_header(b)["op"] == "small"
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# negotiation (both fallback directions)
# ---------------------------------------------------------------------------


class _PreBin1StagingServer(StagingServer):
    """A server from before this PR: hello is an unknown op."""

    def _handle(self, h, payload):
        if h.get("op") == "hello":
            raise ValueError(f"unknown op {h.get('op')!r}")
        return super()._handle(h, payload)


def test_negotiate_agrees_bin1_with_new_server():
    sv, stg = _stack()
    try:
        sock = wire.connect(stg.addr)
        assert wire.negotiate(sock) == wire.WIRE_BIN1
        assert wire.negotiated(sock) == wire.WIRE_BIN1
        sock.close()
    finally:
        stg.stop()
        sv.stop()


def test_new_client_vs_old_server_falls_back_to_json():
    """bin1-preferring client against a pre-handshake server: the unknown
    hello op *is* the negotiation — everything stays on JSON and the
    transfer still lands."""
    sv = SavimeServer().start()
    stg = _PreBin1StagingServer(sv.addr, mem_capacity=1 << 30).start()
    try:
        data = np.arange(4096, dtype=np.float64)
        cfg = TransportConfig(staging_addr=stg.addr, wire_format="bin1",
                              block_size=8 << 10)
        with TransferSession("rdma_staged", cfg) as sess:
            sess.write("fallback", data, dtype="float64")
            sess.sync()
            sess.drain()
        assert stg.stats["bin_conns"] == 0
        assert np.array_equal(sv.engine.datasets["fallback"], data)
    finally:
        stg.stop()
        sv.stop()


def test_old_client_vs_new_server_stays_json():
    """A client that never sends hello (wire_format=json is the default)
    speaks the byte-identical legacy protocol against the new server."""
    sv, stg = _stack()
    try:
        data = np.arange(2048, dtype=np.float64)
        cfg = TransportConfig(staging_addr=stg.addr, block_size=8 << 10)
        assert cfg.wire_format == "json" and cfg.coalesce_bytes == 0
        with TransferSession("rdma_staged", cfg) as sess:
            sess.write("legacy", data, dtype="float64")
            sess.sync()
            sess.drain()
        assert stg.stats["bin_conns"] == 0
        assert stg.stats["batches"] == 0
        assert np.array_equal(sv.engine.datasets["legacy"], data)
    finally:
        stg.stop()
        sv.stop()


def test_binary_block_and_striped_paths_end_to_end():
    sv, stg = _stack()
    try:
        bufs = {f"d{i}": np.random.default_rng(i).standard_normal(4096)
                for i in range(6)}
        # block path (n_channels=1): reg_block/ack ride bin1
        cfg = TransportConfig(staging_addr=stg.addr, wire_format="bin1",
                              block_size=8 << 10)
        with TransferSession("rdma_staged", cfg) as sess:
            for n, b in bufs.items():
                sess.write(n, b, dtype="float64")
            sess.sync()
            sess.drain()
        # striped path: stripe/ack frames ride bin1 on every channel
        cfg2 = cfg.replace(n_channels=2, stripe_bytes=8 << 10)
        with TransferSession("rdma_staged", cfg2) as sess:
            for n, b in bufs.items():
                sess.write("s" + n, b, dtype="float64")
            sess.sync()
            sess.drain()
        assert stg.stats["bin_conns"] >= 2       # both data channels
        for n, b in bufs.items():
            assert np.array_equal(sv.engine.datasets[n], b)
            assert np.array_equal(sv.engine.datasets["s" + n], b)
    finally:
        stg.stop()
        sv.stop()


# ---------------------------------------------------------------------------
# coalescer unit behavior
# ---------------------------------------------------------------------------


class _Recorder:
    def __init__(self, fail=False):
        self.batches = []
        self.fail = fail
        self.event = threading.Event()

    def __call__(self, items):
        self.batches.append(items)
        self.event.set()
        if self.fail:
            raise RuntimeError("flush exploded")


def _add(c, name, n=1024):
    return c.add(name, "uint8", np.zeros(n, dtype=np.uint8), n)


def test_coalescer_flush_on_size():
    rec = _Recorder()
    c = Coalescer(rec, coalesce_bytes=4096, linger_ms=10_000)
    try:
        handles = [_add(c, f"a{i}", 1024) for i in range(4)]  # == threshold
        assert rec.event.wait(5)
        for h in handles:
            assert h.wait(5) == 1024
        assert len(rec.batches) == 1 and len(rec.batches[0]) == 4
    finally:
        c.close()


def test_coalescer_flush_on_linger():
    rec = _Recorder()
    c = Coalescer(rec, coalesce_bytes=1 << 30, linger_ms=30)
    try:
        t0 = time.monotonic()
        h = _add(c, "lone", 64)
        h.wait(5)
        elapsed = time.monotonic() - t0
        # flushed by the linger window, not size and not immediately
        assert 0.02 <= elapsed < 5
        assert len(rec.batches) == 1
    finally:
        c.close()


def test_coalescer_flush_on_close():
    rec = _Recorder()
    c = Coalescer(rec, coalesce_bytes=1 << 30, linger_ms=60_000)
    h = _add(c, "tail", 64)
    c.close()
    assert h.done.is_set() and h.error is None
    assert len(rec.batches) == 1


def test_coalescer_sync_flushes_and_failure_reaches_handles():
    rec = _Recorder(fail=True)
    c = Coalescer(rec, coalesce_bytes=1 << 30, linger_ms=60_000)
    try:
        handles = [_add(c, f"f{i}") for i in range(3)]
        c.sync(5)
        for h in handles:
            with pytest.raises(RuntimeError, match="flush exploded"):
                h.wait(1)
    finally:
        c.close()


def test_coalescer_rejects_adds_after_close():
    c = Coalescer(_Recorder(), coalesce_bytes=1024)
    c.close()
    with pytest.raises(RuntimeError, match="closed"):
        _add(c, "late")


# ---------------------------------------------------------------------------
# batched reservations: rollback + end-to-end coalescing
# ---------------------------------------------------------------------------


def test_batch_open_rollback_on_partial_failure(monkeypatch):
    """If the Nth reservation of a batch fails, every earlier one is
    released (capacity and regions) and the connection stays framed."""
    import repro.core.staging as staging_mod
    sv, stg = _stack()
    real_region = staging_mod.MemoryRegion
    made = []

    class Flaky(real_region):
        def __init__(self, *a, **kw):
            if len(made) == 2:          # third region creation explodes
                made.append("boom")
                raise OSError("synthetic mmap failure")
            made.append(a[0] if a else kw.get("path"))
            super().__init__(*a, **kw)

    monkeypatch.setattr(staging_mod, "MemoryRegion", Flaky)
    try:
        sock = wire.connect(stg.addr)
        items = [{"name": f"x{i}", "dtype": "uint8", "size": 1 << 20}
                 for i in range(5)]
        h, _ = wire.request(sock, {"op": "batch_open", "items": items})
        assert h["ok"] is False and "rolled back" in h["error"]
        stats, _ = wire.request(sock, {"op": "stats"})
        assert stats["mem_used"] == 0 and stats["queued"] == 0
        # a batch_write after the failed open is rejected but must not
        # desynchronize the stream (its payload is drained)
        wire.send_frame(sock, {"op": "batch_write", "count": 5},
                        b"z" * 64)
        h2, _ = wire.recv_frame(sock)
        assert h2["ok"] is False and "batch_open" in h2["error"]
        ping, _ = wire.request(sock, {"op": "ping"})
        assert ping["ok"] is True
        # and the server still accepts healthy batches afterwards
        monkeypatch.setattr(staging_mod, "MemoryRegion", real_region)
        h3, _ = wire.request(sock, {"op": "batch_open", "items": items[:2]})
        assert h3["ok"] is True and len(h3["items"]) == 2
        wire.send_frame(sock, {"op": "batch_write", "count": 2},
                        b"q" * (2 << 20))
        h4, _ = wire.recv_frame(sock)
        assert h4["ok"] is True and h4["count"] == 2
        sock.close()
    finally:
        stg.stop()
        sv.stop()


def test_batch_open_reservations_released_on_disconnect():
    """A client that dies between batch_open and batch_write must not
    leak its reservations: leaked bytes would permanently shrink every
    future credit grant (the stripe TTL reaper does not cover them)."""
    sv, stg = _stack(mem_capacity=1 << 24)
    try:
        sock = wire.connect(stg.addr)
        items = [{"name": f"d{i}", "dtype": "uint8", "size": 1 << 20}
                 for i in range(4)]
        h, _ = wire.request(sock, {"op": "batch_open", "items": items})
        assert h["ok"] and len(h["items"]) == 4
        sock.close()                       # vanish before batch_write
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            with stg._alloc_lock:
                used = stg._mem_used
            if used == 0:
                break
            time.sleep(0.02)
        assert used == 0, "abandoned batch reservations leaked"
        with stg._ds_lock:
            assert not stg._datasets
        # a second batch_open on one conn abandons the first unconsumed one
        sock = wire.connect(stg.addr)
        wire.request(sock, {"op": "batch_open", "items": items[:2]})
        wire.request(sock, {"op": "batch_open", "items": items[:1]})
        stats, _ = wire.request(sock, {"op": "stats"})
        assert stats["mem_used"] == 1 << 20   # only the live batch remains
        sock.close()
    finally:
        stg.stop()
        sv.stop()


def test_coalesced_small_datasets_land_with_content_parity():
    sv, stg = _stack()
    try:
        rng = np.random.default_rng(7)
        bufs = {f"tiny{i}": rng.standard_normal(1024) for i in range(24)}
        bufs["empty"] = np.zeros(0, dtype=np.float64)
        big = rng.standard_normal(1 << 18)       # 2 MiB: bypasses
        cfg = TransportConfig(staging_addr=stg.addr, wire_format="bin1",
                              coalesce_bytes=256 << 10, linger_ms=50,
                              block_size=1 << 20)
        with TransferSession("rdma_staged", cfg) as sess:
            for n, b in bufs.items():
                sess.write(n, b, dtype="float64")
            sess.write("big", big, dtype="float64")
            sess.sync()
            sess.drain()
        assert stg.stats["batches"] >= 1
        assert stg.stats["batched_datasets"] == len(bufs)
        assert stg.stats["datasets"] == len(bufs) + 1
        for n, b in bufs.items():
            assert np.array_equal(sv.engine.datasets[n], b), n
        assert np.array_equal(sv.engine.datasets["big"], big)
    finally:
        stg.stop()
        sv.stop()


def test_coalesce_zero_is_legacy_path():
    """coalesce_bytes=0 (default) must not even build a coalescer."""
    sv, stg = _stack()
    try:
        cfg = TransportConfig(staging_addr=stg.addr)
        t = create("rdma_staged", cfg)
        t.open()
        try:
            assert t.comm._coalescer is None
        finally:
            t.close()
    finally:
        stg.stop()
        sv.stop()


# ---------------------------------------------------------------------------
# proactive credit frames
# ---------------------------------------------------------------------------


class _CreditPushServer:
    """Stripe endpoint that pushes an unsolicited binary credit frame
    before acking (acks deliberately carry no credits)."""

    def __init__(self):
        self._srv = socket.socket()
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(8)
        self.addr = f"127.0.0.1:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        threading.Thread(target=self._accept, daemon=True).start()

    def _accept(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn):
        with conn:
            while True:
                try:
                    h, _ = wire.recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                try:
                    if h.get("op") == "hello":
                        wire.send_frame(conn, wire.hello_reply(h))
                    elif h.get("op") == "stripe_open":
                        wire.send_frame(conn, {"ok": True, "file_id": "f1",
                                               "credits": 2})
                    else:
                        wire.send_frame_bin(conn, {"op": "credit",
                                                   "credits": 7})
                        wire.send_frame(conn, {"ok": True,
                                               "stripe_idx":
                                                   h.get("stripe_idx"),
                                               "done": False, "dup": False})
                except OSError:
                    return

    def stop(self):
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass


def test_unsolicited_credit_frame_updates_window_without_eating_acks():
    srv = _CreditPushServer()
    group = ChannelGroup(srv.addr, n_channels=1, stripe_bytes=1 << 10,
                         credits=4, wire_format="bin1").open()
    try:
        assert group.wire_format == "bin1"
        group.send_dataset("w", "uint8", np.zeros(4 << 10, dtype=np.uint8),
                           timeout=20)
        stats = group.channel_stats()[0]
        # every stripe was acked (no credit frame consumed an ack slot)
        # and the pushed grant became the window
        assert stats["n_stripes"] == 4
        assert stats["window"] == 7
    finally:
        group.close()
        srv.stop()


def test_staging_pushes_credits_to_bin_channels():
    """A forward to SAVIME that releases staging memory proactively
    raises bin1 channel windows (credit_pushes > 0 on the server)."""
    sv, stg = _stack(mem_capacity=1 << 22)
    try:
        cfg = TransportConfig(staging_addr=stg.addr, wire_format="bin1",
                              n_channels=2, stripe_bytes=64 << 10,
                              block_size=64 << 10, credits=4)
        data = np.random.default_rng(0).standard_normal(1 << 16)
        with TransferSession("rdma_staged", cfg) as sess:
            for i in range(4):
                sess.write(f"p{i}", data, dtype="float64")
            sess.sync()
            sess.drain()
        assert stg.stats["credit_pushes"] > 0
    finally:
        stg.stop()
        sv.stop()


# ---------------------------------------------------------------------------
# baseline guard: the copy emulations never go binary
# ---------------------------------------------------------------------------


def test_channelgroup_with_custom_send_frame_never_negotiates_binary():
    def fake_send_frame(sock, header, payload=None):  # pragma: no cover
        wire.send_frame(sock, header, payload)

    g = ChannelGroup("127.0.0.1:1", n_channels=1,
                     send_frame=fake_send_frame, wire_format="bin1")
    assert g.wire_format == "json"       # pinned before any connection


@pytest.mark.parametrize("engine", ["scp_mem", "ssh_direct"])
def test_copy_emulation_transports_never_negotiate_binary(engine):
    """The scp/ssh engines are the paper's measured baselines: even when
    the config begs for bin1 + coalescing they must keep the JSON wire
    and their per-dataset copy cost model."""
    sv = SavimeServer().start()
    try:
        cfg = TransportConfig(savime_addr=sv.addr, wire_format="bin1",
                              coalesce_bytes=1 << 20, n_channels=2,
                              stripe_bytes=16 << 10, io_threads=1,
                              block_size=64 << 10)
        t = create(engine, cfg)
        t.open()
        try:
            assert t._group is not None
            assert t._group.wire_format == "json"
            data = np.random.default_rng(1).standard_normal(8192)
            t.write("guard", "float64", data).wait(30)
            t.sync(30)
            t.drain(30)
        finally:
            t.close()
        assert np.array_equal(sv.engine.datasets["guard"], data)
    finally:
        sv.stop()
