"""Paged staging store (DESIGN.md §11): page-table allocator, LRU spill
tier and content-addressed dedup — store-level lifecycles under memory
pressure, the paged variants of all four ingest protocols (block, striped,
batch, forward), credit derivation from available pages, and the
accounting fixes that ride along (locked stats snapshot, disk-tier
cleanup).
"""
import os
import time

import numpy as np
import pytest

from repro.core import SavimeServer, StagingServer
from repro.core import wire
from repro.core.pagestore import PageStore, PageStoreFull
from repro.core.rdma import PagedMemoryRegion, PagedRdmaWriter
from repro.transport import TransferSession, TransportConfig

pytestmark = pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnraisableExceptionWarning")

PAGE = 16 << 10


@pytest.fixture()
def savime():
    srv = SavimeServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def store(tmp_path):
    st = PageStore(capacity=16 * PAGE, page_bytes=PAGE,
                   mem_dir=str(tmp_path / "mem"),
                   spill_dir=str(tmp_path / "spill"), dedup=True)
    yield st
    st.close()


# ---------------------------------------------------------------------------
# store-level lifecycles
# ---------------------------------------------------------------------------


def test_alloc_write_read_roundtrip(store):
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, 3 * PAGE + 123, dtype=np.uint8)
    t = store.alloc(data.size)
    assert t.n_pages == 4
    store.write(t, 0, data)
    assert bytes(store.read(t)) == data.tobytes()
    # partial range across a page boundary
    assert bytes(store.read(t, PAGE - 7, 20)) == \
        data[PAGE - 7:PAGE + 13].tobytes()
    store.free(t)
    assert store.stats()["pages_free"] == store.n_frames


def test_spill_past_capacity_and_reaccess_byte_exact(store):
    rng = np.random.default_rng(1)
    tables = []
    # 8 tables x 4 pages = 2x the 16-frame store: sealed pages must spill
    for _ in range(8):
        buf = rng.integers(0, 256, 4 * PAGE, dtype=np.uint8)
        t = store.alloc(buf.size)
        store.write(t, 0, buf)
        store.seal(t)
        tables.append((t, buf))
    s = store.stats()
    assert s["spill_outs"] > 0 and s["pages_spilled"] > 0
    # every table round-trips byte-exact, pulling cold pages back in
    for t, buf in tables:
        assert bytes(store.read(t)) == buf.tobytes()
    assert store.stats()["spill_ins"] > 0
    for t, _ in tables:
        store.free(t)
    s = store.stats()
    assert s["pages_free"] == store.n_frames
    assert s["pages_spilled"] == 0 and s["spill_used"] == 0


def test_unsealed_pages_never_spill_overflow_raises(store):
    big = store.alloc(16 * PAGE)           # fills the store, unsealed
    with pytest.raises(PageStoreFull):
        store.alloc(PAGE)
    store.free(big)
    assert store.stats()["pages_free"] == store.n_frames


def test_pinned_pages_never_evicted(store):
    rng = np.random.default_rng(2)
    buf = rng.integers(0, 256, 4 * PAGE, dtype=np.uint8)
    t = store.alloc(buf.size)
    store.write(t, 0, buf)
    store.seal(t)
    store.pin(t)                            # forward in progress
    others = [store.alloc(4 * PAGE) for _ in range(3)]  # exhaust frames
    with pytest.raises(PageStoreFull):      # pinned + unsealed only
        store.alloc(PAGE)
    assert all(p.resident for p in t.pages)
    store.unpin(t)
    t2 = store.alloc(PAGE)                  # now evictable again
    assert store.stats()["spill_outs"] > 0
    for x in (t, t2, *others):
        store.free(x)


def test_dedup_refcount_survives_duplicate_release(store):
    rng = np.random.default_rng(3)
    buf = rng.integers(0, 256, 3 * PAGE + 100, dtype=np.uint8)
    a = store.alloc(buf.size)
    store.write(a, 0, buf)
    store.seal(a)
    b = store.alloc(buf.size)
    store.write(b, 0, buf)
    store.seal(b)                           # collapses onto a's pages
    s = store.stats()
    assert s["dedup_hits"] == 4
    assert s["dedup_saved_bytes"] == buf.size
    assert b.pages == a.pages
    store.free(b)                           # one duplicate released...
    assert bytes(store.read(a)) == buf.tobytes()   # ...survivor intact
    store.free(a)
    assert store.stats()["pages_free"] == store.n_frames


def test_dedup_spilled_then_freed_reclaims_spill_file(store):
    rng = np.random.default_rng(4)
    buf = rng.integers(0, 256, 2 * PAGE, dtype=np.uint8)
    t = store.alloc(buf.size)
    store.write(t, 0, buf)
    store.seal(t)
    # force t's pages cold by filling the store with fresh sealed data
    hot = []
    for _ in range(8):
        h = store.alloc(2 * PAGE)
        store.write(h, 0, rng.integers(0, 256, 2 * PAGE, dtype=np.uint8))
        store.seal(h)
        hot.append(h)
    assert store.stats()["pages_spilled"] > 0
    store.free(t)
    for h in hot:
        store.free(h)
    s = store.stats()
    assert s["pages_spilled"] == 0 and s["spill_used"] == 0


def test_paged_region_one_sided_writer_roundtrip(store):
    rng = np.random.default_rng(5)
    payload = rng.integers(0, 256, 2 * PAGE + 500, dtype=np.uint8)
    t = store.alloc(payload.size)
    reg = PagedMemoryRegion(store, t)
    grant = reg.register_block(0, payload.size)
    w = PagedRdmaWriter(reg.path, store.page_bytes, reg.frame_offsets(),
                        payload.size)
    # unaligned split exercises the offset -> frame translation
    w.write(0, payload[:PAGE + 99])
    w.write(PAGE + 99, payload[PAGE + 99:], grant["rkey"])
    w.close()
    assert bytes(reg.read()) == payload.tobytes()
    reg.seal()
    reg.pin()
    assert b"".join(bytes(v) for v in reg.page_views()) == payload.tobytes()
    reg.unpin()
    reg.close(unlink=True)


# ---------------------------------------------------------------------------
# paged staging end-to-end (all ingest protocols)
# ---------------------------------------------------------------------------


@pytest.fixture()
def paged_staging(savime):
    srv = StagingServer(savime.addr, mem_capacity=64 * PAGE,
                        page_bytes=PAGE, send_threads=2).start()
    yield srv
    srv.stop()


def _verify(savime, bufs):
    for n, b in bufs.items():
        got = np.frombuffer(savime.engine.datasets[n], dtype=np.float64)
        assert np.array_equal(got, b), n


def test_paged_block_path_roundtrip(savime, paged_staging):
    cfg = TransportConfig(staging_addr=paged_staging.addr, io_threads=2,
                          block_size=2 * PAGE, page_bytes=PAGE)
    rng = np.random.default_rng(6)
    bufs = {f"pb{i}": rng.standard_normal(10_000) for i in range(4)}
    with TransferSession("rdma_staged", cfg) as sess:
        for n, b in bufs.items():
            sess.write(n, b, dtype="float64")
        sess.sync()
        sess.drain()
    _verify(savime, bufs)
    assert sess.stats.pages["pages_total"] == 64
    assert sess.stats.pages["peak_mem_used"] > 0


def test_paged_striped_bin1_roundtrip(savime, paged_staging):
    cfg = TransportConfig(staging_addr=paged_staging.addr, n_channels=2,
                          stripe_bytes=int(1.5 * PAGE), wire_format="bin1",
                          page_bytes=PAGE)
    rng = np.random.default_rng(7)
    bufs = {f"ps{i}": rng.standard_normal(12_000) for i in range(4)}
    with TransferSession("rdma_staged", cfg) as sess:
        for n, b in bufs.items():
            sess.write(n, b, dtype="float64")
        sess.sync()
        sess.drain()
    _verify(savime, bufs)
    assert paged_staging.stats["stripes"] > 0


def test_paged_coalesced_batch_roundtrip(savime, paged_staging):
    cfg = TransportConfig(staging_addr=paged_staging.addr,
                          coalesce_bytes=1 << 20, page_bytes=PAGE)
    rng = np.random.default_rng(8)
    bufs = {f"pc{i}": rng.standard_normal(1500) for i in range(6)}
    with TransferSession("rdma_staged", cfg) as sess:
        for n, b in bufs.items():
            sess.write(n, b, dtype="float64")
        sess.sync()
        sess.drain()
    _verify(savime, bufs)
    assert paged_staging.stats["batches"] >= 1


def test_paged_empty_dataset_completes(savime, paged_staging):
    cfg = TransportConfig(staging_addr=paged_staging.addr, page_bytes=PAGE)
    with TransferSession("rdma_staged", cfg) as sess:
        fut = sess.write("pempty", np.empty(0, dtype=np.uint8))
        sess.sync()
        assert fut.done()
        sess.drain()
    assert savime.engine.datasets["pempty"].size == 0


# ---------------------------------------------------------------------------
# memory pressure: spill keeps a sustained over-capacity ingest flowing
# ---------------------------------------------------------------------------


def test_sustained_ingest_past_capacity_spills_and_completes(savime):
    """16 striped datasets against capacity for 4: a slow SAVIME hop
    builds a sealed backlog that must spill (never stall) — grants stay
    >= 1 by construction and the transfer completes byte-exact."""
    ds_bytes = 4 * PAGE
    staging = StagingServer(savime.addr, mem_capacity=4 * ds_bytes,
                            page_bytes=PAGE, send_threads=1).start()
    orig = savime.engine.load_dataset

    def slow_load(name, dtype, payload):
        time.sleep(0.05)                   # the slow analytical hop
        orig(name, dtype, payload)

    savime.engine.load_dataset = slow_load
    rng = np.random.default_rng(9)
    bufs = {f"press{i}": rng.standard_normal(ds_bytes // 8)
            for i in range(16)}
    cfg = TransportConfig(staging_addr=staging.addr, n_channels=2,
                          stripe_bytes=PAGE, credits=4, page_bytes=PAGE)
    try:
        with TransferSession("rdma_staged", cfg) as sess:
            for n, b in bufs.items():
                sess.write(n, b, dtype="float64")
            sess.sync(timeout=60)
            sess.drain(timeout=60)
            srv = sess.server_stats()
        _verify(savime, bufs)
        assert srv["pages"]["spill_outs"] > 0      # pressure really spilled
        assert srv["queued"] == 0
        assert srv["pages"]["mem_used"] == 0       # all frames returned
    finally:
        savime.engine.load_dataset = orig
        staging.stop()


def test_credit_grants_recover_after_gc_stale_stripes(savime):
    staging = StagingServer(savime.addr, mem_capacity=4 * PAGE,
                            page_bytes=PAGE, stripe_ttl=0.2).start()
    sock = wire.connect(staging.addr)
    try:
        # a client that reserves the whole store and dies silently
        h, _ = wire.request(sock, {"op": "stripe_open", "name": "dead",
                                   "dtype": "uint8", "size": 4 * PAGE,
                                   "n_stripes": 4, "credits": 8})
        assert h["ok"] and h["credits"] == 1       # store exhausted
        time.sleep(0.3)                            # age past the TTL
        # next stripe_open reaps the corpse; grants recover immediately
        h2, _ = wire.request(sock, {"op": "stripe_open", "name": "live",
                                    "dtype": "uint8", "size": PAGE,
                                    "n_stripes": 1, "credits": 8})
        assert h2["ok"] and h2["credits"] > 1
        assert staging.stats["stripe_aborts"] >= 1
    finally:
        sock.close()
        staging.stop()


# ---------------------------------------------------------------------------
# accounting fixes (stats snapshot, disk tier cleanup)
# ---------------------------------------------------------------------------


def test_stats_snapshot_keys_and_disk_fallback_cleanup(savime):
    # flat server sized so the dataset must take the disk tier
    staging = StagingServer(savime.addr, mem_capacity=1 << 10).start()
    cfg = TransportConfig(staging_addr=staging.addr)
    buf = np.random.default_rng(10).standard_normal(8_000)
    try:
        with TransferSession("rdma_staged", cfg) as sess:
            sess.write("diskfall", buf, dtype="float64")
            sess.sync()
            sess.drain()
            srv = sess.server_stats()
        assert srv["disk_fallbacks"] >= 1
        # the disk tier owns cleanup now: accounting returns to zero
        assert srv["disk_used"] == 0 and srv["mem_used"] == 0
        assert srv["queued"] == 0
        assert "pages" not in srv              # flat server: no page store
        got = np.frombuffer(savime.engine.datasets["diskfall"], np.float64)
        assert np.array_equal(got, buf)
    finally:
        staging.stop()


def test_paged_overflow_falls_back_to_disk_tier(savime):
    # store holds 4 pages; an unsealed 8-page dataset must overflow to
    # the flat disk tier and still round-trip
    staging = StagingServer(savime.addr, mem_capacity=4 * PAGE,
                            page_bytes=PAGE).start()
    cfg = TransportConfig(staging_addr=staging.addr, page_bytes=PAGE)
    buf = np.random.default_rng(11).standard_normal(PAGE)  # 8 pages worth
    try:
        with TransferSession("rdma_staged", cfg) as sess:
            sess.write("overflow", buf, dtype="float64")
            sess.sync()
            sess.drain()
            srv = sess.server_stats()
        assert srv["disk_fallbacks"] >= 1
        assert srv["disk_used"] == 0           # freed after forward
        got = np.frombuffer(savime.engine.datasets["overflow"], np.float64)
        assert np.array_equal(got, buf)
    finally:
        staging.stop()


def test_server_dirs_reaped_on_stop(savime):
    staging = StagingServer(savime.addr, mem_capacity=4 * PAGE,
                            page_bytes=PAGE).start()
    mem_dir, disk_dir = staging.mem_dir, staging.disk_dir
    staging.stop()
    assert not os.path.exists(mem_dir)
    assert not os.path.exists(disk_dir)
