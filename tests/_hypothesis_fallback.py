"""Minimal, deterministic stand-in for the hypothesis API surface used by
this repo's property tests.

The container image does not ship ``hypothesis`` (and nothing may be pip
installed); rather than skipping the whole property suite, tests fall back
to this shim: each ``@given`` test runs against a fixed number of examples
drawn from a seeded RNG, so the suite stays deterministic and meaningful.
Only the strategy combinators this repo uses are implemented
(``integers``, ``sampled_from``, ``lists``, ``composite``).
"""
from __future__ import annotations

import functools
import itertools

import numpy as np

__all__ = ["given", "settings", "st"]

MAX_EXAMPLES = 25
_SEED = 0xC0FFEE


class Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


class _St:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(seq) -> Strategy:
        seq = list(seq)
        return Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def lists(elem: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.example(rng) for _ in range(n)]
        return Strategy(draw)

    @staticmethod
    def composite(fn):
        @functools.wraps(fn)
        def make(*args, **kw):
            def draw_value(rng):
                return fn(lambda strat: strat.example(rng), *args, **kw)
            return Strategy(draw_value)
        return make


st = _St()


class settings:  # noqa: N801 — mirrors hypothesis' name
    """Profile management is a no-op in the fallback."""

    _profiles: dict = {}

    def __init__(self, *a, **kw):
        pass

    @classmethod
    def register_profile(cls, name, **kw):
        cls._profiles[name] = kw

    @classmethod
    def load_profile(cls, name):
        pass

    def __call__(self, fn):   # used as decorator: @settings(...)
        return fn


def given(*strategies, **kw_strategies):
    """Run the test body over MAX_EXAMPLES deterministic draws."""

    def deco(fn):
        def runner():
            rng = np.random.default_rng(_SEED)
            for i in itertools.islice(itertools.count(), MAX_EXAMPLES):
                args = [s.example(rng) for s in strategies]
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"fallback property test failed on example {i}: "
                        f"args={args!r} kwargs={kwargs!r}") from e
        # NB: no functools.wraps here — pytest must see a zero-arg
        # signature, not the strategy parameters (they are not fixtures)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
