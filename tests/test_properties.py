"""Hypothesis property tests on system invariants.

When ``hypothesis`` is unavailable (the container image does not ship it)
the tests run against the deterministic fallback in
``_hypothesis_fallback`` instead of being skipped.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, st

from repro.core.blocks import TransferCostModel, plan_blocks, vmem_tile
from repro.core.intransit import dequantize_int8_np, quantize_int8_np
from repro.core.tars import TAR, Attribute, Dimension

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# block planner
# ---------------------------------------------------------------------------


@given(nbytes=st.integers(0, 1 << 24), block=st.integers(1, 1 << 22))
def test_plan_blocks_covers_exactly(nbytes, block):
    plan = plan_blocks(nbytes, block)
    assert sum(sz for _, sz in plan) == nbytes
    # contiguous, disjoint, ordered (FCFS over offsets)
    pos = 0
    for off, sz in plan:
        assert off == pos and sz > 0
        pos += sz
    if nbytes:
        assert max(sz for _, sz in plan) <= block


@given(nbytes=st.integers(1, 1 << 30),
       b1=st.sampled_from([1 << 21, 1 << 23, 1 << 25]),
       b2=st.sampled_from([1 << 26, 1 << 27, 1 << 28]))
def test_cost_model_monotone_in_block_size(nbytes, b1, b2):
    """Paper claim C1: larger blocks never slower (per-block costs amortize)."""
    m = TransferCostModel()
    assert m.predict(nbytes, b2) <= m.predict(nbytes, b1) + 1e-12


@given(elems=st.integers(128, 1 << 22),
       itemsize=st.sampled_from([1, 2, 4]))
def test_vmem_tile_alignment(elems, itemsize):
    rows, lanes = vmem_tile(elems, itemsize)
    assert lanes == 128
    assert rows % max(32 // itemsize, 1) == 0      # sublane packing
    assert rows * lanes <= max(elems, rows * lanes)  # never zero-sized


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


@given(st.integers(1, 5000), st.integers(0, 2 ** 32 - 1))
def test_int8_quant_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32) * rng.uniform(0.01, 100)
    block = 256
    q, s = quantize_int8_np(x, block)
    back = dequantize_int8_np(q, s, x.shape, block)
    # per-block error bound: scale/2 = amax/254
    pad = (-n) % block
    xp = np.pad(x, (0, pad)).reshape(-1, block)
    bound = np.abs(xp).max(axis=1) / 127.0
    err = np.abs(np.pad(x, (0, pad)).reshape(-1, block)
                 - np.pad(back, (0, pad)).reshape(-1, block))
    assert (err <= bound[:, None] / 2 + 1e-7).all()


@given(st.integers(1, 2000))
def test_quant_zero_block_is_exact(n):
    x = np.zeros(n, np.float32)
    q, s = quantize_int8_np(x, 128)
    assert (dequantize_int8_np(q, s, x.shape, 128) == 0).all()


@given(n=st.integers(1, 1 << 14), block=st.integers(1, 4096),
       seed=st.integers(0, 2 ** 31 - 1))
def test_quant_roundtrip_any_size_any_block(n, block, seed):
    """Round trip holds for every (size, block) pairing: odd sizes, blocks
    larger than the input, and non-divisible quant_block all pad correctly
    and dequantize back to the original shape within the error bound."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    q, s = quantize_int8_np(x, block)
    pad = (-n) % block
    assert q.size == n + pad                       # block-padded flat stream
    assert s.size == (n + pad) // block            # one scale per block
    back = dequantize_int8_np(q, s, x.shape, block)
    assert back.shape == x.shape
    bound = np.repeat(s, block)[:n] / 2 + 1e-7
    assert (np.abs(back - x) <= bound).all()


@pytest.mark.parametrize("n,block", [
    (1, 4096),        # single element, giant block (all padding)
    (7, 8),           # odd size one short of the block
    (127, 64),        # odd size spanning two blocks
    (129, 64),        # one element into the third block
    (4095, 4096),     # default quant_block, one short
    (4097, 4096),     # default quant_block, one over
    (5000, 333),      # mutually indivisible
])
def test_quant_roundtrip_edge_sizes(n, block):
    rng = np.random.default_rng(n * 31 + block)
    x = (rng.standard_normal(n) * 10).astype(np.float32)
    q, s = quantize_int8_np(x, block)
    back = dequantize_int8_np(q, s, x.shape, block)
    assert back.shape == x.shape
    bound = np.repeat(s, block)[:n] / 2 + 1e-7
    assert (np.abs(back - x) <= bound).all()


@given(n=st.integers(1, 2048), block=st.integers(1, 512))
def test_quant_zero_and_constant_blocks_nondivisible(n, block):
    """All-zero input stays exactly zero for every block size (the zero
    scale is replaced by 1.0, so padding never produces NaN/Inf), and a
    constant input is recovered exactly (it sits on a quantization level)."""
    z = np.zeros(n, np.float32)
    q, s = quantize_int8_np(z, block)
    assert np.isfinite(s).all()
    assert (dequantize_int8_np(q, s, z.shape, block) == 0).all()
    c = np.full(n, 3.25, np.float32)
    q, s = quantize_int8_np(c, block)
    back = dequantize_int8_np(q, s, c.shape, block)
    assert np.allclose(back, c, rtol=1e-6, atol=0)


# ---------------------------------------------------------------------------
# TARS
# ---------------------------------------------------------------------------


@st.composite
def tar_and_boxes(draw):
    nd = draw(st.integers(1, 3))
    dims = [draw(st.integers(2, 12)) for _ in range(nd)]
    n_sub = draw(st.integers(1, 4))
    subs = []
    for _ in range(n_sub):
        origin = tuple(draw(st.integers(0, d - 1)) for d in dims)
        shape = tuple(draw(st.integers(1, d - o)) for d, o in zip(dims, origin))
        subs.append((origin, shape))
    qlo = tuple(draw(st.integers(0, d - 1)) for d in dims)
    qhi = tuple(draw(st.integers(l, d - 1)) for d, l in zip(dims, qlo))
    return dims, subs, qlo, qhi


@given(tar_and_boxes(), st.integers(0, 2 ** 31 - 1))
def test_tars_select_matches_numpy(data, seed):
    """select() over overlapping subtars == last-write-wins dense array."""
    dims, subs, qlo, qhi = data
    rng = np.random.default_rng(seed)
    t = TAR("t", [Dimension(f"d{i}", 0, n - 1) for i, n in enumerate(dims)],
            [Attribute("v", "float64")])
    dense = np.zeros(dims)
    for origin, shape in subs:
        data_arr = rng.standard_normal(shape)
        t.load_subtar(origin, shape, {"v": data_arr})
        sl = tuple(slice(o, o + s) for o, s in zip(origin, shape))
        dense[sl] = data_arr
    sel = t.select("v", qlo, qhi)
    qsl = tuple(slice(l, h + 1) for l, h in zip(qlo, qhi))
    assert np.array_equal(sel, dense[qsl])
    # aggregates consistent with select
    assert np.isclose(t.aggregate("v", "sum", qlo, qhi), dense[qsl].sum())


@given(st.integers(1, 50), st.integers(2, 40))
def test_dimension_mapping_roundtrip(i, stride):
    d = Dimension("x", 0, 100, offset=3.5, stride=float(stride))
    assert d.to_index(float(d.to_coord(i))) == i


# ---------------------------------------------------------------------------
# FCFS queue ordering
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 100), min_size=1, max_size=30))
def test_fcfs_single_thread_preserves_order(items):
    from repro.core.queues import FCFSPool
    out = []
    pool = FCFSPool(1, "t")
    hs = [pool.submit(out.append, i, name=str(i)) for i in items]
    pool.sync(10)
    pool.stop()
    assert out == items
