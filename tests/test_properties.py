"""Hypothesis property tests on system invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.blocks import TransferCostModel, plan_blocks, vmem_tile
from repro.core.intransit import dequantize_int8_np, quantize_int8_np
from repro.core.tars import TAR, Attribute, Dimension

settings.register_profile("ci", max_examples=50, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# block planner
# ---------------------------------------------------------------------------


@given(nbytes=st.integers(0, 1 << 24), block=st.integers(1, 1 << 22))
def test_plan_blocks_covers_exactly(nbytes, block):
    plan = plan_blocks(nbytes, block)
    assert sum(sz for _, sz in plan) == nbytes
    # contiguous, disjoint, ordered (FCFS over offsets)
    pos = 0
    for off, sz in plan:
        assert off == pos and sz > 0
        pos += sz
    if nbytes:
        assert max(sz for _, sz in plan) <= block


@given(nbytes=st.integers(1, 1 << 30),
       b1=st.sampled_from([1 << 21, 1 << 23, 1 << 25]),
       b2=st.sampled_from([1 << 26, 1 << 27, 1 << 28]))
def test_cost_model_monotone_in_block_size(nbytes, b1, b2):
    """Paper claim C1: larger blocks never slower (per-block costs amortize)."""
    m = TransferCostModel()
    assert m.predict(nbytes, b2) <= m.predict(nbytes, b1) + 1e-12


@given(elems=st.integers(128, 1 << 22),
       itemsize=st.sampled_from([1, 2, 4]))
def test_vmem_tile_alignment(elems, itemsize):
    rows, lanes = vmem_tile(elems, itemsize)
    assert lanes == 128
    assert rows % max(32 // itemsize, 1) == 0      # sublane packing
    assert rows * lanes <= max(elems, rows * lanes)  # never zero-sized


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


@given(st.integers(1, 5000), st.integers(0, 2 ** 32 - 1))
def test_int8_quant_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32) * rng.uniform(0.01, 100)
    block = 256
    q, s = quantize_int8_np(x, block)
    back = dequantize_int8_np(q, s, x.shape, block)
    # per-block error bound: scale/2 = amax/254
    pad = (-n) % block
    xp = np.pad(x, (0, pad)).reshape(-1, block)
    bound = np.abs(xp).max(axis=1) / 127.0
    err = np.abs(np.pad(x, (0, pad)).reshape(-1, block)
                 - np.pad(back, (0, pad)).reshape(-1, block))
    assert (err <= bound[:, None] / 2 + 1e-7).all()


@given(st.integers(1, 2000))
def test_quant_zero_block_is_exact(n):
    x = np.zeros(n, np.float32)
    q, s = quantize_int8_np(x, 128)
    assert (dequantize_int8_np(q, s, x.shape, 128) == 0).all()


# ---------------------------------------------------------------------------
# TARS
# ---------------------------------------------------------------------------


@st.composite
def tar_and_boxes(draw):
    nd = draw(st.integers(1, 3))
    dims = [draw(st.integers(2, 12)) for _ in range(nd)]
    n_sub = draw(st.integers(1, 4))
    subs = []
    for _ in range(n_sub):
        origin = tuple(draw(st.integers(0, d - 1)) for d in dims)
        shape = tuple(draw(st.integers(1, d - o)) for d, o in zip(dims, origin))
        subs.append((origin, shape))
    qlo = tuple(draw(st.integers(0, d - 1)) for d in dims)
    qhi = tuple(draw(st.integers(l, d - 1)) for d, l in zip(dims, qlo))
    return dims, subs, qlo, qhi


@given(tar_and_boxes(), st.integers(0, 2 ** 31 - 1))
def test_tars_select_matches_numpy(data, seed):
    """select() over overlapping subtars == last-write-wins dense array."""
    dims, subs, qlo, qhi = data
    rng = np.random.default_rng(seed)
    t = TAR("t", [Dimension(f"d{i}", 0, n - 1) for i, n in enumerate(dims)],
            [Attribute("v", "float64")])
    dense = np.zeros(dims)
    for origin, shape in subs:
        data_arr = rng.standard_normal(shape)
        t.load_subtar(origin, shape, {"v": data_arr})
        sl = tuple(slice(o, o + s) for o, s in zip(origin, shape))
        dense[sl] = data_arr
    sel = t.select("v", qlo, qhi)
    qsl = tuple(slice(l, h + 1) for l, h in zip(qlo, qhi))
    assert np.array_equal(sel, dense[qsl])
    # aggregates consistent with select
    assert np.isclose(t.aggregate("v", "sum", qlo, qhi), dense[qsl].sum())


@given(st.integers(1, 50), st.integers(2, 40))
def test_dimension_mapping_roundtrip(i, stride):
    d = Dimension("x", 0, 100, offset=3.5, stride=float(stride))
    assert d.to_index(float(d.to_coord(i))) == i


# ---------------------------------------------------------------------------
# FCFS queue ordering
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 100), min_size=1, max_size=30))
def test_fcfs_single_thread_preserves_order(items):
    from repro.core.queues import FCFSPool
    out = []
    pool = FCFSPool(1, "t")
    hs = [pool.submit(out.append, i, name=str(i)) for i in items]
    pool.sync(10)
    pool.stop()
    assert out == items
