"""Known-good: one global nesting order; RLock re-entry is fine."""
import threading


class Ordered:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def one(self):
        with self._a:
            with self._b:
                pass

    def two(self):
        with self._a:
            with self._b:
                pass


class Reentrant:
    def __init__(self):
        self._r = threading.RLock()

    def outer(self):
        with self._r:
            self.inner()           # fine: _r is reentrant

    def inner(self):
        with self._r:
            pass


class Annotated:
    def __init__(self):
        self._m = threading.Lock()
        self._n = 0

    def outer(self):
        with self._m:
            self._locked_helper()

    def _helper_also_locks(self):
        with self._m:          # called nowhere under _m: no self-edge
            pass

    def _locked_helper(self):  # holds: self._m
        self._n += 1           # runs under the caller's _m, acquires nothing
