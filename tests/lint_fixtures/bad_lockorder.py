"""Known-bad: inconsistent nesting order + non-reentrant re-acquire."""
import threading


class Inverted:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def forward(self):
        with self._a:
            with self._b:
                pass

    def backward(self):
        with self._b:
            with self._a:          # BAD: reverse of forward() -> cycle
                pass


class SelfDeadlock:
    def __init__(self):
        self._m = threading.Lock()

    def outer(self):
        with self._m:
            self.inner()           # BAD: inner re-acquires non-reentrant _m

    def inner(self):
        with self._m:
            pass
