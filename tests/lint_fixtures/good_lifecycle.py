"""Known-good: every thread joined from stop(), sockets closed/handed off."""
import socket
import threading


class Tidy:
    def __init__(self):
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()
        self._ts = []
        t = threading.Thread(target=self._run, daemon=True)
        self._ts.append(t)
        t.start()

    def _run(self):
        pass

    def stop(self):
        self._t.join(2.0)
        ts = list(self._ts)                # one level of local aliasing
        for t in ts:
            t.join(2.0)


def closes(addr):
    s = socket.create_connection(addr)
    try:
        s.sendall(b"x")
    finally:
        s.close()


def hands_off(addr, registry):
    s = socket.create_connection(addr)
    registry.adopt(s)                      # ownership transferred: not a leak
