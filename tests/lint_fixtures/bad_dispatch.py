"""Known-bad: handlers with reply-less paths; an untagged error reply."""


class EchoServer:
    def _handle(self, h):
        op = h.get("op")
        if op == "ping":
            return {"ok": True}
        # BAD: unknown ops fall off the end -> peer gets no reply

    def _op_get(self, h):
        if not h.get("key"):
            return                         # BAD: bare return replies None
        return {"ok": True, "value": 1}


def make_error(msg):
    return {"ok": False, "error": msg}     # BAD: no "code" tag
