"""Known-bad: threads nobody joins, a socket nobody closes."""
import socket
import threading


class Leaky:
    def __init__(self):
        self._t = threading.Thread(target=self._run, daemon=True)  # BAD
        self._t.start()

    def _run(self):
        pass

    def poke(self):
        threading.Thread(target=self._run, daemon=True).start()    # BAD


def leak(addr):
    s = socket.create_connection(addr)     # BAD: never closed, never handed off
    s.sendall(b"x")
    return True
