"""Known-bad: guarded attribute touched without its lock."""
import threading


class Counter:
    _GUARDED_BY = {"_count": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0

    def bump(self):
        self._count += 1          # BAD: _lock not held

    def peek(self):
        return self._count        # BAD: _lock not held


class Commented:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []          # guarded by: self._lock

    def drop(self):
        self._items.clear()       # BAD: _lock not held
