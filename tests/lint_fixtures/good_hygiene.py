"""Known-good: the clean versions, plus one deliberate suppression."""
import threading
import time


def fetch(sock, seen=None):
    if seen is None:
        seen = []
    try:
        return sock.recv(1)
    except OSError:
        return None


class Calm:
    def __init__(self):
        self._lock = threading.Lock()

    def nap(self):
        time.sleep(0.001)                  # fine: no lock held
        with self._lock:
            pass

    def chat(self, sock):
        # deliberate request/reply serialization on this connection
        with self._lock:  # lint: ignore[io-under-lock]
            sock.sendall(b"hi")
