"""Known-bad: every hygiene ban in one file."""
import threading
import time


def fetch(sock, seen=[]):                  # BAD: mutable default
    try:
        return sock.recv(1)
    except:                                # BAD: bare except
        return None


class Slow:
    def __init__(self):
        self._lock = threading.Lock()

    def nap(self):
        with self._lock:
            time.sleep(0.1)                # BAD: sleep under lock

    def chat(self, sock):
        with self._lock:
            sock.sendall(b"hi")            # BAD: blocking io under lock
