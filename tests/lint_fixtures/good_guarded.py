"""Known-good: every guarded access holds the lock (or declares holds)."""
import threading


class Counter:
    _GUARDED_BY = {"_count": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0           # __init__ is exempt: no concurrency yet

    def bump(self):
        with self._lock:
            self._count += 1

    def _bump_locked(self):  # holds: self._lock
        self._count += 1

    def value(self):
        with self._lock:
            return self._count
