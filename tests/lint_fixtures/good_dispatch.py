"""Known-good: every handler path replies or raises; errors carry codes."""


class EchoServer:
    def _handle(self, h):
        op = h.get("op")
        if op == "ping":
            return {"ok": True}
        raise ValueError(f"unknown op {op!r}")

    def _op_get(self, h):
        if not h.get("key"):
            return {"ok": False, "error": "missing key", "code": "bad_request"}
        return {"ok": True, "value": 1}


def make_error(msg):
    return {"ok": False, "error": msg, "code": "error"}
