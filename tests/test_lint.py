"""reprolint tests: every rule family fires on its known-bad fixture,
stays quiet on the known-good one, the suppression syntax works, the
baseline machinery grandfathers findings, and the linter runs clean on
its own package (and on all of src/ — the CI acceptance criterion).

Runtime half: the lock-order sanitizer detects a real inversion, stays
quiet on consistent orders, and composes with threading.Condition.
"""
import json
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.lint import Baseline, lint_paths, runtime

TESTS = Path(__file__).resolve().parent
FIXTURES = TESTS / "lint_fixtures"
SRC = TESTS.parent / "src"


def _rules(path, rules=None):
    return [f.rule for f in lint_paths([path], rules=rules)]


# -- guarded-by -----------------------------------------------------------

def test_guarded_by_fires_on_bad_fixture():
    rules = _rules(FIXTURES / "bad_guarded.py", rules={"guarded-by"})
    assert rules.count("guarded-by") == 3      # bump, peek, drop


def test_guarded_by_clean_on_good_fixture():
    assert _rules(FIXTURES / "good_guarded.py", rules={"guarded-by"}) == []


# -- lock-order -----------------------------------------------------------

def test_lock_order_cycle_fires_on_bad_fixture():
    found = lint_paths([FIXTURES / "bad_lockorder.py"], rules={"lock-order"})
    msgs = " ".join(f.message for f in found)
    assert [f.rule for f in found].count("lock-order") == 2
    assert "Inverted._a" in msgs and "Inverted._b" in msgs   # a<->b cycle
    assert "SelfDeadlock._m" in msgs                         # self-edge


def test_lock_order_clean_on_good_fixture():
    assert _rules(FIXTURES / "good_lockorder.py", rules={"lock-order"}) == []


# -- lifecycle ------------------------------------------------------------

def test_thread_join_fires_on_bad_fixture():
    rules = _rules(FIXTURES / "bad_lifecycle.py", rules={"thread-join"})
    assert rules.count("thread-join") == 2     # tracked-but-unjoined + detached


def test_socket_close_fires_on_bad_fixture():
    rules = _rules(FIXTURES / "bad_lifecycle.py", rules={"socket-close"})
    assert rules.count("socket-close") == 1


def test_lifecycle_clean_on_good_fixture():
    assert _rules(FIXTURES / "good_lifecycle.py",
                  rules={"thread-join", "socket-close"}) == []


# -- dispatch -------------------------------------------------------------

def test_dispatch_return_fires_on_bad_fixture():
    rules = _rules(FIXTURES / "bad_dispatch.py", rules={"dispatch-return"})
    assert rules.count("dispatch-return") == 2  # fall-off-end + bare return


def test_error_code_fires_on_bad_fixture():
    rules = _rules(FIXTURES / "bad_dispatch.py", rules={"error-code"})
    assert rules.count("error-code") == 1


def test_dispatch_clean_on_good_fixture():
    assert _rules(FIXTURES / "good_dispatch.py",
                  rules={"dispatch-return", "error-code"}) == []


# -- hygiene --------------------------------------------------------------

def test_hygiene_bans_fire_on_bad_fixture():
    rules = _rules(FIXTURES / "bad_hygiene.py")
    for expected in ("bare-except", "mutable-default", "sleep-under-lock",
                     "io-under-lock"):
        assert rules.count(expected) == 1, (expected, rules)


def test_hygiene_clean_on_good_fixture_with_suppression():
    # good_hygiene contains a real sendall-under-lock, suppressed on the
    # `with` line — proving the block-scope suppression syntax works
    assert _rules(FIXTURES / "good_hygiene.py") == []


def test_every_rule_family_has_a_firing_fixture():
    """ISSUE acceptance: >= 5 rule families, each provably firing."""
    fired = set()
    for bad in FIXTURES.glob("bad_*.py"):
        fired.update(_rules(bad))
    assert {"guarded-by", "lock-order", "thread-join", "socket-close",
            "dispatch-return", "error-code", "bare-except",
            "mutable-default", "sleep-under-lock",
            "io-under-lock"} <= fired


# -- baseline / CLI -------------------------------------------------------

def test_baseline_grandfathers_and_reports_stale(tmp_path):
    findings = lint_paths([FIXTURES / "bad_hygiene.py"])
    assert findings
    bl_path = tmp_path / "bl.json"
    Baseline.write(bl_path, findings)
    bl = Baseline.load(bl_path)
    new, old, stale = bl.split(findings)
    assert not new and len(old) == len(findings) and not stale
    # a baseline with an extra fingerprint reports it stale
    data = json.loads(bl_path.read_text())
    data["findings"].append(dict(data["findings"][0], fingerprint="ffffffff" * 2))
    bl_path.write_text(json.dumps(data))
    new, old, stale = Baseline.load(bl_path).split(findings)
    assert not new and len(stale) == 1


def test_cli_strict_exit_codes(tmp_path):
    env_path = str(SRC)
    base = [sys.executable, "-m", "repro.lint"]

    def run(*args):
        return subprocess.run(
            base + list(args), capture_output=True, text=True,
            env={"PYTHONPATH": env_path, "PATH": "/usr/bin:/bin:/usr/local/bin"},
            cwd=str(TESTS.parent))

    bad = str(FIXTURES / "bad_hygiene.py")
    r = run(bad, "--strict", "--no-baseline")
    assert r.returncode == 1 and "bare-except" in r.stdout
    r = run(str(FIXTURES / "good_hygiene.py"), "--strict", "--no-baseline")
    assert r.returncode == 0
    # --write-baseline then --strict with it: grandfathered, exit 0
    bl = tmp_path / "bl.json"
    r = run(bad, "--write-baseline", "--baseline", str(bl))
    assert r.returncode == 0
    r = run(bad, "--strict", "--baseline", str(bl))
    assert r.returncode == 0
    r = run(bad, "--strict", "--no-baseline", "--json")
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    assert payload["findings"] and all("fingerprint" in f
                                       for f in payload["findings"])


# -- self-checks ----------------------------------------------------------

def test_lint_clean_on_own_package():
    assert lint_paths([SRC / "repro" / "lint"]) == []


def test_lint_clean_on_whole_src_tree():
    """The ISSUE acceptance criterion: empty baseline over src/."""
    found = lint_paths([SRC])
    assert found == [], "\n".join(f.render() for f in found)


# -- runtime sanitizer ----------------------------------------------------

@pytest.fixture
def sanitizer():
    was = runtime.installed()
    runtime.install(force=True)
    saved = runtime.report()
    runtime.reset()
    try:
        yield runtime
    finally:
        runtime.reset()
        # restore edges observed before this test so a REPRO_LOCKCHECK=1
        # session keeps its cross-test order graph
        with runtime._state_lock:
            runtime._edges.update(saved.edges)
        if not was:
            runtime.uninstall()


def test_runtime_detects_inversion(sanitizer):
    lock_a = threading.Lock()
    lock_b = threading.Lock()

    def forward():
        with lock_a:
            with lock_b:
                pass

    t = threading.Thread(target=forward)
    t.start()
    t.join(5.0)
    with lock_b:
        with lock_a:               # reverse order: inversion
            pass
    inv = sanitizer.inversions()
    assert len(inv) == 1
    assert "test_lint.py" in inv[0]["first"]


def test_runtime_quiet_on_consistent_order(sanitizer):
    lock_a = threading.Lock()
    lock_b = threading.Lock()
    for _ in range(3):
        with lock_a:
            with lock_b:
                pass
    assert sanitizer.inversions() == []
    assert sanitizer.report().edges   # the a->b edge was recorded


def test_runtime_dedups_repeated_inversions(sanitizer):
    lock_a = threading.Lock()
    lock_b = threading.Lock()

    def forward():
        with lock_a:
            with lock_b:
                pass

    t = threading.Thread(target=forward)
    t.start()
    t.join(5.0)
    for _ in range(4):
        with lock_b:
            with lock_a:
                pass
    assert len(sanitizer.inversions()) == 1   # one report per lock pair


def test_runtime_condition_compat_with_plain_lock(sanitizer):
    """Condition(Lock()) must keep working: wait() releases through the
    checked proxy and the held-stack stays balanced."""
    cond = threading.Condition(threading.Lock())
    fired = []

    def waiter():
        with cond:
            while not fired:
                cond.wait(2.0)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cond:
        fired.append(1)
        cond.notify_all()
    t.join(5.0)
    assert not t.is_alive()
    assert sanitizer.inversions() == []
    assert runtime._held() == []              # balanced in this thread


def test_runtime_rlock_reentry_is_not_an_inversion(sanitizer):
    r = threading.RLock()
    with r:
        with r:
            pass
    assert sanitizer.inversions() == []
