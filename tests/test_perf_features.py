"""Correctness of the §Perf features: padded-MHA exactness, microbatch
equivalence, comm-saving remat, egress pack, lowp collectives."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model


def test_padded_mha_is_exact():
    """pad_heads_to runs attention in padded-MHA layout; logits identical."""
    base = dataclasses.replace(get_config("arctic-480b").smoke(),
                               compute_dtype="float32",
                               n_heads=6, n_kv_heads=2)
    padded = dataclasses.replace(base, pad_heads_to=8)
    m0, m1 = Model(base), Model(padded)
    params = m0.init(jax.random.PRNGKey(4))  # same param shapes
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 40), 0,
                              base.vocab_size)
    lg0, _ = m0.prefill(params, toks, rules={})
    lg1, _ = m1.prefill(params, toks, rules={})
    rel = float(jnp.max(jnp.abs(lg1 - lg0)) /
                (jnp.max(jnp.abs(lg0)) + 1e-9))
    assert rel < 1e-6, rel


def test_microbatch_equivalence():
    """microbatches=n produces the same update as a single full batch."""
    from repro.data import DataConfig, SyntheticLM, device_put_batch
    from repro.launch.mesh import make_debug_mesh
    from repro.train import TrainConfig, TrainSetup
    cfg = dataclasses.replace(get_config("granite-34b").smoke(),
                              compute_dtype="float32",
                              param_dtype="float32")
    model = Model(cfg)
    mesh = make_debug_mesh(1, 1)
    b = next(SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8, seed=3)).batches())
    outs = {}
    for n in (1, 4):
        ts = TrainSetup(model, mesh, TrainConfig(egress="none",
                                                 microbatches=n))
        st = ts.init_state(jax.random.PRNGKey(0))
        with jax.set_mesh(mesh):
            st2, m, _ = jax.jit(ts.step_fn())(
                st, device_put_batch(b, mesh, ts.rules))
        outs[n] = (float(m["loss"]),
                   jax.tree.map(np.asarray, st2["params"]))
    assert np.isclose(outs[1][0], outs[4][0], rtol=1e-6)
    worst = max(
        np.max(np.abs(a - c)) / (np.max(np.abs(a)) + 1e-9)
        for a, c in zip(jax.tree.leaves(outs[1][1]),
                        jax.tree.leaves(outs[4][1])))
    assert worst < 5e-5, worst


def test_comm_remat_same_loss_and_grads():
    """remat='comm' changes what is saved, not what is computed."""
    cfg = dataclasses.replace(get_config("qwen2-72b").smoke(),
                              compute_dtype="float32",
                              param_dtype="float32", n_layers=4,
                              remat="full")
    cfg2 = dataclasses.replace(cfg, remat="comm")
    m1, m2 = Model(cfg), Model(cfg2)
    params = m1.init(jax.random.PRNGKey(7))
    batch = {
        "tokens": jnp.ones((2, 32), jnp.int32),
        "targets": jnp.ones((2, 32), jnp.int32),
        "loss_mask": jnp.ones((2, 32), jnp.float32),
    }
    (l1, _), g1 = jax.value_and_grad(
        lambda p: m1.loss_fn(p, batch, {}), has_aux=True)(params)
    (l2, _), g2 = jax.value_and_grad(
        lambda p: m2.loss_fn(p, batch, {}), has_aux=True)(params)
    assert np.isclose(float(l1), float(l2), rtol=1e-6)
    worst = max(np.max(np.abs(np.asarray(a) - np.asarray(b)))
                for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert worst < 1e-4, worst


def test_lowp_collectives_context_numerics():
    """lowp emits compute-dtype dot outputs; fp32 compute is unchanged."""
    from repro.models import layers
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    y0 = layers.dense(x, w)
    with layers.lowp_collectives(True):
        y1 = layers.dense(x, w)
    assert bool(jnp.allclose(y0, y1))


def test_egress_pack_roundtrip_through_step():
    from repro.data import DataConfig, SyntheticLM, device_put_batch
    from repro.launch.mesh import make_debug_mesh
    from repro.train import TrainConfig, TrainSetup
    from repro.kernels.staging_pack import ref
    cfg = get_config("gemma3-4b").smoke()
    model = Model(cfg)
    mesh = make_debug_mesh(1, 1)
    ts = TrainSetup(model, mesh, TrainConfig(egress="grads_int8",
                                             egress_blocks=8))
    st = ts.init_state(jax.random.PRNGKey(0))
    b = next(SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4)).batches())
    with jax.set_mesh(mesh):
        _, _, egress = jax.jit(ts.step_fn())(
            st, device_put_batch(b, mesh, ts.rules))
    assert egress["blocks"].dtype == jnp.int8
    assert egress["blocks"].shape == (8, 1024)  # (egress_blocks, tile elems)
    deq = ref.unpack_blocks_ref(egress["blocks"], egress["scales"],
                                (64, 128), (8, 128))
    assert bool(jnp.isfinite(deq).all())
