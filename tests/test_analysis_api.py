"""Analysis-API tests: builder→mini-language golden strings, analyzer
registry round-trip and built-in correctness, AnalysisSession typed
results (direct and via the staging proxy), watch() under concurrent
ingest, the non-contiguous wire reply fix, the staging reservation
rollback, and server thread-hygiene soak checks."""
import threading

import numpy as np
import pytest

from repro import analysis
from repro.analysis import (AnalysisSession, Subscription, analyzers, tar)
from repro.analysis.query import (Aggregate, CreateTar, DropTar, LoadSubtar,
                                  Select, Window)
from repro.core import SavimeClient, SavimeServer, StagingServer
from repro.core.savime import SavimeEngine, SavimeError
from repro.core.tars import Attribute, Dimension
from repro.transport import TransferSession, TransportConfig


@pytest.fixture()
def savime():
    srv = SavimeServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def staging(savime):
    srv = StagingServer(savime.addr, mem_capacity=64 << 20,
                        send_threads=2).start()
    yield srv
    srv.stop()


# ---------------------------------------------------------------------------
# typed query layer: golden strings + engine round-trip
# ---------------------------------------------------------------------------


def test_builder_compiles_listing1_strings():
    ct = CreateTar("velocity", (Dimension("x", 0, 200),
                                Dimension("y", 0, 500),
                                Dimension("z", 0, 500)),
                   (Attribute("v", "float64"),))
    assert ct.compile() == \
        'create_tar(velocity, "x:0:200, y:0:500, z:0:500", "v:float64")'
    ls = LoadSubtar("velocity", "D", (0, 0, 0), (201, 501, 501), "v")
    assert ls.compile() == \
        'load_subtar(velocity, D, "0,0,0", "201,501,501", v)'
    sel = tar("velocity").attr("v").range((0, 0, 0), (10, 10, 10)).select()
    assert sel.compile() == 'select(velocity, v, "0,0,0", "10,10,10")'
    assert tar("velocity").attr("v").select().compile() == \
        "select(velocity, v)"
    assert tar("velocity").attr("v").mean().compile() == \
        "aggregate(velocity, v, mean)"
    bounded = tar("velocity").attr("v").range((0, 0, 0), (10, 10, 10)).max()
    assert bounded.compile() == \
        'aggregate(velocity, v, max, "0,0,0", "10,10,10")'
    assert DropTar("velocity").compile() == "drop_tar(velocity)"


def test_builder_dimension_mapping_function():
    ct = CreateTar("t", (Dimension("x", 0, 9, offset=1.5, stride=0.5),),
                   (Attribute("v", "float32"),))
    assert ct.compile() == 'create_tar(t, "x:0:9:1.5:0.5", "v:float32")'


def test_builder_validation():
    with pytest.raises(ValueError):
        tar("t").select()                       # missing .attr()
    with pytest.raises(ValueError):
        tar("t").attr("v").aggregate("median")  # unknown op
    with pytest.raises(ValueError):
        Select("t", "v", lo=(0, 0), hi=None)    # half-open box
    with pytest.raises(ValueError):
        Aggregate("t", "v", "mean", lo=(0,), hi=(1, 2))  # rank mismatch
    with pytest.raises(ValueError):
        LoadSubtar("t", "D", (0,), (1, 2), "v")
    with pytest.raises(ValueError):
        tar("t").attr("v").window(size=0)


def test_compiled_statements_roundtrip_through_engine():
    eng = SavimeEngine()
    eng.run(CreateTar("t", (Dimension("x", 0, 7),),
                      (Attribute("v", "float64"),)).compile())
    eng.load_dataset("D", "float64", np.arange(8.0).tobytes())
    eng.run(LoadSubtar("t", "D", (0,), (8,), "v").compile())
    out = eng.run(tar("t").attr("v").range((2,), (5,)).select().compile())
    np.testing.assert_array_equal(out, np.arange(2.0, 6.0))
    assert eng.run(tar("t").attr("v").sum().compile()) == 28.0


def test_window_statement_reduces_client_side():
    w = tar("t").attr("v").window(size=2, op="mean")
    assert w.compile() == "select(t, v)"       # no window op on the wire
    arr = np.arange(12.0).reshape(4, 3)        # 4 steps of 3 values
    out = w.finalize(arr)
    np.testing.assert_array_equal(out, arr[-2:].mean(axis=0))


# ---------------------------------------------------------------------------
# analyzer registry + built-ins
# ---------------------------------------------------------------------------


def test_analyzer_registry_roundtrip():
    names = analyzers.available()
    for expected in ("running_stats", "histogram", "window_reduce"):
        assert expected in names
    a = analyzers.create("histogram", bins=4)
    assert a.name == "histogram"
    assert analyzers.get("histogram") is type(a)


def test_analyzer_unknown_name_error():
    with pytest.raises(analysis.UnknownAnalyzerError) as ei:
        analyzers.create("crystal_ball")
    msg = str(ei.value)
    assert "crystal_ball" in msg and "running_stats" in msg


def test_analyzer_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        @analyzers.register_analyzer("running_stats")
        class Impostor(analyzers.Analyzer):  # pragma: no cover
            def _consume(self, arr): ...
            def summary(self): ...


def test_running_stats_matches_numpy():
    rng = np.random.default_rng(1)
    batches = [rng.standard_normal(100) for _ in range(3)]
    a = analyzers.create("running_stats")
    for b in batches:
        a.update(b)
    s = a.summary()
    allv = np.concatenate(batches)
    assert s.n_updates == 3 and s["count"] == allv.size
    assert np.isclose(s["mean"], allv.mean())
    assert np.isclose(s["std"], allv.std())
    assert np.isclose(s["min"], allv.min())
    assert np.isclose(s["max"], allv.max())


def test_histogram_rejects_half_specified_range():
    with pytest.raises(ValueError):
        analyzers.create("histogram", lo=0.0)        # hi missing
    with pytest.raises(ValueError):
        analyzers.create("histogram", lo=1.0, hi=1.0)  # empty range


def test_histogram_counts_everything():
    a = analyzers.create("histogram", bins=8, lo=0.0, hi=1.0)
    a.update(np.linspace(0, 1, 64))
    a.update(np.array([-5.0, 5.0]))            # out of range -> edge bins
    s = a.summary()
    assert s["total"] == 66 and sum(s["counts"]) == 66
    assert len(s["edges"]) == 9


def test_window_reduce_keeps_trailing_window():
    a = analyzers.create("window_reduce", window=3, op="mean", step_op="sum")
    for step in range(6):
        a.update(np.full(4, float(step)))      # per-step sum = 4*step
    s = a.summary()
    assert s["series"] == [12.0, 16.0, 20.0]   # steps 3, 4, 5
    assert np.isclose(s["value"], 16.0)


# ---------------------------------------------------------------------------
# AnalysisSession
# ---------------------------------------------------------------------------


def _load(savime, name, arr):
    cli = SavimeClient(savime.addr)
    cli.load_dataset(name, str(arr.dtype), arr.tobytes())
    cli.close()


def test_session_typed_results_and_stats(savime):
    v = np.arange(24.0).reshape(4, 6)
    with AnalysisSession(savime.addr) as an:
        an.execute(CreateTar("t", (Dimension("x", 0, 3),
                                   Dimension("y", 0, 5)),
                             (Attribute("v", "float64"),)))
        _load(savime, "D", v)
        an.execute(LoadSubtar("t", "D", (0, 0), (4, 6), "v"))
        res = an.execute(tar("t").attr("v").select())
        assert res.kind == "select"
        assert res.dtype == "float64" and res.shape == (4, 6)
        assert res.elapsed_s > 0
        np.testing.assert_array_equal(res.array, v)
        agg = an.execute(tar("t").attr("v").mean())
        assert agg.scalar == v.mean() and agg.shape is None
    assert an.stats.n_queries == 4
    assert an.stats.by_kind == {"createtar": 1, "loadsubtar": 1,
                                "select": 1, "aggregate": 1}
    assert an.stats.result_bytes == v.nbytes
    with pytest.raises(RuntimeError):          # closed
        an.execute(tar("t").attr("v").mean())


def test_session_requires_exactly_one_endpoint(savime):
    with pytest.raises(ValueError):
        AnalysisSession()
    with pytest.raises(ValueError):
        AnalysisSession(savime.addr, via=object())


def test_session_semantic_errors_do_not_retry(savime):
    with AnalysisSession(savime.addr, retries=2) as an:
        with pytest.raises(SavimeError):
            an.execute(tar("nope").attr("v").mean())
    assert an.stats.n_retries == 0


def test_session_via_transport_proxy(staging):
    cfg = TransportConfig(staging_addr=staging.addr, io_threads=1)
    with TransferSession("rdma_staged", cfg) as sess:
        an = sess.analysis()
        an.execute(CreateTar("p", (Dimension("i", 0, 63),),
                             (Attribute("v", "float64"),)))
        sess.write("P", np.full(64, 7.0))
        sess.sync()
        sess.drain()
        an.execute(LoadSubtar("p", "P", (0,), (64,), "v"))
        res = an.execute(tar("p").attr("v").max())
        assert res.value == 7.0
        with pytest.raises(RuntimeError):      # no push path behind proxy
            an.watch("p")


# ---------------------------------------------------------------------------
# live subscription (subscribe/notify)
# ---------------------------------------------------------------------------


def test_watch_delivers_events_during_concurrent_ingest(savime, staging):
    n = 3
    with AnalysisSession(savime.addr) as an:
        an.execute(CreateTar("w", (Dimension("step", 0, 100),
                                   Dimension("i", 0, 63)),
                             (Attribute("v", "float64"),)))
        sub = an.watch("w", timeout=10.0, max_events=n)
        done = threading.Event()

        def ingest():
            cfg = TransportConfig(staging_addr=staging.addr)
            with TransferSession("rdma_staged", cfg) as s:
                for i in range(n):
                    s.write(f"w{i}", np.full(64, float(i)))
                    s.sync()
                    s.drain()
                    s.run_savime(LoadSubtar("w", f"w{i}", (i, 0), (1, 64),
                                            "v"))
            done.set()

        t = threading.Thread(target=ingest)
        t.start()
        events = list(sub)
        t.join(timeout=10)
        assert done.is_set()
    assert [e.origin for e in events] == [(0, 0), (1, 0), (2, 0)]
    assert all(e.shape == (1, 64) and e.attr == "v" for e in events)
    assert [e.seq for e in events] == [1, 2, 3]
    assert events[0].hi == (0, 63)


def test_watch_name_filter_and_poll_timeout(savime):
    v = np.ones(8)
    with AnalysisSession(savime.addr) as an:
        for name in ("a_one", "b_two"):
            an.execute(CreateTar(name, (Dimension("i", 0, 7),),
                                 (Attribute("v", "float64"),)))
        with an.watch("a_*") as sub:           # prefix subscription
            assert sub.poll(0.05) is None      # nothing yet
            _load(savime, "da", v)
            _load(savime, "db", v)
            an.execute(LoadSubtar("b_two", "db", (0,), (8,), "v"))
            an.execute(LoadSubtar("a_one", "da", (0,), (8,), "v"))
            ev = sub.poll(5.0)
            assert ev is not None and ev.tar == "a_one"
            assert sub.poll(0.05) is None      # b_two was filtered out


def test_subscription_survives_unmatched_tar(savime):
    sub = Subscription(savime.addr, "never_loaded", timeout=0.1)
    assert list(sub) == []                     # timeout -> clean end
    sub.close()


def test_idle_subscriber_disconnect_releases_listener_and_thread(savime):
    import time
    for _ in range(3):
        sub = Subscription(savime.addr, "idle_tar")
        sub.close()                            # disconnect with no events
    deadline = time.monotonic() + 3.0
    while time.monotonic() < deadline and \
            (savime.engine._listeners or savime.live_threads()):
        time.sleep(0.05)
    assert savime.engine._listeners == []
    assert savime.live_threads() == 0


def test_only_idempotent_statements_marked_retryable():
    assert Select("t", "v").idempotent
    assert Aggregate("t", "v", "mean").idempotent
    assert tar("t").attr("v").window().idempotent
    assert DropTar("t").idempotent
    assert not CreateTar("t", (), ()).idempotent
    assert not LoadSubtar("t", "D", (0,), (1,), "v").idempotent


# ---------------------------------------------------------------------------
# satellite fixes
# ---------------------------------------------------------------------------


def test_noncontiguous_query_reply_over_wire(savime):
    base = np.arange(64.0).reshape(8, 8)
    # range-filter ops can hand back strided views; emulate one directly
    savime.engine._q_strided = lambda: base[::2, ::2]
    cli = SavimeClient(savime.addr)
    out = cli.run("strided()")
    np.testing.assert_array_equal(out, base[::2, ::2])
    cli.close()


def test_write_req_reservation_rolls_back_on_failure(staging, monkeypatch):
    import repro.core.staging as stg

    def boom(path, nbytes, create=True):
        raise OSError("mmap failed")

    monkeypatch.setattr(stg, "MemoryRegion", boom)
    before = staging._mem_used
    with pytest.raises(OSError):
        staging._op_write_req({"size": 4096, "name": "x"})
    assert staging._mem_used == before
    assert not staging._datasets


def test_server_threads_stay_bounded_over_many_connections(savime, staging):
    for i in range(40):
        cli = SavimeClient(savime.addr)
        assert cli.run("list_tars()") == ""
        cli.close()
        import repro.core.wire as wire
        s = wire.connect(staging.addr)
        wire.request(s, {"op": "ping"})
        s.close()
    # one more accept triggers pruning of the finished 40
    cli = SavimeClient(savime.addr)
    cli.run("list_tars()")
    s = __import__("repro.core.wire", fromlist=["connect"]).connect(
        staging.addr)
    assert len(savime._threads) < 10
    assert len(staging._threads) < 10
    cli.close()
    s.close()


def test_server_stop_joins_connection_threads(savime):
    clis = [SavimeClient(savime.addr) for _ in range(4)]
    for c in clis:
        c.run("list_tars()")
    savime.stop()
    assert savime.live_threads() == 0
    for c in clis:
        c.close()
