"""The paper's end-to-end scenario: a running simulation streams per-step
fields through staging into SAVIME while an ANALYTICAL CLIENT concurrently
consumes them — analysis in transit, no files, no post-processing.

The analyst is *event-driven*: instead of polling with repeated full
queries, it holds a live ``watch()`` subscription and reacts to each
subtar-arrival event with a range query scoped to exactly the step that
landed, feeding a registered ``window_reduce`` analyzer.

    PYTHONPATH=src python examples/simulation_intransit.py
"""
import threading
import time

import numpy as np

from repro.analysis import AnalysisSession, analyzers, tar
from repro.core import (InTransitConfig, InTransitSink, SavimeServer,
                        StagingServer)
from repro.data import SeismicConfig, SeismicField

N_STEPS = 12

savime = SavimeServer().start()
staging = StagingServer(savime.addr, mem_capacity=2 << 30,
                        send_threads=2).start()
# the sink rides the pluggable transport API; swap transport="scp_mem"
# (and pass savime.addr) to demo the paper's baseline path instead
sink = InTransitSink(staging.addr,
                     InTransitConfig(io_threads=2, tar_prefix="sim",
                                     transport="rdma_staged",
                                     max_inflight_bytes=256 << 20))

analysis_rows = []
stop = threading.Event()


def analyst():
    """Concurrent analytical app: wavefront energy per step, driven by
    subtar-arrival events rather than polling."""
    with AnalysisSession(savime.addr) as an:
        energy_window = analyzers.create("window_reduce", window=4,
                                         op="mean", step_op="sum")
        with an.watch("sim_velocity") as sub:
            while not stop.is_set():
                ev = sub.poll(0.1)
                if ev is None:
                    continue
                step = ev.origin[0]
                box = an.execute(tar("sim_velocity").attr("v")
                                 .range(ev.origin, ev.hi).select())
                sq = box.array.astype(np.float64) ** 2
                energy_window.update(sq)
                analysis_rows.append((step, float(sq.sum())))
                print(f"  [analysis] step {step}: field energy "
                      f"{analysis_rows[-1][1]:10.1f} (4-step mean "
                      f"{energy_window.summary()['value']:10.1f})")


t = threading.Thread(target=analyst, daemon=True)
t.start()

sim = SeismicField(SeismicConfig(nx=31, ny=64, nz=64))
t0 = time.perf_counter()
for step, field in sim.trial(N_STEPS):
    # the simulation never blocks on analysis:
    sink.stage_array("velocity", field.astype(np.float32), step=step)
    sink.flush(timeout=30)      # make it visible promptly for the demo
    print(f"[sim] step {step} produced + staged "
          f"({field.nbytes / 1e6:.1f} MB)")
time.sleep(0.3)                 # let the last events drain to the analyst
stop.set()
t.join(timeout=2)

dt = time.perf_counter() - t0
# completeness: every staged step is queryable at the end
with AnalysisSession(savime.addr) as an:
    final = an.execute(tar("sim_velocity").attr("v").select())
print(f"\n{N_STEPS} steps, {sink.staged_bytes / 1e6:.1f} MB staged "
      f"in {dt:.2f}s ({sink.staged_bytes / dt / 1e6:.0f} MB/s); "
      f"analysis observed {len(analysis_rows)} arrival events live; "
      f"SAVIME holds {final.shape[0]} steps")
assert final.shape[0] == N_STEPS
assert len(analysis_rows) >= 1  # concurrency demonstrated (pacing-dependent)
sink.close()
staging.stop()
savime.stop()
print("OK")
