"""The paper's end-to-end scenario: a running simulation streams per-step
fields through staging into SAVIME while an ANALYTICAL CLIENT concurrently
queries past steps — analysis in transit, no files, no post-processing.

    PYTHONPATH=src python examples/simulation_intransit.py
"""
import threading
import time

import numpy as np

from repro.core import (InTransitConfig, InTransitSink, SavimeClient,
                        SavimeServer, StagingServer)
from repro.data import SeismicConfig, SeismicField

N_STEPS = 12

savime = SavimeServer().start()
staging = StagingServer(savime.addr, mem_capacity=2 << 30,
                        send_threads=2).start()
# the sink rides the pluggable transport API; swap transport="scp_mem"
# (and pass savime.addr) to demo the paper's baseline path instead
sink = InTransitSink(staging.addr,
                     InTransitConfig(io_threads=2, tar_prefix="sim",
                                     transport="rdma_staged",
                                     max_inflight_bytes=256 << 20))

analysis_rows = []
stop = threading.Event()


def analyst():
    """Concurrent analytical app: tracks wavefront energy per step."""
    cli = SavimeClient(savime.addr)
    seen = -1
    while not stop.is_set():
        try:
            box = cli.run("select(sim_velocity, v)")
        except Exception:
            time.sleep(0.1)
            continue
        if box.size and box.shape[0] - 1 > seen:
            seen = box.shape[0] - 1
            energy = float((box[seen] ** 2).sum())
            analysis_rows.append((seen, energy))
            print(f"  [analysis] step {seen}: field energy {energy:10.1f}")
        time.sleep(0.1)


t = threading.Thread(target=analyst, daemon=True)
t.start()

sim = SeismicField(SeismicConfig(nx=31, ny=64, nz=64))
t0 = time.perf_counter()
for step, field in sim.trial(N_STEPS):
    # the simulation never blocks on analysis:
    sink.stage_array("velocity", field.astype(np.float32), step=step)
    sink.flush(timeout=30)      # make it visible promptly for the demo
    print(f"[sim] step {step} produced + staged "
          f"({field.nbytes / 1e6:.1f} MB)")
stop.set()
t.join(timeout=2)

dt = time.perf_counter() - t0
# completeness: every staged step is queryable at the end
cli = SavimeClient(savime.addr)
final = cli.run("select(sim_velocity, v)")
print(f"\n{N_STEPS} steps, {sink.staged_bytes / 1e6:.1f} MB staged "
      f"in {dt:.2f}s ({sink.staged_bytes / dt / 1e6:.0f} MB/s); "
      f"analysis observed {len(analysis_rows)} steps concurrently; "
      f"SAVIME holds {final.shape[0]} steps")
assert final.shape[0] == N_STEPS
assert len(analysis_rows) >= 1  # concurrency demonstrated (pacing-dependent)
sink.close()
staging.stop()
savime.stop()
print("OK")
