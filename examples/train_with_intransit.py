"""End-to-end training driver with the paper's technique as a first-class
feature: a real model (reduced gemma2 family; swap --arch/--mesh for the
production config on hardware) trains for a few hundred steps while

  * per-step diagnostics (loss, grad-norm) and int8-packed gradient blocks
    flow through libstaging -> tmpfs -> SAVIME (asynchronously),
  * checkpoints are written asynchronously (and staged for analysis),
  * one step failure is INJECTED and recovered from the last checkpoint.

    PYTHONPATH=src python examples/train_with_intransit.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.analysis import AnalysisSession, tar
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import (InTransitConfig, InTransitSink, SavimeServer,
                        StagingServer)
from repro.data import DataConfig, SyntheticLM, device_put_batch
from repro.launch.mesh import make_debug_mesh
from repro.models import Model
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import TrainConfig, TrainSetup

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="gemma2-27b")
args = ap.parse_args()

cfg = get_config(args.arch).smoke()
model = Model(cfg)
mesh = make_debug_mesh(1, 1)
print(f"[setup] {cfg.name}: {cfg.param_count() / 1e6:.2f}M params")

savime = SavimeServer().start()
staging = StagingServer(savime.addr).start()
sink = InTransitSink(staging.addr,
                     InTransitConfig(io_threads=2, tar_prefix="train",
                                     transport="rdma_staged",
                                     max_inflight_bytes=512 << 20))

setup = TrainSetup(model, mesh, TrainConfig(
    peak_lr=5e-3, warmup_steps=20, total_steps=args.steps,
    egress="grads_int8", egress_blocks=16))
state = setup.init_state(jax.random.PRNGKey(0))
import tempfile
ckpt = CheckpointManager(tempfile.mkdtemp(prefix="repro-example-ckpt-"),
                         sink=None)

step_jit = jax.jit(setup.step_fn(), donate_argnums=(0,))
dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
raw = SyntheticLM(dc).batches()


def wrapped_step(state, batch):
    state, metrics, egress = step_jit(state, batch)
    step = int(jax.device_get(state["step"]))
    # in-transit egress: never blocks the hot loop
    sink.stage_array("diag", np.asarray(egress["diag"]), step=step)
    if "blocks" in egress:
        sink.stage_array("grad_blocks", np.asarray(egress["blocks"]),
                         step=step)
    return state, metrics, egress


def batches():
    for b in raw:
        yield device_put_batch(b, mesh, setup.rules)


sup = Supervisor(wrapped_step, ckpt, SupervisorConfig(ckpt_every=50))
t0 = time.perf_counter()
with jax.set_mesh(mesh):
    state = sup.run(state, batches(), args.steps,
                    abstract_state=setup.abstract_state(),
                    shardings=setup.state_shardings(),
                    fail_at={args.steps // 2})   # injected failure
dt = time.perf_counter() - t0

losses = [m["loss"] for m in sup.metrics_log if "loss" in m]
print(f"[train] {args.steps} steps in {dt:.1f}s; "
      f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
      f"restarts={sup.restarts}")
assert losses[-1] < losses[0]
assert sup.restarts == 1

sink.flush()
with AnalysisSession(savime.addr) as an:
    diag = an.execute(tar("train_diag").attr("v").select()).array
print(f"[analysis] SAVIME holds {diag.shape[0]} step diagnostics; "
      f"last staged loss={diag[-1, 0]:.3f}")
sink.close()
staging.stop()
savime.stop()
print("OK")
