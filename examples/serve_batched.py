"""Batched serving example: prefill a batch of prompts, decode tokens with a
donated KV cache, greedy sampling — the inference path the decode_* dry-run
shapes lower (reduced config on CPU; --mesh single/multi on hardware).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import Model
from repro.train import ServeSetup

cfg = get_config("qwen2-72b").smoke()
model = Model(cfg)
mesh = make_debug_mesh(1, 1)
setup = ServeSetup(model, mesh, global_batch=4)

params = model.init(jax.random.PRNGKey(0))
B, S, N_NEW = 4, 48, 16
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)

prefill = jax.jit(setup.prefill_fn(max_len=S + N_NEW))
decode = jax.jit(setup.decode_fn(), donate_argnums=(1,))

with jax.set_mesh(mesh):
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits, -1)[:, None]
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(N_NEW - 1):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, cache = decode(params, cache, {"tokens": tok, "pos": pos})
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

gen = jnp.concatenate(out, axis=1)
print(f"prefill {B}x{S} in {t_prefill * 1e3:.0f} ms; "
      f"{N_NEW - 1} decode steps in {t_decode * 1e3:.0f} ms "
      f"({t_decode / (N_NEW - 1) * 1e3:.1f} ms/tok incl. dispatch)")
print("generated token ids (batch 0):", gen[0].tolist())
assert gen.shape == (B, N_NEW)
assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab_size))
print("OK")
