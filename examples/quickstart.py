"""Quickstart: the paper's Listing 1 plus the typed analysis API.

Starts an in-memory SAVIME and a staging server, ships a 3-D velocity
field through the RDMA-emulated staging path via a TransferSession, and
reads it back through an AnalysisSession: a live ``watch()`` subscription
sees the subtar land while the writer runs, then typed builder queries
and a registered analyzer summarize the field.

    PYTHONPATH=src python examples/quickstart.py [--smoke]

``--smoke`` shrinks the array for CI (loopback, ~1 MB).
"""
import argparse

import numpy as np

from repro.analysis import AnalysisSession, CreateTar, LoadSubtar, analyzers, tar
from repro.core import SavimeServer, StagingServer
from repro.core.tars import Attribute, Dimension
from repro.transport import TransferSession, TransportConfig

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true", help="tiny arrays for CI")
args = ap.parse_args()
nx, ny = (41, 36) if args.smoke else (201, 126)

savime = SavimeServer().start()
staging = StagingServer(savime.addr, mem_capacity=1 << 30).start()

cfg = TransportConfig(staging_addr=staging.addr, io_threads=1,
                      block_size=4 << 20)
with TransferSession("rdma_staged", cfg) as st, \
        AnalysisSession(savime.addr) as an:
    # --- the paper's Listing 1, typed ----------------------------------
    an.execute(CreateTar("velocity",
                         (Dimension("x", 0, nx - 1),
                          Dimension("y", 0, ny - 1),
                          Dimension("z", 0, ny - 1)),
                         (Attribute("v", "float64"),)))
    with an.watch("velocity", timeout=15.0, max_events=1) as sub:
        v = np.random.default_rng(0).standard_normal((nx, ny, ny))
        fut = st.write("D", v)           # asynchronous: returns a future
        st.sync()                        # writes reached staging
        st.drain()                       # staging -> SAVIME done
        assert fut.done()
        an.execute(LoadSubtar("velocity", "D", (0, 0, 0), (nx, ny, ny), "v"))
        events = list(sub)               # the subscription saw it arrive
        assert len(events) == 1 and events[0].tar == "velocity"
        print(f"watch: subtar {events[0].origin}+{events[0].shape} "
              f"arrived (seq {events[0].seq})")
    # --- typed queries (fluent builder -> compiled in one place) -------
    mean = an.execute(tar("velocity").attr("v").mean())
    corner = an.execute(
        tar("velocity").attr("v").range((0, 0, 0), (10, 10, 10)).max())
    print(f"mean(v) via SAVIME = {mean.value:.6f}   (numpy: {v.mean():.6f})")
    print(f"max over [0:10]^3  = {corner.value:.6f} "
          f"(numpy: {v[:11, :11, :11].max():.6f})")
    assert np.isclose(mean.value, v.mean())
    assert np.isclose(corner.value, v[:11, :11, :11].max())
    # --- a registered analyzer over a typed result ---------------------
    rs = analyzers.create("running_stats")
    rs.update(an.execute(tar("velocity").attr("v").select()))
    s = rs.summary()
    print(f"analyzer[{s.analyzer}]: mean={s['mean']:.4f} std={s['std']:.4f} "
          f"count={s['count']}")
    assert s["count"] == v.size
    print("server:", {k: x for k, x in st.server_stats().items()
                      if k in ("datasets", "bytes_in", "registrations")})

print(f"egress: {st.stats.nbytes / 1e6:.1f} MB in "
      f"{st.stats.to_staging_s:.3f}s to staging "
      f"({st.stats.staging_gbps:.2f} GB/s)")
print(f"analysis: {an.stats.n_queries} queries, "
      f"mean {an.stats.mean_query_s * 1e3:.2f} ms, kinds {an.stats.by_kind}")
staging.stop()
savime.stop()

# --- the same pipeline against a 3-server staging pool (DESIGN.md §12) ---
# One gateway address fronts N (staging, SAVIME) pairs: datasets place
# onto backends by consistent hash, and a RouterSession answers one
# query over the sharded tar exactly as a single server would.
from repro.gateway import RouterSession, StagingPool  # noqa: E402

width = ny * ny
parts = {f"slab{i}": np.random.default_rng(i).standard_normal(width)
         for i in range(6)}
with StagingPool(3, mem_capacity=1 << 30) as pool:
    cfg = TransportConfig(gateway_addr=pool.addr)
    with TransferSession("rdma_staged", cfg) as st:
        st.run_savime(f'create_tar(field, "x:0:{6 * width - 1}", '
                      f'"v:float64")')
        for name, arr in parts.items():
            st.write(name, arr)
        st.sync()
        st.drain()
        for i, name in enumerate(parts):
            st.run_savime(f'load_subtar(field, {name}, "{width * i}", '
                          f'"{width}", v)')
        with RouterSession(gateway_addr=pool.addr) as router:
            total = router.execute(tar("field").attr("v").sum())
    expect = float(np.sum(np.concatenate(list(parts.values()))))
    assert total.value == expect, (total.value, expect)
    print(f"pool: {len(parts)} datasets sharded over 3 backends; "
          f"sum(v) = {total.value:.6f} (numpy: {expect:.6f})")
    gw = st.stats.gateway
    print(f"gateway: {gw['totals']} across {gw['live_backends']} backends")
print("OK")
