"""Quickstart: the paper's Listing 1 in 30 lines, on the transport API.

Starts an in-memory SAVIME, a staging server, ships a 3-D velocity field
through the RDMA-emulated staging path via a TransferSession, and queries
it back.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SavimeServer, StagingServer
from repro.transport import TransferSession, TransportConfig

savime = SavimeServer().start()
staging = StagingServer(savime.addr, mem_capacity=1 << 30).start()

# --- the paper's Listing 1, one session per compute job --------------------
cfg = TransportConfig(staging_addr=staging.addr, io_threads=1,
                      block_size=16 << 20)
with TransferSession("rdma_staged", cfg) as st:
    st.run_savime('create_tar(velocity, "x:0:200, y:0:125, z:0:125", '
                  '"v:float64")')
    v = np.random.default_rng(0).standard_normal((201, 126, 126))
    fut = st.write("D", v)           # asynchronous: returns a future
    st.sync()                        # block until writes reached staging
    st.drain()                       # (benchmark hook: staging -> SAVIME done)
    assert fut.done()
    st.run_savime('load_subtar(velocity, D, "0,0,0", "201,126,126", v)')
    # -----------------------------------------------------------------------

    mean = st.run_savime("aggregate(velocity, v, mean)")
    corner = st.run_savime('aggregate(velocity, v, max, "0,0,0", "10,10,10")')
    print(f"mean(v) via SAVIME = {mean:.6f}   (numpy: {v.mean():.6f})")
    print(f"max over [0:10]^3  = {corner:.6f} "
          f"(numpy: {v[:11, :11, :11].max():.6f})")
    assert np.isclose(mean, v.mean())
    print("server:", {k: s for k, s in st.server_stats().items()
                      if k in ("datasets", "bytes_in", "registrations")})

print(f"session: {st.stats.nbytes / 1e6:.1f} MB in "
      f"{st.stats.to_staging_s:.3f}s to staging "
      f"({st.stats.staging_gbps:.2f} GB/s)")
staging.stop()
savime.stop()
print("OK")
