"""Quickstart: the paper's Listing 1 in 30 lines.

Starts an in-memory SAVIME, a staging server, ships a 3-D velocity field
through the RDMA-emulated staging path, and queries it back.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Dataset, SavimeServer, StagingClient, StagingServer)

savime = SavimeServer().start()
staging = StagingServer(savime.addr, mem_capacity=1 << 30).start()

# --- the paper's Listing 1 -------------------------------------------------
st = StagingClient(staging.addr, io_threads=1, block_size=16 << 20)
st.run_savime('create_tar(velocity, "x:0:200, y:0:125, z:0:125", "v:float64")')

v = np.random.default_rng(0).standard_normal((201, 126, 126))
ds = Dataset("D", "float64", st)
ds.write(v)                      # asynchronous: returns immediately
st.sync()                        # block until writes reached staging
st.drain()                       # (benchmark hook: staging -> SAVIME done)
st.run_savime('load_subtar(velocity, D, "0,0,0", "201,126,126", v)')
# ---------------------------------------------------------------------------

mean = st.run_savime("aggregate(velocity, v, mean)")
corner = st.run_savime('aggregate(velocity, v, max, "0,0,0", "10,10,10")')
print(f"mean(v) via SAVIME = {mean:.6f}   (numpy: {v.mean():.6f})")
print(f"max over [0:10]^3  = {corner:.6f} (numpy: {v[:11, :11, :11].max():.6f})")
assert np.isclose(mean, v.mean())

print("stats:", {k: s for k, s in st.stats().items()
                 if k in ("datasets", "bytes_in", "registrations")})
st.close()
staging.stop()
savime.stop()
print("OK")
