# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale-ish sizes (slower)")
    args = ap.parse_args()

    from benchmarks import (fig3_blocksize, fig4_threads, fig5_scaling,
                            fig6_baselines, fig7_query_latency,
                            fig8_striping, fig9_coalesce, fig11_gateway,
                            fig12_codecs, roofline)

    print("name,us_per_call,derived")
    if args.full:
        fig3_blocksize.run(n_clients=5, n_files=16, file_mb=8, trials=5)
        fig4_threads.run(trials=5)
        fig5_scaling.run(sizes_mb=(32, 64, 128, 256), trials=5)
        fig6_baselines.run(n_files=16, file_mb=8, trials=5)
        fig7_query_latency.run(trials=8)
        fig8_striping.run(n_files=2, file_mb=32, trials=5)
        fig9_coalesce.run(ds_kb=(16, 64, 256, 1024, 4096, 16384), trials=7,
                          budget_mb=128)
        fig11_gateway.run(n_backends=4, n_datasets=24, ds_kb=1024, trials=5)
        fig12_codecs.run(n_versions=8, ds_kbs=(64, 256, 1024, 4096),
                         trials=5)
    else:
        fig3_blocksize.run(n_clients=2, n_files=4, file_mb=4, trials=3,
                           blocks_kb=(16, 64, 256, 1024, 4096, 16384))
        fig4_threads.run(trials=3)
        fig5_scaling.run(sizes_mb=(8, 16, 32, 64), trials=3)
        fig6_baselines.run(n_files=8, file_mb=4, trials=3)
        fig7_query_latency.run(blocks_kb=(1024, 16384), shape=(8, 32, 32),
                               trials=4)
        fig8_striping.run(n_files=2, file_mb=8, trials=3,
                          blocks_kb=(1024, 4096), channels=(1, 2, 4))
        fig9_coalesce.run(ds_kb=(16, 64, 16384), trials=3, budget_mb=16)
        fig11_gateway.run(n_backends=3, n_datasets=9, ds_kb=256, trials=2)
        fig12_codecs.run(n_versions=6, ds_kbs=(64, 256), trials=2)
    roofline.run()


if __name__ == "__main__":
    main()
