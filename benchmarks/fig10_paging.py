"""Fig 10 (beyond the paper): ingest under memory pressure on the paged
staging store (DESIGN.md §11).

Two questions the flat-region staging area cannot answer:

  * **pressure** — when the SAVIME hop is slow and producers outrun the
    staging capacity, does ingest keep flowing?  The flat path falls back
    to whole-dataset disk regions; the paged store spills cold *pages*
    and keeps credit grants alive.  Row per mode: 16 striped datasets
    against capacity sized for 4, with an artificially slowed analytical
    hop — matched trials, paged vs flat, byte-exact verified in SAVIME.
  * **dedup capacity** — on a 50%-duplicate checkpoint-style stream, how
    many logical bytes fit before the first spill?  Content-addressed
    dedup stores each repeated page once, so the effective capacity
    multiple should approach 2x (the gate is >= 1.5x).

Prints one JSON row per cell:

    {"fig": "fig10", "row": "pressure", "mode": "paged"|"flat", ...}
    {"fig": "fig10", "row": "dedup_capacity", "dedup": ...,
     "effective_capacity_x": ...}
"""
from __future__ import annotations

import argparse
import json
import statistics
import tempfile
import time

import numpy as np

from benchmarks.common import ci95, fresh_stack, make_buffers, write_rows
from repro.core.pagestore import PageStore
from repro.transport import TransferSession, TransportConfig

PAGE_BYTES = 64 << 10
MODES = ("flat", "paged")


def _pressure_trial(mode: str, bufs, ds_bytes: int, delay_s: float,
                    tag: str) -> tuple[float, dict]:
    """16-dataset striped ingest against capacity for 4, slow SAVIME hop.

    Returns (ingest wall time, server counters); raises if any byte
    lands wrong in SAVIME.
    """
    page_bytes = PAGE_BYTES if mode == "paged" else 0
    with fresh_stack(mem_capacity=4 * ds_bytes, send_threads=1,
                     page_bytes=page_bytes) as (sv, st):
        orig = sv.engine.load_dataset

        def slow_load(name, dtype, payload):
            time.sleep(delay_s)             # the slow analytical hop
            orig(name, dtype, payload)

        sv.engine.load_dataset = slow_load
        cfg = TransportConfig(staging_addr=st.addr, n_channels=2,
                              stripe_bytes=ds_bytes // 4, credits=4,
                              page_bytes=page_bytes)
        sess = TransferSession("rdma_staged", cfg).open()
        t0 = time.perf_counter()
        for j, b in enumerate(bufs):
            sess.write(f"{tag}f{j}", b, dtype="float64")
        sess.sync(timeout=120)
        dt = time.perf_counter() - t0
        sess.drain(timeout=120)
        server = sess.server_stats()
        sess.close()
        for j, b in enumerate(bufs):        # byte-exact at the endpoint
            got = np.frombuffer(sv.engine.datasets[f"{tag}f{j}"],
                                dtype=np.float64)
            assert np.array_equal(got, b), f"{tag}f{j} corrupted"
    keep = {k: server.get(k, 0) for k in ("disk_fallbacks", "stripes")}
    if "pages" in server:
        keep["spill_outs"] = server["pages"]["spill_outs"]
        keep["mem_used"] = server["pages"]["mem_used"]
    return dt, keep


def _dedup_capacity(dedup: bool, n_pages: int = 32,
                    ds_pages: int = 4) -> dict:
    """Stream 50%-duplicate datasets into a store until the first spill;
    the logical bytes admitted before spilling, over nominal capacity,
    is the effective capacity multiple. Byte-exact re-reads are checked
    after pushing well past capacity (so spilled pages round-trip too),
    and a duplicate's release must not take its twin down."""
    capacity = n_pages * PAGE_BYTES
    ds_bytes = ds_pages * PAGE_BYTES
    rng = np.random.default_rng(12)
    with tempfile.TemporaryDirectory() as td:
        store = PageStore(capacity=capacity, page_bytes=PAGE_BYTES,
                          mem_dir=f"{td}/mem", spill_dir=f"{td}/spill",
                          dedup=dedup)
        tables, logical, admitted, unique = [], 0, None, None
        for i in range(4 * n_pages // ds_pages):
            if i % 2 == 1 and unique is not None:
                buf = unique                # 50% duplicate stream
            else:
                buf = rng.integers(0, 256, ds_bytes, dtype=np.uint8)
                unique = buf
            t = store.alloc(ds_bytes)
            store.write(t, 0, buf)
            store.seal(t)
            tables.append((t, buf))
            logical += ds_bytes
            if admitted is None and store.stats()["spill_outs"] > 0:
                admitted = logical - ds_bytes   # last fully-resident fill
        s = store.stats()
        assert admitted is not None and s["spill_outs"] > 0
        # byte-exact after spilling, including pulled-back cold pages
        for t, buf in tables:
            assert bytes(store.read(t)) == buf.tobytes()
        # a duplicate's release must not free pages its twin still holds
        if dedup and len(tables) >= 2:
            (t_dup, _), (t_orig, buf0) = tables[1], tables[0]
            store.free(t_dup)
            assert bytes(store.read(t_orig)) == buf0.tobytes()
        counters = store.stats()
        store.close()
    return {"fig": "fig10", "row": "dedup_capacity", "dedup": dedup,
            "capacity_kb": capacity >> 10, "ds_kb": ds_bytes >> 10,
            "effective_capacity_x": round(admitted / capacity, 3),
            "spill_outs": counters["spill_outs"],
            "dedup_hits": counters["dedup_hits"],
            "dedup_saved_kb": counters["dedup_saved_bytes"] >> 10}


def run(n_datasets=16, ds_kb=256, trials=3, delay_ms=20.0, quiet=False):
    rows = []
    ds_bytes = ds_kb << 10
    bufs = make_buffers(n_datasets, ds_bytes, seed=0)
    total = sum(b.nbytes for b in bufs)
    times = {m: [] for m in MODES}
    server = {m: {} for m in MODES}
    for t in range(trials):
        for m in MODES:                      # matched: both modes per trial
            dt, srv = _pressure_trial(m, bufs, ds_bytes, delay_ms / 1e3,
                                      f"p{t}{m}")
            times[m].append(dt)
            for k, v in srv.items():
                server[m][k] = server[m].get(k, 0) + v
    for m in MODES:
        med = statistics.median(times[m])
        mean, ci = ci95(times[m])
        ratios = [flat / own for flat, own in zip(times["flat"], times[m])]
        row = {"fig": "fig10", "row": "pressure", "mode": m,
               "n_datasets": n_datasets, "ds_kb": ds_kb,
               "median_s": round(med, 6), "mean_s": round(mean, 6),
               "ci95_s": round(ci, 6),
               "gbps": round(total / med / 1e9, 4),
               "speedup_vs_flat": round(statistics.median(ratios), 3),
               "server": server[m]}
        rows.append(row)
        if not quiet:
            print(json.dumps(row), flush=True)
    for dedup in (False, True):
        row = _dedup_capacity(dedup)
        rows.append(row)
        if not quiet:
            print(json.dumps(row), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one matched trial per mode + capacity rows (CI)")
    ap.add_argument("--full", action="store_true",
                    help="more datasets / trials (slower)")
    ap.add_argument("--out", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n_datasets=16, ds_kb=256, trials=2, delay_ms=20.0)
        # the smoke gate: both modes moved every byte (the trials verify
        # byte-exactness in SAVIME themselves), the paged mode really
        # spilled under pressure and returned every frame, and dedup buys
        # >= 1.5x effective capacity on the 50%-duplicate stream
        press = {r["mode"]: r for r in rows if r["row"] == "pressure"}
        assert press["flat"]["gbps"] > 0 and press["paged"]["gbps"] > 0
        assert press["paged"]["server"]["spill_outs"] > 0, rows
        assert press["paged"]["server"]["mem_used"] == 0, rows
        cap = {r["dedup"]: r for r in rows if r["row"] == "dedup_capacity"}
        assert cap[True]["effective_capacity_x"] >= 1.5, rows
        assert cap[True]["effective_capacity_x"] >= \
            1.5 * cap[False]["effective_capacity_x"], rows
    elif args.full:
        rows = run(n_datasets=32, ds_kb=512, trials=5)
    else:
        rows = run()
    if args.out:
        write_rows(args.out, rows)


if __name__ == "__main__":
    main()
