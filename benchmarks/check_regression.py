"""Perf-trajectory regression gate over committed BENCH_*.json snapshots.

Wall-clock numbers do not transfer between machines, so the gate only
compares *dimensionless ratio metrics* — speedups and capacity multiples
— which encode "the optimization still works" independent of hardware:

    fig8   speedup_vs_1ch               (striping wins over 1 channel)
    fig9   speedup_vs_json_uncoalesced  (bin1/coalescing win over legacy)
    fig10  effective_capacity_x         (dedup capacity multiple)
           speedup_vs_flat              (paging does not slow ingest)
    fig11  speedup_vs_proxy             (redirect beats full proxying)
           spread_min_over_mean         (the ring spreads the ingest)
    fig12  wire_reduction_x             (egress codecs still reduce)
    fig13  goodput_vs_clean             (fault recovery stays cheap)

A current row regresses when its metric drops more than ``--tolerance``
(default 25%) below the committed snapshot's value; improvements always
pass. Rows are matched on their identity fields; a row present in the
snapshot but missing from the current run fails (silent coverage loss).

Usage (CI):
    python -m benchmarks.fig9_coalesce --smoke --out /tmp/fig9.json
    python -m benchmarks.check_regression BENCH_fig9.json /tmp/fig9.json

    # refresh a snapshot after an intentional change:
    python -m benchmarks.check_regression BENCH_fig9.json /tmp/fig9.json \
        --update
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys

# fig -> (identity fields, gated ratio metrics)
SCHEMAS = {
    "fig8": (("block_kb", "n_channels"), ("speedup_vs_1ch",)),
    "fig9": (("ds_kb", "wire", "coalesce"),
             ("speedup_vs_json_uncoalesced",)),
    "fig10": (("row", "mode", "dedup"),
              ("effective_capacity_x", "speedup_vs_flat")),
    "fig11": (("row", "mode", "backends"),
              ("speedup_vs_proxy", "spread_min_over_mean")),
    "fig12": (("ds_kb", "codec", "wire"), ("wire_reduction_x",)),
    "fig13": (("fault_pct", "wire"), ("goodput_vs_clean",)),
}


def _key(row: dict):
    fig = row.get("fig")
    ident, _ = SCHEMAS.get(fig, ((), ()))
    return (fig,) + tuple((k, row.get(k)) for k in ident)


def check(baseline: list[dict], current: list[dict],
          tolerance: float) -> list[str]:
    cur = {_key(r): r for r in current}
    problems = []
    for base in baseline:
        fig = base.get("fig")
        _, metrics = SCHEMAS.get(fig, ((), ()))
        key = _key(base)
        row = cur.get(key)
        if row is None:
            problems.append(f"{key}: row missing from current run")
            continue
        for m in metrics:
            if m not in base:
                continue
            want, got = float(base[m]), float(row.get(m, 0.0))
            floor = want * (1.0 - tolerance)
            if got < floor:
                problems.append(
                    f"{key}: {m} regressed {want:.3f} -> {got:.3f} "
                    f"(floor {floor:.3f} at {tolerance:.0%} tolerance)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_*.json snapshot")
    ap.add_argument("current", help="rows from the current run (--out)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional drop in ratio metrics")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the snapshot with the current rows "
                         "instead of gating")
    args = ap.parse_args()
    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"[check_regression] snapshot updated: {args.baseline}")
        return
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    problems = check(baseline, current, args.tolerance)
    for p in problems:
        print(f"[check_regression] REGRESSION {p}", file=sys.stderr)
    if problems:
        sys.exit(1)
    figs = sorted({r.get("fig") for r in baseline})
    print(f"[check_regression] OK: {len(baseline)} rows "
          f"({', '.join(map(str, figs))}) within {args.tolerance:.0%}")


if __name__ == "__main__":
    main()
