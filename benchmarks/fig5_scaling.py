"""Fig 5 repro: elapsed time vs dataset size, fixed block size, 1 thread.
Paper claim C3: linear scaling. Uses a TransferSession on the
``rdma_staged`` transport."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (ci95, csv_row, fresh_stack, make_buffers,
                               staged_sessions)


def run(sizes_mb=(16, 32, 64, 128), block_kb=16384, trials=4, quiet=False):
    points = []
    for mb in sizes_mb:
        n_files = max(mb // 8, 1)
        bufs = make_buffers(n_files, (mb // n_files) << 20, seed=mb)
        times = []
        for t in range(trials):
            with fresh_stack() as (sv, st):
                (sess,) = staged_sessions(st.addr, 1, io_threads=1,
                                          block_size=block_kb << 10)
                t0 = time.perf_counter()
                for j, b in enumerate(bufs):
                    sess.write(f"s{mb}t{t}f{j}", b, dtype="float64")
                sess.sync()
                times.append(time.perf_counter() - t0)
                sess.close()
        m, ci = ci95(times)
        points.append((mb, m, ci))
        if not quiet:
            csv_row(f"fig5/size_{mb}MB", m * 1e6, f"ci95={ci * 1e6:.0f}us")
    # linear fit R^2 (claim C3)
    x = np.array([p[0] for p in points], float)
    y = np.array([p[1] for p in points], float)
    a, b = np.polyfit(x, y, 1)
    r2 = 1 - ((y - (a * x + b)) ** 2).sum() / ((y - y.mean()) ** 2).sum()
    if not quiet:
        csv_row("fig5/linear_fit", a * 1e6, f"R2={r2:.4f}")
    return points, r2


if __name__ == "__main__":
    run()
