"""Fig 13 (beyond the paper): goodput under injected faults (DESIGN.md §15).

The paper measures the in-transit pipeline on a healthy fabric; this
sweep measures what the durability machinery costs when the fabric is
*not* healthy. A seeded :class:`~repro.faults.FaultPlan` mangles a
fraction of the stripe frames (CRC-rejected + resent) and severs a
smaller fraction of the channel connections (failover + adoption), and
every trial still requires the zero-loss contract: each dataset must be
bit-identical at SAVIME after ``sync``.

Stripes are forced onto the payload data plane for the run (the
one-sided mmap store never touches the socket, so a loopback bench
would otherwise hide the wire entirely — exactly the plane a remote
fabric would use, and the one corruption can reach).

The gated metric is ``goodput_vs_clean`` = faulty goodput / matched
clean goodput — dimensionless, so it transfers between machines. The
smoke gate requires >= 0.5 at a 1% fault rate: retry/replay may tax the
stream, but it must not halve it.

Prints one JSON row per fault rate:

    {"fig": "fig13", "fault_pct": ..., "wire": "bin1",
     "goodput_vs_clean": ..., "crc_errors": ..., "drops": ..., ...}
"""
from __future__ import annotations

import argparse
import contextlib
import json
import statistics
import time

import numpy as np

from benchmarks.common import ci95, fresh_stack, write_rows
from repro.faults import FaultPlan, injected
from repro.transport import TransferSession, TransportConfig
from repro.transport import channels as channels_mod


@contextlib.contextmanager
def payload_plane():
    """Disable the one-sided mmap store so stripes carry their payload
    on the socket (the remote-fabric plane the injector can reach)."""
    saved = channels_mod.writer_for_reply
    channels_mod.writer_for_reply = lambda h, n: None
    try:
        yield
    finally:
        channels_mod.writer_for_reply = saved


def _plan(fault_pct: float, seed: int) -> FaultPlan:
    """Corrupt ``fault_pct`` percent of stripe frames and sever channels
    at a quarter of that rate (links die less often than frames mangle)."""
    if fault_pct <= 0:
        return FaultPlan(seed=seed)
    p = fault_pct / 100.0
    return FaultPlan.parse(
        f"seed={seed};corrupt:op=stripe,prob={p},flips=3;"
        f"drop:op=stripe,prob={p / 4}")


def _trial(fault_pct: float, bufs: dict, seed: int) -> tuple[float, dict]:
    """Ship ``bufs`` through a fresh striped bin1 stack under the fault
    plan; returns (ingest wall time, fault/durability accounting) and
    asserts the zero-loss contract at the endpoint."""
    plan = _plan(fault_pct, seed)
    with fresh_stack(mem_capacity=1 << 28, send_threads=2) as (sv, st):
        cfg = TransportConfig(staging_addr=st.addr, n_channels=2,
                              wire_format="bin1", stripe_bytes=32 << 10,
                              io_threads=2, retry=6)
        with injected(plan, scope=[st.addr]) as inj:
            sess = TransferSession("rdma_staged", cfg).open()
            t0 = time.perf_counter()
            for n, b in bufs.items():
                sess.write(n, b, dtype="float64")
            sess.sync(timeout=120)
            dt = time.perf_counter() - t0
            sess.drain(timeout=120)
            crc_errors = sess.server_stats().get("crc_errors", 0)
            sess.close()
        # the zero-loss contract: every acked dataset bit-identical
        for n, b in bufs.items():
            got = np.frombuffer(sv.engine.datasets[n], dtype=np.float64)
            assert np.array_equal(got, b), \
                f"{n}: data loss/corruption at fault_pct={fault_pct}"
    return dt, {"corrupts": inj.fired.get("corrupt", 0),
                "drops": inj.fired.get("drop", 0),
                "crc_errors": int(crc_errors),
                "replays": sess.stats.replays,
                "failed_over": sum(c.get("failed_over", 0)
                                   for c in sess.stats.channels)}


def run(fault_pcts=(0.0, 1.0, 5.0), n_datasets=8, ds_kb=256, trials=3,
        quiet=False):
    rng = np.random.default_rng(13)
    bufs = {f"f13_{i}": rng.standard_normal((ds_kb << 10) // 8)
            for i in range(n_datasets)}
    total = sum(b.nbytes for b in bufs.values())
    rows = []
    with payload_plane():
        times = {p: [] for p in fault_pcts}
        acct = {p: None for p in fault_pcts}
        for t in range(trials):
            for p in fault_pcts:         # matched: every rate per trial
                dt, a = _trial(p, bufs, seed=int(p * 100) + t)
                times[p].append(dt)
                acct[p] = a
    clean = statistics.median(times[fault_pcts[0]])
    for p in fault_pcts:
        med = statistics.median(times[p])
        mean, ci = ci95(times[p])
        a = acct[p]
        row = {"fig": "fig13", "fault_pct": p, "wire": "bin1",
               "n_datasets": n_datasets, "ds_kb": ds_kb,
               "median_s": round(med, 6), "mean_s": round(mean, 6),
               "ci95_s": round(ci, 6),
               "gbps": round(total / med / 1e9, 4),
               "corrupts": a["corrupts"], "drops": a["drops"],
               "crc_errors": a["crc_errors"], "replays": a["replays"],
               "failed_over": a["failed_over"],
               "goodput_vs_clean": round(clean / med, 3)}
        rows.append(row)
        if not quiet:
            print(json.dumps(row), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small matched sweep + the 1%% goodput gate (CI)")
    ap.add_argument("--full", action="store_true",
                    help="more data / rates / trials (slower)")
    ap.add_argument("--out", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        rows = run(fault_pcts=(0.0, 1.0), n_datasets=6, ds_kb=128,
                   trials=2)
        # every trial already asserted zero loss; the smoke gate is the
        # throughput side of the contract — recovery must cost < 2x
        by = {r["fault_pct"]: r for r in rows}
        assert by[0.0]["goodput_vs_clean"] == 1.0, rows
        assert by[1.0]["goodput_vs_clean"] >= 0.5, rows
    elif args.full:
        rows = run(fault_pcts=(0.0, 0.5, 1.0, 2.0, 5.0, 10.0),
                   n_datasets=16, ds_kb=512, trials=5)
    else:
        rows = run()
    if args.out:
        write_rows(args.out, rows)


if __name__ == "__main__":
    main()
