"""Fig 9 (beyond the paper): winning the small-block regime.

Fig 3 shows throughput collapsing as datasets shrink — per-dataset
reservation round-trips, registration and JSON framing stop amortizing.
This sweep measures the two levers this repo adds against that collapse
(DESIGN.md §10), on the ``rdma_staged`` path:

  * ``wire_format``: legacy JSON frames vs the struct-packed ``bin1``
    fast path (negotiated per connection, single-``sendmsg`` frames);
  * ``coalesce``: off (every dataset pays its own control RTTs) vs on
    (datasets below the threshold are packed into one ``batch_open`` +
    ``batch_write`` round-trip, payloads scatter-gathered in one
    vectored send).

Cells: dataset size x {json, bin1} x {coalesce off, on}. Datasets at or
above ``coalesce_bytes`` bypass the coalescer, so the large-dataset
cells double as the no-regression check (the acceptance bar is "within
noise at 16 MB"); ``wire=json, coalesce=off`` is byte-identical legacy
behavior and the baseline every speedup is measured against.

Methodology matches fig8: shared boxes drift by 2-3x over minutes, so
cells are *matched* — every trial runs all four modes back-to-back
against a fresh stack and the reported speedup is the median of
per-trial ratios against the same trial's json/uncoalesced run.

Prints one JSON row per cell:

    {"fig": "fig9", "ds_kb": ..., "wire": ..., "coalesce": ...,
     "n_files": ..., "median_s": ..., "mean_s": ..., "ci95_s": ...,
     "gbps": ..., "speedup_vs_json_uncoalesced": ...,
     "server": {"batches": ..., "batched_datasets": ..., "datasets": ...}}
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

from benchmarks.common import ci95, fresh_stack, make_buffers
from repro.transport import TransferSession, TransportConfig

COALESCE_BYTES = 1 << 20      # datasets below 1 MiB batch; larger bypass
MODES = (("json", False), ("bin1", False), ("json", True), ("bin1", True))
BASE_MODE = ("json", False)


def _n_files(ds_bytes: int, budget: int) -> int:
    """Files per trial: enough small datasets to expose per-dataset
    overhead, bounded by a total-bytes budget at the large end."""
    return max(2, min(64, budget // ds_bytes))


def _trial(bufs, ds_bytes, wire_fmt, coalesce, io_threads, tag):
    with fresh_stack(send_threads=1) as (sv, st):
        cfg = TransportConfig(
            staging_addr=st.addr, io_threads=io_threads,
            block_size=ds_bytes, wire_format=wire_fmt,
            coalesce_bytes=COALESCE_BYTES if coalesce else 0,
            linger_ms=2.0)
        sess = TransferSession("rdma_staged", cfg).open()
        t0 = time.perf_counter()
        for j, b in enumerate(bufs):
            sess.write(f"{tag}f{j}", b, dtype="float64")
        sess.sync()
        dt = time.perf_counter() - t0
        server = sess.server_stats()
        sess.close()
    return dt, {k: server.get(k, 0)
                for k in ("batches", "batched_datasets", "datasets")}


def run(ds_kb=(16, 64, 1024, 16384), trials=5, io_threads=1,
        budget_mb=32, quiet=False):
    rows = []
    for kb in ds_kb:
        ds_bytes = kb << 10
        n = _n_files(ds_bytes, budget_mb << 20)
        bufs = make_buffers(n, ds_bytes)
        total = sum(b.nbytes for b in bufs)
        times = {m: [] for m in MODES}
        server = {m: {} for m in MODES}
        for t in range(trials):
            for m in MODES:              # matched: all cells per trial
                wire_fmt, coalesce = m
                dt, srv = _trial(bufs, ds_bytes, wire_fmt, coalesce,
                                 io_threads,
                                 f"k{kb}t{t}{wire_fmt}{int(coalesce)}")
                times[m].append(dt)
                for k, v in srv.items():
                    server[m][k] = server[m].get(k, 0) + v
        for m in MODES:
            wire_fmt, coalesce = m
            med = statistics.median(times[m])
            mean, ci = ci95(times[m])
            ratios = [base / own
                      for base, own in zip(times[BASE_MODE], times[m])]
            row = {"fig": "fig9", "ds_kb": kb, "wire": wire_fmt,
                   "coalesce": coalesce, "n_files": n,
                   "median_s": round(med, 6), "mean_s": round(mean, 6),
                   "ci95_s": round(ci, 6),
                   "gbps": round(total / med / 1e9, 4),
                   "speedup_vs_json_uncoalesced":
                       round(statistics.median(ratios), 3),
                   "server": server[m]}
            rows.append(row)
            if not quiet:
                print(json.dumps(row), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one small-dataset size, all four modes (CI)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale-ish sizes (slower)")
    ap.add_argument("--out", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        rows = run(ds_kb=(64,), trials=3, budget_mb=4)
        # the smoke gate: every mode moved every byte (server accounting
        # parity), coalesced cells actually batched, and the fast path
        # beats the legacy path where the PR claims it does
        assert all(r["gbps"] > 0 for r in rows), rows
        n = rows[0]["n_files"]
        assert all(r["server"]["datasets"] == n * 3 for r in rows), rows
        coalesced = [r for r in rows if r["coalesce"]]
        assert coalesced and all(
            r["server"]["batched_datasets"] == n * 3 for r in coalesced), rows
        fast = [r for r in rows if r["wire"] == "bin1" and r["coalesce"]]
        assert fast and all(
            r["speedup_vs_json_uncoalesced"] >= 2.0 for r in fast), rows
    elif args.full:
        rows = run(ds_kb=(16, 64, 256, 1024, 4096, 16384), trials=7,
                   budget_mb=128)
    else:
        rows = run()
    if args.out:
        from benchmarks.common import write_rows
        write_rows(args.out, rows)


if __name__ == "__main__":
    main()
