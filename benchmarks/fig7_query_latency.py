"""Fig 7 (beyond the paper): analytical query latency under concurrent
ingest, across RDMA block sizes — the reader-side cost of the paper's
query-while-running goal (§6). A background InTransitSink keeps staging
new steps while the foreground AnalysisSession measures typed
select/aggregate latency. Emits one JSON row per (block_size, query
kind), like roofline's per-cell JSON.

Comparability: every measured query uses a FIXED box over the warm
steps (data volume per query is constant), ingest is capped at
``max_steps`` so the subtar list the engine scans stays bounded, and
each row records the subtar count observed at measurement time.
"""
from __future__ import annotations

import json
import threading

import numpy as np

from benchmarks.common import ci95, fresh_stack
from repro.analysis import AnalysisSession, tar
from repro.core import InTransitConfig, InTransitSink


def run(blocks_kb=(1024, 4096, 16384), shape=(16, 64, 64), trials=8,
        warm_steps=3, max_steps=48, quiet=False):
    rng = np.random.default_rng(0)
    field = rng.standard_normal(shape).astype(np.float32)
    zeros = (0,) * len(shape)
    one_step_hi = (0,) + tuple(n - 1 for n in shape)
    warm_hi = (warm_steps - 1,) + tuple(n - 1 for n in shape)
    rows = []
    for bk in blocks_kb:
        with fresh_stack() as (sv, st):
            sink = InTransitSink(st.addr, InTransitConfig(
                block_size=bk << 10, io_threads=2, tar_prefix="fig7"))
            for s in range(warm_steps):          # queries need data to hit
                sink.stage_array("field", field, step=s)
            sink.flush()
            stop = threading.Event()

            def ingest():
                for step in range(warm_steps, max_steps):
                    if stop.is_set():
                        return
                    sink.stage_array("field", field, step=step)
                    sink.flush(timeout=30)

            t = threading.Thread(target=ingest, daemon=True)
            t.start()
            try:
                with AnalysisSession(sv.addr) as an:
                    queries = {
                        "select_step": lambda: an.execute(
                            tar("fig7_field").attr("v")
                            .range((0,) + zeros, one_step_hi).select()),
                        "select_warm": lambda: an.execute(
                            tar("fig7_field").attr("v")
                            .range((0,) + zeros, warm_hi).select()),
                        "agg_mean": lambda: an.execute(
                            tar("fig7_field").attr("v")
                            .range((0,) + zeros, warm_hi).mean()),
                        "agg_step_max": lambda: an.execute(
                            tar("fig7_field").attr("v")
                            .range((0,) + zeros, one_step_hi).max()),
                    }
                    for kind, fn in queries.items():
                        times = [fn().elapsed_s for _ in range(trials)]
                        m, ci = ci95(times)
                        row = {"fig": "fig7", "block_kb": bk, "query": kind,
                               "mean_us": round(m * 1e6, 1),
                               "ci95_us": round(ci * 1e6, 1),
                               "trials": trials,
                               "subtars_at_measure":
                                   an.server_stats().get("subtars"),
                               "concurrent_ingest": t.is_alive()}
                        rows.append(row)
                        if not quiet:
                            print(json.dumps(row), flush=True)
            finally:
                stop.set()
                t.join(timeout=10)
                sink.close()
    return rows


if __name__ == "__main__":
    run()
