"""Fig 11 (beyond the paper): ingest through the multi-tenant staging
gateway (DESIGN.md §12) — 1 vs N backends, redirect vs proxy.

Two questions a single staging server cannot answer:

  * **does the pool scale** — N backends behind one gateway address
    should absorb a fixed ingest workload faster than one backend, and
    the consistent-hash ring should spread the bytes across the fleet
    (``balance_max_over_mean`` near 1.0, ``spread_min_over_mean`` > 0);
  * **what does redirect buy** — a gateway-aware client pays one admit
    RTT per dataset and then writes straight to its backend (the
    one-sided plane survives), while a legacy client's every frame is
    relayed through the gateway.  ``speedup_vs_proxy`` is the win.

Cells are matched per trial: every (backends, mode) cell of one trial
ingests the identical buffers on a fresh pool.  Every trial also checks
accounting parity — the gateway's admitted totals must equal the sum of
the backends' in-process ``bytes_in`` counters, byte for byte.

Prints one JSON row per cell:

    {"fig": "fig11", "row": "ingest", "mode": "redirect"|"proxy",
     "backends": 1|N, "gbps": ..., "speedup_vs_proxy": ...,
     "speedup_vs_1": ..., "balance_max_over_mean": ...,
     "spread_min_over_mean": ...}
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

from benchmarks.common import ci95, make_buffers, write_rows
from repro.gateway import StagingPool
from repro.transport import TransferSession, TransportConfig

MODES = ("proxy", "redirect")


def _trial(mode: str, n_backends: int, bufs, tag: str,
           block_size: int) -> tuple[float, list[int], dict]:
    """One matched cell: ingest ``bufs`` through a fresh pool.

    Returns (ingest wall seconds, per-backend bytes_in, gateway totals);
    raises if the gateway's admission accounting and the backends'
    ingress counters disagree.
    """
    with StagingPool(n_backends, mem_capacity=1 << 30) as pool:
        if mode == "redirect":
            cfg = TransportConfig(gateway_addr=pool.addr,
                                  block_size=block_size)
        else:                       # legacy client pointed at the gateway
            cfg = TransportConfig(staging_addr=pool.addr,
                                  block_size=block_size)
        sess = TransferSession("rdma_staged", cfg).open()
        t0 = time.perf_counter()
        for j, b in enumerate(bufs):
            sess.write(f"{tag}d{j}", b, dtype="float64")
        sess.sync(timeout=120)
        dt = time.perf_counter() - t0
        sess.drain(timeout=120)
        sess.close()
        landed = [s["bytes_in"] for s in pool.backend_stats().values()]
        with pool.gateway._lock:
            totals = {
                "admitted_bytes": sum(
                    b.admitted_bytes for b in pool.gateway.backends.values()),
                "admitted_datasets": sum(
                    b.admitted_datasets
                    for b in pool.gateway.backends.values())}
    expect = sum(b.nbytes for b in bufs)
    assert sum(landed) == expect, (mode, n_backends, landed, expect)
    assert totals["admitted_bytes"] == expect, (mode, n_backends, totals)
    assert totals["admitted_datasets"] == len(bufs), (mode, totals)
    return dt, landed, totals


def run(n_backends=3, n_datasets=12, ds_kb=512, trials=3,
        block_size=1 << 20, quiet=False):
    rows = []
    bufs = make_buffers(n_datasets, ds_kb << 10, seed=11)
    total = sum(b.nbytes for b in bufs)
    cells = [(k, m) for k in (1, n_backends) for m in MODES]
    times = {c: [] for c in cells}
    landed = {c: None for c in cells}
    for t in range(trials):
        for c in cells:                     # matched: every cell per trial
            k, m = c
            dt, per_backend, _ = _trial(m, k, bufs, f"t{t}{m}{k}",
                                        block_size)
            times[c].append(dt)
            landed[c] = per_backend
    for c in cells:
        k, m = c
        med = statistics.median(times[c])
        mean, ci = ci95(times[c])
        vs_proxy = [p / own for p, own in zip(times[(k, "proxy")],
                                              times[c])]
        vs_one = [one / own for one, own in zip(times[(1, m)], times[c])]
        per_backend = landed[c]
        mean_b = sum(per_backend) / len(per_backend)
        row = {"fig": "fig11", "row": "ingest", "mode": m, "backends": k,
               "n_datasets": n_datasets, "ds_kb": ds_kb,
               "median_s": round(med, 6), "mean_s": round(mean, 6),
               "ci95_s": round(ci, 6),
               "gbps": round(total / med / 1e9, 4),
               "speedup_vs_proxy": round(statistics.median(vs_proxy), 3),
               "speedup_vs_1": round(statistics.median(vs_one), 3),
               "balance_max_over_mean": round(max(per_backend) / mean_b, 3),
               "spread_min_over_mean": round(min(per_backend) / mean_b, 3)}
        rows.append(row)
        if not quiet:
            print(json.dumps(row), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one matched trial per cell + parity gate (CI)")
    ap.add_argument("--full", action="store_true",
                    help="more backends / datasets / trials (slower)")
    ap.add_argument("--out", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n_backends=3, n_datasets=9, ds_kb=256, trials=2)
        # the smoke gate: every cell moved every byte with gateway-vs-
        # backend accounting parity (asserted inside each trial), the
        # ring actually spread the ingest across the pool, and the
        # redirect path is not slower than full proxying
        pooled = {r["mode"]: r for r in rows if r["backends"] > 1}
        assert pooled["redirect"]["spread_min_over_mean"] > 0, rows
        assert pooled["proxy"]["spread_min_over_mean"] > 0, rows
        assert pooled["redirect"]["speedup_vs_proxy"] >= 0.75, rows
    elif args.full:
        rows = run(n_backends=4, n_datasets=24, ds_kb=1024, trials=5)
    else:
        rows = run()
    if args.out:
        write_rows(args.out, rows)


if __name__ == "__main__":
    main()
