"""Fig 8 (beyond the paper): block size x channel count on the staged path.

The paper's central experiment (Fig 3) sweeps the RDMA block size on one
connection per I/O thread; this sweep adds the parallelism axis — each
dataset striped across ``n_channels`` concurrent connections with
credit-based flow control (DESIGN.md §9). ``n_channels=1`` runs the
original single-connection one-sided path, so the first column doubles as
the no-regression baseline.

Methodology: shared/throttled boxes drift by 2-3x over minutes, so cells
are *matched* — every trial runs all channel counts back-to-back and the
reported speedup is the median of per-trial ratios against the
``n_channels=1`` run of the *same* trial, not a comparison of cells
measured at different times.

Prints one JSON row per (block_size, n_channels) cell:

    {"fig": "fig8", "block_kb": ..., "n_channels": ..., "median_s": ...,
     "mean_s": ..., "ci95_s": ..., "gbps": ..., "speedup_vs_1ch": ...,
     "per_channel": [...]}
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

from benchmarks.common import ci95, fresh_stack, make_buffers
from repro.transport import TransferSession, TransportConfig


def _trial(bufs, bk_kb, nc, credits, io_threads, tag):
    """One timed write+sync of every buffer against a fresh stack.

    One forward thread, so the staging->SAVIME hop contributes a constant
    background load to every cell — the sweep isolates the
    compute->staging parallelism axis (two forward threads make the
    in-window contention burst unpredictably and swamp the comparison).
    """
    with fresh_stack(send_threads=1) as (sv, st):
        cfg = TransportConfig(staging_addr=st.addr, io_threads=io_threads,
                              block_size=bk_kb << 10, n_channels=nc,
                              stripe_bytes=bk_kb << 10, credits=credits)
        sess = TransferSession("rdma_staged", cfg).open()
        t0 = time.perf_counter()
        for j, b in enumerate(bufs):
            sess.write(f"{tag}f{j}", b, dtype="float64")
        sess.sync()
        dt = time.perf_counter() - t0
        per_channel = sess.stats.channels
        sess.close()
    return dt, per_channel


def run(n_files=2, file_mb=32, trials=5, io_threads=1,
        blocks_kb=(1024, 4096, 16384), channels=(1, 2, 4),
        credits=8, quiet=False):
    bufs = make_buffers(n_files, file_mb << 20)
    total = sum(b.nbytes for b in bufs)
    base_nc = min(channels)
    rows = []
    for bk in blocks_kb:
        times = {nc: [] for nc in channels}
        # per-channel counters are summed across trials (each trial runs a
        # fresh stack, so a skewed or stalled channel in any trial shows)
        per_channel = {nc: {} for nc in channels}
        for t in range(trials):
            for nc in channels:          # matched: all cells per trial
                dt, ch = _trial(bufs, bk, nc, credits, io_threads,
                                f"b{bk}t{t}c{nc}")
                times[nc].append(dt)
                for c in ch:
                    acc = per_channel[nc].get(c["channel"])
                    if acc is None:
                        per_channel[nc][c["channel"]] = dict(c)
                        continue
                    for k, v in c.items():
                        if k in ("nbytes", "n_stripes", "stripe_s",
                                 "credit_wait_s"):
                            acc[k] += v
                        elif k == "peak_unacked":
                            acc[k] = max(acc[k], v)
                        else:
                            acc[k] = v
        for nc in channels:
            med = statistics.median(times[nc])
            m, ci = ci95(times[nc])
            ratios = [a / b for a, b in zip(times[base_nc], times[nc])]
            row = {"fig": "fig8", "block_kb": bk, "n_channels": nc,
                   "median_s": round(med, 6), "mean_s": round(m, 6),
                   "ci95_s": round(ci, 6),
                   "gbps": round(total / med / 1e9, 4),
                   "speedup_vs_1ch": round(statistics.median(ratios), 3),
                   "per_channel": [
                       {k: (round(v, 6) if isinstance(v, float) else v)
                        for k, v in c.items()}
                       for c in sorted(per_channel[nc].values(),
                                       key=lambda c: c["channel"])]}
            rows.append(row)
            if not quiet:
                print(json.dumps(row), flush=True)
    return rows, total


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one small cell, single- and 2-channel (CI)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale-ish sizes (slower)")
    ap.add_argument("--out", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        rows, total = run(n_files=2, file_mb=2, trials=1, blocks_kb=(1024,),
                          channels=(1, 2))
        # the smoke gate: both paths ran, and the striped path acked every
        # byte across its channels (per-channel stats parity)
        assert all(r["gbps"] > 0 for r in rows), rows
        striped = [r for r in rows if r["n_channels"] == 2]
        assert striped and all(
            sum(c["nbytes"] for c in r["per_channel"]) == total
            for r in striped), rows
    elif args.full:
        rows, _ = run(n_files=4, file_mb=32, trials=7)
    else:
        rows, _ = run()
    if args.out:
        from benchmarks.common import write_rows
        write_rows(args.out, rows)


if __name__ == "__main__":
    main()
