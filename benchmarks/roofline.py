"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Hardware model (TPU v5e class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Three terms (seconds, per step):
  compute    = HLO_FLOPs_per_device / 197e12
               (loop-corrected dot flops parsed from optimized HLO —
               repro.launch.hlo_analysis; XLA cost_analysis counts loop
               bodies once and is kept only as a reference field)
  memory     = analytic HBM bytes per device / 819e9
               (documented model below; the HLO-derived bytes proxy is an
               upper bound distorted by CPU-backend fusion choices and is
               reported as `hbm_hlo`)
  collective = per-device collective wire bytes / 50e9
               (equivalent to global_bytes / (chips x link_bw))

Derived:
  bound        = max(terms)                  (step-time lower bound)
  mfu_at_bound = useful_time / bound, useful_time = MODEL_FLOPS /
                 (chips x 197e12)            (the roofline fraction)
  useful_ratio = MODEL_FLOPS / (HLO_FLOPs x chips)

Analytic HBM model (per device):  P = params/TP, Bl = batch/DP
  train:   4B*P*(3r+1w params, 2rw grads) + 4B*P*4/DP (ZeRO-1 moments)
           + act*(1w+2r)*L + xent 2*Bl*S*Vloc*4 + attn KV streaming
  prefill: 4B*P + act*L + KV writes + KV streaming reads
  decode:  4B*P + cache read
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12     # MXU bf16
VPU_FLOPS = 4e12        # elementwise/VPU estimate (SSM scans live here)
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def _cfg(arch: str):
    from repro.configs import get_config
    return get_config(arch)


def analytic_hbm_bytes(arch: str, shape_name: str, n_chips: int) -> float:
    from repro.configs import SHAPES
    cfg = _cfg(arch)
    shape = SHAPES[shape_name]
    tp = 16
    dp = n_chips // tp
    P = cfg.param_count() / tp
    pb = 4  # param storage fp32 (bf16-on-TPU would halve this)
    B_loc = max(shape.global_batch // dp, 1)
    M, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    S = shape.seq_len
    act = B_loc * S * M * 2  # bf16 residual-stream tensor per device

    n_global = sum(k in ("dense", "global", "moe") for k in cfg.layer_kinds)
    n_local = sum(k == "local" for k in cfg.layer_kinds)
    kv_loc = max(cfg.n_kv_heads * cfg.head_dim // tp,
                 cfg.head_dim if cfg.n_kv_heads == 1 else cfg.head_dim)

    if shape.kind == "train":
        w = P * pb * 4 + P * 4 * 2 + P * 4 * 4 / dp
        a = act * 3 * L
        xent = 2 * B_loc * S * (V / tp) * 4
        # chunked-flash KV streaming: each kv chunk re-read per q chunk
        n_chunks = max(S // cfg.attn_chunk, 1)
        kv = B_loc * S * kv_loc * 2 * 2
        attn = kv * n_chunks * 3 * n_global + kv * 2 * 3 * n_local
        return w + a + xent + attn
    if shape.kind == "prefill":
        w = P * pb
        a = act * 2 * L
        n_chunks = max(S // cfg.attn_chunk, 1)
        kv = B_loc * S * kv_loc * 2 * 2
        attn = kv * n_chunks * n_global + kv * 2 * n_local
        return w + a + attn + kv * L
    # decode: weights + full cache read per token
    w = P * pb
    cache = 0.0
    for k in cfg.layer_kinds:
        if k in ("dense", "global", "moe"):
            cache += B_loc * S * kv_loc * 2 * 2
        elif k == "local":
            cache += B_loc * min(cfg.attn_window, S) * kv_loc * 2 * 2
        elif k == "mamba":
            cache += B_loc * cfg.d_inner / tp * cfg.ssm.d_state * 4 * 2
        elif k == "rglru":
            cache += B_loc * cfg.d_rnn / tp * 4 * 2
    return w + cache


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        cells.append(json.load(open(p)))
    return cells


def roofline_row(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    if rec["status"] != "ok":
        return {"arch": arch, "shape": shape, "status": rec["status"],
                "reason": rec.get("reason", rec.get("error", ""))[:80]}
    chips = rec["n_chips"]
    t_c = rec["flops"] / PEAK_FLOPS + rec.get("vpu_flops", 0.0) / VPU_FLOPS
    hbm = analytic_hbm_bytes(arch, shape, chips)
    t_m = hbm / HBM_BW
    t_n = rec["collectives"]["total_bytes"] / LINK_BW
    bound = max(t_c, t_m, t_n)
    useful = rec["model_flops"] / chips / PEAK_FLOPS
    dom = {t_c: "compute", t_m: "memory", t_n: "collective"}[bound]
    return {
        "arch": arch, "shape": shape, "status": "ok", "chips": chips,
        "t_compute_ms": t_c * 1e3, "t_memory_ms": t_m * 1e3,
        "t_collective_ms": t_n * 1e3, "bound_ms": bound * 1e3,
        "bottleneck": dom,
        "mfu_at_bound": useful / bound if bound else 0.0,
        "useful_ratio": rec["model_flops"] / max(rec["flops"] * chips, 1.0),
        "hbm_hlo_gb": rec["hbm_bytes"] / 1e9,
        "coll_gb": rec["collectives"]["total_bytes"] / 1e9,
    }


LEVERS = {
    "compute": "cut non-useful flops (remat policy, causal block-skip, "
               "MoE capacity/padding)",
    "memory": "cut weight/activation re-reads (bf16 params, fused egress, "
              "larger xent chunks)",
    "collective": "resharding: Megatron-SP reduce-scatter+all-gather, "
                  "fewer per-layer all-reduces, compressed cross-pod grads",
}


def table(mesh: str = "single") -> str:
    rows = [roofline_row(r) for r in load_cells(mesh)]
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "bound ms | bottleneck | MFU@bound | useful ratio |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.1f} | "
            f"{r['t_memory_ms']:.1f} | {r['t_collective_ms']:.1f} | "
            f"{r['bound_ms']:.1f} | {r['bottleneck']} | "
            f"{r['mfu_at_bound'] * 100:.1f}% | "
            f"{r['useful_ratio'] * 100:.0f}% |")
    return "\n".join(out)


def run(quiet: bool = False):
    from benchmarks.common import csv_row
    rows = [roofline_row(r) for r in load_cells("single")]
    ok = [r for r in rows if r["status"] == "ok"]
    for r in sorted(ok, key=lambda r: r["mfu_at_bound"]):
        if not quiet:
            csv_row(f"roofline/{r['arch']}/{r['shape']}",
                    r["bound_ms"] * 1e3,
                    f"bottleneck={r['bottleneck']};"
                    f"mfu_at_bound={r['mfu_at_bound'] * 100:.1f}%")
    return rows


if __name__ == "__main__":
    print(table("single"))
