"""Fig 12 (beyond the paper): egress reduction codecs (DESIGN.md §13).

The staging link is the shared, contended resource: the paper scales the
analytical cluster, we shrink the bytes instead.  This sweep measures the
negotiated codec layer on a checkpoint-style stream — successive versions
of one dataset where each step perturbs a sparse subset of elements —
across codec x dataset size x wire format, with matched interleaved
trials (every codec sees the same buffers in the same trial):

  * ``none``       — the control: raw bytes, reduction 1.0 by definition.
  * ``delta-rle``  — lossless xor-delta + run-length vs the previous
                     version; byte-exact at the endpoint.
  * ``int8-block`` — lossy per-4096-block quantization; the endpoint
                     value is checked against the provable scale/2 bound.

The gated metric is ``wire_reduction_x`` = raw bytes / wire bytes — a
dimensionless ratio that encodes "the codec still reduces the stream"
independent of hardware (loopback wall time would reward *not* encoding,
since the CPU encode cost is real but the network win here is fake).
Every trial also cross-checks the accounting: client ``codec_stats``
wire bytes must equal the server's ``bytes_in``, raw bytes its
``raw_bytes_in``, and the SAVIME hop must ship raw-size bytes.

Prints one JSON row per cell:

    {"fig": "fig12", "codec": ..., "ds_kb": ..., "wire": ...,
     "wire_reduction_x": ..., "gbps": ..., ...}
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import numpy as np

from benchmarks.common import ci95, fresh_stack, write_rows
from repro.transport import TransferSession, TransportConfig

CODECS = ("none", "delta-rle", "int8-block")


def make_stream(n_versions: int, ds_bytes: int, seed: int = 0):
    """Checkpoint-style stream: version i+1 = version i with ~1% of the
    float64 elements replaced (sparse byte-level churn, so delta-rle has
    structure to find and int8-block has floats to quantize)."""
    rng = np.random.default_rng(seed)
    n = ds_bytes // 8
    buf = rng.standard_normal(n)
    out = [buf]
    for _ in range(n_versions - 1):
        buf = buf.copy()
        k = max(1, n // 100)
        idx = rng.integers(0, n, k)
        buf[idx] = rng.standard_normal(k)
        out.append(buf)
    return out


def _int8_bound(x: np.ndarray, block: int = 4096) -> np.ndarray:
    """Per-element |err| bound scale/2 = amax/254 over each codec block."""
    n = x.size
    nb = -(-n // block)
    xb = np.zeros(nb * block)
    xb[:n] = np.abs(x)
    amax = xb.reshape(nb, block).max(axis=1)
    scale = np.where(amax == 0, 1.0, amax) / 127.0
    return np.repeat(scale, block)[:n] * 0.5


def _trial(codec: str, wire: str, stream, tag: str) -> tuple[float, dict]:
    """Ship one version stream through a fresh stack; returns (ingest
    wall time, accounting) and verifies endpoint content + parity."""
    total_raw = sum(b.nbytes for b in stream)
    with fresh_stack(mem_capacity=1 << 28, send_threads=1) as (sv, st):
        cfg = TransportConfig(staging_addr=st.addr, wire_format=wire,
                              codec=codec, io_threads=1)
        sess = TransferSession("rdma_staged", cfg).open()
        t0 = time.perf_counter()
        for b in stream:                 # same name: a versioned dataset
            sess.write(tag, b, dtype="double")
        sess.sync(timeout=120)
        dt = time.perf_counter() - t0
        sess.drain(timeout=120)
        server = sess.server_stats()
        cs = sess.stats.codec
        sess.close()
        got = np.frombuffer(sv.engine.datasets[tag], dtype=np.float64)
        last = stream[-1]
        if codec == "int8-block":        # provable per-block bound
            assert (np.abs(got - last) <= _int8_bound(last) + 1e-12).all(), \
                f"{tag}: int8-block error bound violated"
        else:                            # lossless paths are byte-exact
            assert np.array_equal(got, last), f"{tag}: content mismatch"
    wire_bytes = cs["wire_bytes"] if cs else total_raw
    raw_bytes = cs["raw_bytes"] if cs else total_raw
    # accounting parity: what the client says it shipped is what the
    # server metered in, and the SAVIME hop carries raw-size bytes
    assert raw_bytes == total_raw, (raw_bytes, total_raw)
    assert server["bytes_in"] == wire_bytes, (server["bytes_in"], cs)
    assert server["raw_bytes_in"] == total_raw, server
    assert server["bytes_to_savime"] == total_raw, server
    if cs:
        assert cs["fallbacks"] == 0, cs
    return dt, {"wire_bytes": wire_bytes, "raw_bytes": raw_bytes,
                "encode_s": cs.get("encode_s", 0.0) if cs else 0.0,
                "codec_datasets": server.get("codec_datasets", 0)}


def run(n_versions=6, ds_kbs=(64, 256, 1024), wires=("json", "bin1"),
        trials=3, quiet=False):
    rows = []
    for ds_kb in ds_kbs:
        stream = make_stream(n_versions, ds_kb << 10, seed=ds_kb)
        total_raw = sum(b.nbytes for b in stream)
        for wire in wires:
            times = {c: [] for c in CODECS}
            acct = {c: None for c in CODECS}
            for t in range(trials):
                for c in CODECS:         # matched: every codec per trial
                    dt, a = _trial(c, wire, stream,
                                   f"ck{ds_kb}{wire}{t}{c}")
                    times[c].append(dt)
                    acct[c] = a
            for c in CODECS:
                med = statistics.median(times[c])
                mean, ci = ci95(times[c])
                a = acct[c]
                row = {"fig": "fig12", "codec": c, "ds_kb": ds_kb,
                       "wire": wire, "n_versions": n_versions,
                       "median_s": round(med, 6), "mean_s": round(mean, 6),
                       "ci95_s": round(ci, 6),
                       "gbps": round(total_raw / med / 1e9, 4),
                       "wire_kb": a["wire_bytes"] >> 10,
                       "encode_ms": round(a["encode_s"] * 1e3, 3),
                       "wire_reduction_x": round(
                           a["raw_bytes"] / a["wire_bytes"], 3)}
                rows.append(row)
                if not quiet:
                    print(json.dumps(row), flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one size, both wires, 2 matched trials (CI)")
    ap.add_argument("--full", action="store_true",
                    help="more sizes / versions / trials (slower)")
    ap.add_argument("--out", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n_versions=6, ds_kbs=(256,), wires=("json", "bin1"),
                   trials=2)
        # the smoke gate: every trial already verified endpoint content
        # (int8 within its scale/2 bound) and client<->server accounting
        # parity; here both reducing codecs must actually reduce the
        # stream >= 1.5x while the control stays at exactly 1.0
        by = {(r["codec"], r["wire"]): r for r in rows}
        for wire in ("json", "bin1"):
            assert by[("none", wire)]["wire_reduction_x"] == 1.0, rows
            assert by[("delta-rle", wire)]["wire_reduction_x"] >= 1.5, rows
            assert by[("int8-block", wire)]["wire_reduction_x"] >= 1.5, rows
    elif args.full:
        rows = run(n_versions=8, ds_kbs=(64, 256, 1024, 4096), trials=5)
    else:
        rows = run()
    if args.out:
        write_rows(args.out, rows)


if __name__ == "__main__":
    main()
