"""Fig 6 + §4 repro: staged-RDMA vs scp (tmpfs), scp (disk), SSH-direct.

Paper: scp->tmpfs ~4x slower, scp->disk ~18x slower, ssh-direct ~5x slower
than the RDMA staged path. Our container's disk is NVMe-class; scp_disk is
reported twice: honest (native) and throttled to the paper's 2018
disk-array class (120 MB/s), clearly labelled.
"""
from __future__ import annotations

from repro.core.savime import SavimeServer
from repro.core.transfer import run_rdma_staged, run_scp, run_ssh_direct
from benchmarks.common import ci95, csv_row, make_buffers

PAPER_DISK_BW = 120e6  # B/s — 2018 spinning-disk array class


def run(n_files=12, file_mb=4, trials=3, io_threads=2, quiet=False):
    bufs = make_buffers(n_files, file_mb << 20)
    names = [f"f{i}" for i in range(n_files)]
    engines = {
        "rdma_staged": lambda sv, tag: run_rdma_staged(
            bufs, [f"{tag}{n}" for n in names], savime_addr=sv.addr,
            block_size=16 << 20, io_threads=io_threads),
        "scp_mem": lambda sv, tag: run_scp(
            bufs, [f"{tag}{n}" for n in names], savime_addr=sv.addr,
            storage="mem", io_threads=io_threads),
        "scp_disk": lambda sv, tag: run_scp(
            bufs, [f"{tag}{n}" for n in names], savime_addr=sv.addr,
            storage="disk", io_threads=io_threads),
        "scp_disk_paperbw": lambda sv, tag: run_scp(
            bufs, [f"{tag}{n}" for n in names], savime_addr=sv.addr,
            storage="disk", io_threads=io_threads, disk_bw=PAPER_DISK_BW),
        "ssh_direct": lambda sv, tag: run_ssh_direct(
            bufs, [f"{tag}{n}" for n in names], savime_addr=sv.addr,
            io_threads=io_threads),
    }
    out = {}
    for name, fn in engines.items():
        times = []
        for t in range(trials):
            sv = SavimeServer().start()
            try:
                times.append(fn(sv, f"{name}_{t}_").to_staging_s)
            finally:
                sv.stop()
        out[name] = ci95(times)
    base = out["rdma_staged"][0]
    for name, (m, ci) in out.items():
        if not quiet:
            csv_row(f"fig6/{name}", m * 1e6,
                    f"slowdown_vs_rdma={m / base:.2f};ci95={ci * 1e6:.0f}us")
    return out


if __name__ == "__main__":
    run()
