"""Fig 6 + §4 repro: staged-RDMA vs scp (tmpfs), scp (disk), SSH-direct.

Paper: scp->tmpfs ~4x slower, scp->disk ~18x slower, ssh-direct ~5x slower
than the RDMA staged path. Our container's disk is NVMe-class; scp_disk is
reported twice: honest (native) and throttled to the paper's 2018
disk-array class (120 MB/s), clearly labelled.

Every engine is named only by its transport-registry string and driven
through one TransferSession (``repro.transport.run_engine``).
"""
from __future__ import annotations

from repro.core.savime import SavimeServer
from repro.transport import run_engine
from benchmarks.common import ci95, csv_row, engine_cfg, make_buffers

PAPER_DISK_BW = 120e6  # B/s — 2018 spinning-disk array class

# label -> (registry name, extra TransportConfig kwargs)
ENGINE_MATRIX = {
    "rdma_staged": ("rdma_staged", {}),
    "scp_mem": ("scp_mem", {}),
    "scp_disk": ("scp_disk", {}),
    "scp_disk_paperbw": ("scp_disk", {"disk_bw": PAPER_DISK_BW}),
    "ssh_direct": ("ssh_direct", {}),
}


def run(n_files=12, file_mb=4, trials=3, io_threads=2, quiet=False):
    bufs = make_buffers(n_files, file_mb << 20)
    names = [f"f{i}" for i in range(n_files)]
    out = {}
    for label, (engine, extra) in ENGINE_MATRIX.items():
        times = []
        for t in range(trials):
            sv = SavimeServer().start()
            try:
                cfg = engine_cfg(sv.addr, io_threads=io_threads, **extra)
                stats = run_engine(engine, bufs,
                                   [f"{label}_{t}_{n}" for n in names],
                                   cfg, label=label)
                times.append(stats.to_staging_s)
            finally:
                sv.stop()
        out[label] = ci95(times)
    base = out["rdma_staged"][0]
    for label, (m, ci) in out.items():
        if not quiet:
            csv_row(f"fig6/{label}", m * 1e6,
                    f"slowdown_vs_rdma={m / base:.2f};ci95={ci * 1e6:.0f}us")
    return out


if __name__ == "__main__":
    run()
