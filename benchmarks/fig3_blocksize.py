"""Fig 3 repro: elapsed time to staging vs RDMA block size, 1 I/O thread per
client. Paper claim C1: monotone improvement with block size (per-block
registration + control RTT amortize). Clients are TransferSessions on the
``rdma_staged`` transport.

The sweep extends down to 16 KB / 64 KB blocks so the small-block
collapse the paper measures (and the coalescing/binary fast path of
``fig9_coalesce.py`` attacks) is actually on the curve, not just implied
by its left edge."""
from __future__ import annotations

import time

from benchmarks.common import (ci95, csv_row, fresh_stack, make_buffers,
                               staged_sessions)


def run(n_clients=3, n_files=8, file_mb=4, trials=5, io_threads=1,
        blocks_kb=(16, 64, 256, 1024, 4096, 16384), quiet=False):
    bufs = make_buffers(n_clients * n_files, file_mb << 20)
    total = sum(b.nbytes for b in bufs)
    results = {}
    for bk in blocks_kb:
        times = []
        for t in range(trials):
            with fresh_stack() as (sv, st):
                sessions = staged_sessions(st.addr, n_clients,
                                           io_threads=io_threads,
                                           block_size=bk << 10)
                t0 = time.perf_counter()
                for i, sess in enumerate(sessions):
                    for j in range(n_files):
                        sess.write(f"t{t}c{i}f{j}", bufs[i * n_files + j],
                                   dtype="float64")
                for sess in sessions:
                    sess.sync()
                times.append(time.perf_counter() - t0)
                for sess in sessions:
                    sess.close()
        m, ci = ci95(times)
        results[bk] = (m, ci)
        if not quiet:
            csv_row(f"fig3/block_{bk}KB_t{io_threads}", m * 1e6,
                    f"GB/s={total / m / 1e9:.2f};ci95={ci * 1e6:.0f}us")
    return results, total


if __name__ == "__main__":
    run()
