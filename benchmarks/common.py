"""Shared benchmark utilities: trials with 95% CI, servers, sessions,
CSV, and JSON row snapshots (the perf-trajectory gate's input).

All benchmarks go through the transport registry
(:mod:`repro.transport`): an engine is only ever named by its registry
string, so new backends show up in the sweeps without touching callers.
"""
from __future__ import annotations

import math
import statistics
import time
from contextlib import contextmanager

import numpy as np

from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer
from repro.transport import TransferSession, TransportConfig


def ci95(xs: list[float]) -> tuple[float, float]:
    """(mean, 95% CI half-width)."""
    m = statistics.fmean(xs)
    if len(xs) < 2:
        return m, 0.0
    s = statistics.stdev(xs)
    return m, 1.96 * s / math.sqrt(len(xs))


@contextmanager
def fresh_stack(mem_capacity: int = 4 << 30, send_threads: int = 2,
                page_bytes: int = 0, spill_dir=None, dedup: bool = False):
    sv = SavimeServer().start()
    st = StagingServer(sv.addr, mem_capacity=mem_capacity,
                       send_threads=send_threads, page_bytes=page_bytes,
                       spill_dir=spill_dir, dedup=dedup).start()
    try:
        yield sv, st
    finally:
        st.stop()
        sv.stop()


def staged_sessions(staging_addr: str, n_clients: int = 1, *,
                    io_threads: int = 1, block_size: int = 64 << 20,
                    **kw) -> list[TransferSession]:
    """Open ``n_clients`` independent rdma_staged sessions against one
    staging server (the paper's multiple compute nodes)."""
    cfg = TransportConfig(staging_addr=staging_addr, io_threads=io_threads,
                          block_size=block_size, **kw)
    return [TransferSession("rdma_staged", cfg).open()
            for _ in range(n_clients)]


def engine_cfg(savime_addr: str, *, io_threads: int = 2,
               block_size: int = 16 << 20, **kw) -> TransportConfig:
    """Config for a self-contained engine run against a SAVIME endpoint
    (rdma_staged owns its staging server in this mode)."""
    return TransportConfig(savime_addr=savime_addr, io_threads=io_threads,
                           block_size=block_size, **kw)


def make_buffers(n_files: int, file_bytes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(file_bytes // 8) for _ in range(n_files)]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row


def write_rows(path: str, rows: list[dict]) -> None:
    """Persist benchmark rows as pretty JSON (committed as BENCH_*.json
    snapshots; ``benchmarks.check_regression`` gates ratio metrics
    against them)."""
    import json
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
        f.write("\n")
