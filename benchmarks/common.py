"""Shared benchmark utilities: trials with 95% CI, servers, CSV."""
from __future__ import annotations

import math
import statistics
import time
from contextlib import contextmanager

import numpy as np

from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer


def ci95(xs: list[float]) -> tuple[float, float]:
    """(mean, 95% CI half-width)."""
    m = statistics.fmean(xs)
    if len(xs) < 2:
        return m, 0.0
    s = statistics.stdev(xs)
    return m, 1.96 * s / math.sqrt(len(xs))


@contextmanager
def fresh_stack(mem_capacity: int = 4 << 30, send_threads: int = 2):
    sv = SavimeServer().start()
    st = StagingServer(sv.addr, mem_capacity=mem_capacity,
                       send_threads=send_threads).start()
    try:
        yield sv, st
    finally:
        st.stop()
        sv.stop()


def make_buffers(n_files: int, file_bytes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(file_bytes // 8) for _ in range(n_files)]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row, flush=True)
    return row
