"""Fig 4 repro: same sweep with 4 I/O threads per client. Paper claim C2:
faster but less stable (wider CI); large blocks damp the instability.

Rides on fig3's TransferSession sweep — ``io_threads`` maps onto
``TransportConfig.io_threads`` of the ``rdma_staged`` transport."""
from __future__ import annotations

from benchmarks.common import csv_row
from benchmarks import fig3_blocksize


def run(trials=5, quiet=False):
    r1, total = fig3_blocksize.run(trials=trials, io_threads=1, quiet=True)
    r4, _ = fig3_blocksize.run(trials=trials, io_threads=4, quiet=True)
    out = {}
    for bk in r1:
        (m1, c1), (m4, c4) = r1[bk], r4[bk]
        out[bk] = dict(t1=m1, t1_ci=c1, t4=m4, t4_ci=c4,
                       speedup=m1 / m4,
                       rel_ci_1=c1 / m1 if m1 else 0.0,
                       rel_ci_4=c4 / m4 if m4 else 0.0)
        if not quiet:
            csv_row(f"fig4/block_{bk}KB", m4 * 1e6,
                    f"speedup_vs_1thr={m1 / m4:.2f};"
                    f"relCI_1thr={c1 / m1:.3f};relCI_4thr={c4 / m4:.3f}")
    return out, total


if __name__ == "__main__":
    run()
