"""AdamW with global-norm clipping and optional ZeRO-1 state sharding.

ZeRO-1 (zero1=True): first- and second-moment tensors get an *additional*
sharding constraint over the DP axes on their largest divisible,
not-yet-sharded dimension. Under pjit this turns the gradient all-reduce
into reduce-scatter + (post-update) all-gather — same wire bytes, 1/dp the
optimizer-state memory per device (visible in the dry-run memory analysis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, is_spec, param_logical_axes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    zero1: bool = True


def _zero1_axes(axes: tuple, shape: tuple, dp: tuple[str, ...],
                dp_size: int) -> tuple:
    """Pick the largest divisible unsharded dim for the extra DP shard."""
    best, best_size = None, 0
    for i, (a, n) in enumerate(zip(axes, shape)):
        if a in (None, "layers") or a is None:
            if n % dp_size == 0 and n > best_size:
                best, best_size = i, n
    if best is None:
        return axes
    new = list(axes)
    new[best] = "__zero1__"
    return tuple(new)


def make_optimizer(spec_tree: PyTree, cfg: AdamWConfig, mesh=None,
                   rules: Optional[dict] = None):
    """Returns (init_fn(params)->state, update_fn(grads, state, params, lr)
    -> (new_params, new_state, stats))."""
    axes_tree = param_logical_axes(spec_tree)
    dp = tuple(a for a in ("pod", "data") if mesh is not None
               and a in mesh.axis_names)
    dp_size = 1
    if mesh is not None:
        for a in dp:
            dp_size *= mesh.shape[a]
    use_zero1 = cfg.zero1 and mesh is not None and dp_size > 1 and rules

    def moment_constraint(m, axes, shape):
        if not use_zero1:
            return m
        zaxes = _zero1_axes(axes, shape, dp, dp_size)
        r = dict(rules, __zero1__=(dp if len(dp) > 1 else dp[0]))
        spec = jax.sharding.PartitionSpec(
            *[r.get(a) if a else None for a in zaxes])
        try:
            return jax.lax.with_sharding_constraint(m, spec)
        except (ValueError, RuntimeError):
            return m

    def init_fn(params: PyTree) -> dict:
        def zeros_like_sharded(p, axes):
            return moment_constraint(jnp.zeros_like(p), axes, p.shape)
        mu = jax.tree.map(zeros_like_sharded, params, axes_tree)
        nu = jax.tree.map(zeros_like_sharded, params, axes_tree)
        return {"mu": mu, "nu": nu, "count": jnp.zeros((), jnp.int32)}

    def update_fn(grads: PyTree, state: dict, params: PyTree, lr):
        count = state["count"] + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

        def upd(g, m, v, p, axes):
            g = g.astype(jnp.float32) * scale
            # ZeRO: pin grads to the moment layout -> XLA reduce-scatters
            # the DP gradient reduction instead of all-reducing, and the
            # f32 grad buffer is 1/dp per device
            g = moment_constraint(g, axes, p.shape)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            m = moment_constraint(m, axes, p.shape)
            v = moment_constraint(v, axes, p.shape)
            mhat = m / (1 - cfg.b1 ** count.astype(jnp.float32))
            vhat = v / (1 - cfg.b2 ** count.astype(jnp.float32))
            step = mhat / (jnp.sqrt(vhat) + cfg.eps)
            decay = cfg.weight_decay * p.astype(jnp.float32) \
                if p.ndim > 1 else 0.0
            new_p = p.astype(jnp.float32) - lr * (step + decay)
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params,
                           axes_tree)
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x:
                                           isinstance(x, tuple) and
                                           len(x) == 3)
        new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
        new_mu = jax.tree.unflatten(treedef, [l[1] for l in leaves])
        new_nu = jax.tree.unflatten(treedef, [l[2] for l in leaves])
        new_state = {"mu": new_mu, "nu": new_nu, "count": count}
        return new_p, new_state, {"grad_norm": gnorm, "clip_scale": scale}

    return init_fn, update_fn


def opt_state_specs(spec_tree: PyTree, cfg: AdamWConfig, mesh=None,
                    rules: Optional[dict] = None) -> PyTree:
    """ParamSpec tree for the optimizer state (for dry-run / checkpointing
    shardings), mirroring init_fn's (possibly ZeRO-1) layout."""
    dp = tuple(a for a in ("pod", "data") if mesh is not None
               and a in mesh.axis_names)
    dp_size = 1
    if mesh is not None:
        for a in dp:
            dp_size *= mesh.shape[a]
    use_zero1 = cfg.zero1 and dp_size > 1

    def momspec(s: ParamSpec) -> ParamSpec:
        axes = s.logical_axes
        if use_zero1:
            axes = _zero1_axes(axes, s.shape, dp, dp_size)
        return ParamSpec(s.shape, axes, jnp.float32, init="zeros")

    mu = jax.tree.map(momspec, spec_tree, is_leaf=is_spec)
    nu = jax.tree.map(momspec, spec_tree, is_leaf=is_spec)
    return {"mu": mu, "nu": nu,
            "count": ParamSpec((), (), jnp.int32, init="zeros")}
