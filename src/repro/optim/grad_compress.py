"""Compressed cross-pod gradient reduction with error feedback.

The paper's setting has a fast local network and a slow inter-environment
hop; its §6 proposes data reduction before the slow link. The multi-pod
training analogue: the in-pod gradient reduce rides fast ICI, the cross-pod
hop rides slow DCI. We compress exactly that hop:

  * train_step computes grads with the batch sharded over (`data` only) —
    pjit's autodiff all-reduces over `data` within each pod;
  * a shard_map over {`pod`} (other axes stay auto) then performs an int8
    block-quantized reduce-scatter + all-gather over the pod axis with
    per-(pod, block) scales and local error-feedback accumulation, so the
    bf16->int8 quantization error is re-injected next step (convergence-
    safe; standard EF-SGD result).

Wire bytes across pods: 2·N·1 B (int8 RS+AG) vs 2·N·4 B for an fp32 ring
all-reduce -> 4x reduction (+ scales, negligible at block=4096).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any
QBLOCK = 4096


def _quant_blocks(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (n_blocks, QBLOCK) f32 -> (int8, scales f32)."""
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _flatten(tree: PyTree, n_pods: int = 1):
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    pad = (-flat.size) % QBLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    flat2d = flat.reshape(-1, QBLOCK)
    # Pad rows to a multiple of n_pods so the ring reduce-scatter shards
    # evenly — must mirror `error_state`, which sizes the EF residual the
    # same way (g + e in body would otherwise shape-mismatch whenever
    # ceil(n/QBLOCK) % n_pods != 0).
    rpad = (-flat2d.shape[0]) % max(n_pods, 1)
    if rpad:
        flat2d = jnp.pad(flat2d, ((0, rpad), (0, 0)))
    return flat2d, pad + rpad * QBLOCK


def _unflatten(flat2d: jax.Array, pad: int, tree: PyTree) -> PyTree:
    flat = flat2d.reshape(-1)
    if pad:
        flat = flat[:-pad]
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def compressed_pod_allreduce(grads: PyTree, err: jax.Array, mesh):
    """Mean-reduce `grads` over the `pod` mesh axis with int8 compression +
    error feedback. `err`: f32 (n_blocks, QBLOCK) residual carried across
    steps (init zeros via `error_state`). Returns (reduced_grads, new_err).
    """
    n_pods = mesh.shape["pod"]
    flat, pad = _flatten(grads, n_pods)
    n_blocks = flat.shape[0]

    def body(g, e):
        # g, e: per-pod (n_blocks, QBLOCK) f32 (manual over `pod` only)
        g = g + e                                     # error feedback in
        q, s = _quant_blocks(g)
        new_e = g - q.astype(jnp.float32) * s[:, None]  # residual out
        # reduce-scatter over pods: pod p owns rows [p::n_pods]
        mine = jax.lax.axis_index("pod")
        # exchange int8 shards: psum of dequantized own-shard contributions
        # via ppermute ring (int8 on the wire)
        shard_rows = n_blocks // n_pods
        my_rows = jax.lax.dynamic_slice_in_dim(q, mine * shard_rows,
                                               shard_rows, 0)
        my_scale = jax.lax.dynamic_slice_in_dim(s, mine * shard_rows,
                                                shard_rows, 0)
        acc = my_rows.astype(jnp.float32) * my_scale[:, None]
        qr, sr = q, s
        for hop in range(1, n_pods):
            perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
            qr = jax.lax.ppermute(qr, "pod", perm)        # int8 wire
            sr = jax.lax.ppermute(sr, "pod", perm)
            rows = jax.lax.dynamic_slice_in_dim(qr, mine * shard_rows,
                                                shard_rows, 0)
            sc = jax.lax.dynamic_slice_in_dim(sr, mine * shard_rows,
                                              shard_rows, 0)
            acc = acc + rows.astype(jnp.float32) * sc[:, None]
        acc = acc / n_pods
        # all-gather the reduced shards (int8 wire again)
        qa, sa = _quant_blocks(acc)
        q_all = jax.lax.all_gather(qa, "pod", tiled=True)   # (n_blocks, QB)
        s_all = jax.lax.all_gather(sa, "pod", tiled=True)
        out = q_all.astype(jnp.float32) * s_all[:, None]
        return out, new_e

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), axis_names={"pod"},
                       check_vma=False)
    reduced, new_err = fn(flat, err)
    return _unflatten(reduced, pad, grads), new_err


def error_state(grads_abstract: PyTree, n_pods: int = 1) -> jax.ShapeDtypeStruct:
    n = sum(int(jnp.prod(jnp.asarray(l.shape)))
            for l in jax.tree.leaves(grads_abstract))
    n += (-n) % QBLOCK
    rows = n // QBLOCK
    rows += (-rows) % max(n_pods, 1)   # ring reduce-scatter row padding
    return jax.ShapeDtypeStruct((rows, QBLOCK), jnp.float32)
