from repro.optim.optimizer import AdamWConfig, make_optimizer  # noqa: F401
from repro.optim.schedule import constant, warmup_cosine  # noqa: F401
