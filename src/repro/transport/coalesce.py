"""Adaptive small-dataset coalescing for the egress path (DESIGN.md §10).

The paper's Fig 3 pathology: as datasets shrink, per-dataset protocol
costs (reservation round-trip, registration, framing, syscalls) stop
amortizing and throughput collapses. ADIOS2/DataSpaces-style staging
systems attack this by aggregating many small writes into fixed-format
jumbo messages; this module is that aggregation layer for every engine
that opts in via ``TransportConfig(coalesce_bytes=..., linger_ms=...)``.

    Coalescer(flush_fn, coalesce_bytes=1 << 20, linger_ms=2.0)

``add(name, dtype, buf)`` buffers one dataset below the threshold and
returns a :class:`~repro.core.queues.TaskHandle` that completes when its
batch lands. A batch flushes when

  * **size** — buffered bytes reach ``coalesce_bytes`` (or ``max_items``
    datasets), the jumbo frame is full;
  * **linger** — ``linger_ms`` elapsed since the first buffered dataset,
    bounding the latency a small write can be held back;
  * **close / sync** — lifecycle barriers never leave datasets behind.

``flush_fn(items)`` performs the actual transfer (one vectored
``batch_open`` + ``batch_write`` round-trip on the staged path); the
coalescer completes or fails every handle in the batch and serializes
flushes on one worker thread, so ``flush_fn`` needs no locking of its
own. Datasets at or above the threshold must bypass the coalescer
entirely — callers keep their existing block/striped path, which is why
``coalesce_bytes=0`` (the default) is byte-identical legacy behavior.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

from repro.core.queues import TaskHandle

DEFAULT_LINGER_MS = 2.0
DEFAULT_MAX_ITEMS = 512


@dataclasses.dataclass
class CoalesceItem:
    """One buffered small dataset awaiting its batch."""

    name: str
    dtype: str
    buf: object            # flat uint8 view of the caller's buffer
    nbytes: int
    handle: TaskHandle
    extra: Optional[dict] = None   # codec header fields riding batch_open


class Coalescer:
    """Batches sub-threshold datasets into jumbo flushes."""

    # ``stats`` is only touched by the single worker thread; reads from
    # other threads are monitoring-only, so it stays unguarded.
    _GUARDED_BY = {
        "_pending": "_cond",
        "_pending_bytes": "_cond",
        "_deadline": "_cond",
        "_force": "_cond",
        "_inflight": "_cond",
        "_stop": "_cond",
    }

    def __init__(self, flush_fn: Callable[[list], None],
                 coalesce_bytes: int,
                 linger_ms: float = DEFAULT_LINGER_MS,
                 max_items: int = DEFAULT_MAX_ITEMS):
        if coalesce_bytes <= 0:
            raise ValueError("Coalescer needs coalesce_bytes > 0 "
                             "(0 disables coalescing at the caller)")
        self.coalesce_bytes = coalesce_bytes
        self.linger_s = max(linger_ms, 0.0) / 1e3
        self.max_items = max(1, max_items)
        self._flush_fn = flush_fn
        self._cond = threading.Condition()
        self._pending: list[CoalesceItem] = []
        self._pending_bytes = 0
        self._deadline: Optional[float] = None   # linger expiry of batch 0
        self._force = False
        self._inflight = 0                       # batches inside flush_fn
        self._stop = False
        self.stats = {"batches": 0, "datasets": 0, "bytes": 0, "failures": 0}
        self._worker = threading.Thread(target=self._run, name="coalescer",
                                        daemon=True)
        self._worker.start()

    # -- producer side --------------------------------------------------
    def add(self, name: str, dtype: str, buf, nbytes: int,
            extra: Optional[dict] = None) -> TaskHandle:
        """Buffer one small dataset; returns its completion handle."""
        handle = TaskHandle(self._flush_fn, (), name=f"coalesce-{name}")
        item = CoalesceItem(name, dtype, buf, nbytes, handle, extra)
        with self._cond:
            if self._stop:
                raise RuntimeError("Coalescer is closed")
            if not self._pending:
                self._deadline = time.monotonic() + self.linger_s
            self._pending.append(item)
            self._pending_bytes += nbytes
            self._cond.notify_all()
        return handle

    def flush(self) -> None:
        """Request an asynchronous flush of whatever is buffered now."""
        with self._cond:
            self._force = True
            self._cond.notify_all()

    def sync(self, timeout: Optional[float] = None) -> None:
        """Flush and block until every added dataset's batch completed
        (successfully or not — per-item failures live on the handles)."""
        deadline = time.monotonic() + timeout if timeout else None
        with self._cond:
            self._force = True
            self._cond.notify_all()
            while self._pending or self._inflight:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"coalescer sync: {len(self._pending)} buffered "
                            f"+ {self._inflight} in-flight batches")
                self._cond.wait(remaining)

    def close(self, timeout: float = 30.0) -> None:
        """Flush everything still buffered, then stop the worker."""
        with self._cond:
            self._stop = True
            self._force = True
            self._cond.notify_all()
        self._worker.join(timeout)
        # a worker that died anyway must not strand handles forever
        with self._cond:
            stranded, self._pending = self._pending, []
            self._pending_bytes = 0
        for it in stranded:
            it.handle.complete(error=RuntimeError("coalescer closed"))

    def pending(self) -> int:
        with self._cond:
            return len(self._pending)

    # -- worker ---------------------------------------------------------
    def _due(self) -> bool:  # holds: self._cond
        if not self._pending:
            return False
        return (self._force
                or self._pending_bytes >= self.coalesce_bytes
                or len(self._pending) >= self.max_items
                or (self._deadline is not None
                    and time.monotonic() >= self._deadline))

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._due():
                    if self._stop:
                        return
                    timeout = None
                    if self._pending and self._deadline is not None:
                        timeout = max(self._deadline - time.monotonic(),
                                      0.0) or 0.001
                    self._cond.wait(timeout)
                batch, self._pending = self._pending, []
                self._pending_bytes = 0
                self._deadline = None
                if not self._stop:
                    self._force = False
                self._inflight += 1
            try:
                self._flush_fn(batch)
            except BaseException as e:  # noqa: BLE001 — fail the batch
                self.stats["failures"] += 1
                for it in batch:
                    it.handle.complete(error=e)
            else:
                self.stats["batches"] += 1
                self.stats["datasets"] += len(batch)
                self.stats["bytes"] += sum(it.nbytes for it in batch)
                for it in batch:
                    it.handle.complete(result=it.nbytes)
            finally:
                with self._cond:
                    self._inflight -= 1
                    self._cond.notify_all()
