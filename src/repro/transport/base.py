"""Transport abstraction — one lifecycle for every egress path.

The paper compares a staged-RDMA pipeline against scp/ssh baselines; the
repo previously exposed three disjoint APIs for the same act of "move
blocks from compute to analysis" (StagingClient+Dataset, the run_* engine
functions, InTransitSink). This module defines the single contract they
all sit on now — in the spirit of ADIOS2's engine-agnostic IO API:

    Transport        abstract lifecycle: open / write / sync / drain / close
    TransportConfig  typed configuration shared by every engine
    TransferStats    per-phase timings (replaces the old TransferResult)
    registry         string-keyed: @register_transport / create / available

Engines register themselves by name; ``create("scp_disk", cfg)`` is the
only way an engine is named. User code goes through
:class:`repro.transport.TransferSession`, which layers buffer pinning,
backpressure and futures on top of any registered transport.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Callable, Optional

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Configuration shared by all transports.

    Engines ignore fields that do not apply to them (e.g. ``disk_bw`` only
    matters to ``scp_disk``); unknown one-off knobs go in ``extra``.
    """

    savime_addr: Optional[str] = None     # analytical endpoint (host:port)
    staging_addr: Optional[str] = None    # existing staging server, if any
    block_size: int = 64 << 20            # RDMA block knob (paper Fig 3)
    io_threads: int = 1                   # client-side FCFS I/O threads
    send_threads: int = 2                 # staging->SAVIME / forward threads
    mem_capacity: int = 8 << 30           # staging tmpfs capacity
    disk_bw: Optional[float] = None       # B/s cap for scp_disk (paper HW)
    straggler_timeout: Optional[float] = None
    max_inflight_bytes: Optional[int] = None  # session backpressure bound
    n_channels: int = 1                   # striped connections (1 = off)
    stripe_bytes: Optional[int] = None    # stripe size (None = block_size)
    credits: int = 4                      # per-channel credit window request
    wire_format: str = "json"             # "json" (legacy) | "bin1" fast path
    coalesce_bytes: int = 0               # datasets below this batch (0 = off)
    linger_ms: float = 2.0                # coalescing flush window
    page_bytes: int = 0                   # paged staging page size (0 = flat)
    spill_dir: Optional[str] = None       # cold-page spill tier (paged mode)
    dedup: bool = False                   # content-addressed page dedup
    gateway_addr: Optional[str] = None    # staging gateway (DESIGN.md §12);
    #                                       set => data admits via the pool
    tenant: Optional[str] = None          # tenant token for gateway auth
    codec: str = "none"                   # egress reduction codec (§13)
    decode_at: str = "staging"            # "staging" (ingest) | "query"
    #                                       (store compressed, lazy decode)
    retry: int = 3                        # transfer retries per write (§15)
    deadline_s: Optional[float] = None    # retry budget per write (None = off)
    journal: bool = True                  # in-flight journal + replay on
    #                                       reconnect (replay-capable engines)
    extra: dict = dataclasses.field(default_factory=dict)

    def replace(self, **kw) -> "TransportConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class TransferStats:
    """Per-phase timings for one session (replaces ``TransferResult``).

    The first five fields keep the old TransferResult layout so legacy
    positional construction and attribute access keep working.
    """

    engine: str
    nbytes: int = 0
    n_datasets: int = 0
    to_staging_s: float = 0.0       # first write -> sync complete
    end_to_end_s: float = 0.0       # first write -> drain complete
    open_s: float = 0.0             # transport.open() wall time
    close_s: float = 0.0            # transport.close() wall time
    write_wait_s: float = 0.0       # time write() spent blocked (backpressure)
    peak_inflight_bytes: int = 0    # high-water mark of pinned bytes
    # per-channel byte/latency breakdowns when the transport stripes over
    # multiple connections (empty on single-connection paths)
    channels: list = dataclasses.field(default_factory=list)
    # page/spill/dedup counters when the staging area runs the paged
    # store (cfg.page_bytes > 0); empty on the flat path
    pages: dict = dataclasses.field(default_factory=dict)
    # fleet snapshot (placement/tenancy/admission totals) when the session
    # rode a staging gateway (cfg.gateway_addr); empty otherwise
    gateway: dict = dataclasses.field(default_factory=dict)
    # egress-codec accounting (raw vs wire bytes, encode time) when a
    # reduction codec is configured (cfg.codec != "none"); empty otherwise
    codec: dict = dataclasses.field(default_factory=dict)
    # durability accounting (DESIGN.md §15): writes replayed from the
    # in-flight journal after a reconnect, and replays the receiver
    # recognised as already-acked epochs (no double ingest)
    replays: int = 0
    replay_dups: int = 0

    @property
    def staging_gbps(self) -> float:
        return self.nbytes / max(self.to_staging_s, 1e-9) / 1e9

    @property
    def end_to_end_gbps(self) -> float:
        return self.nbytes / max(self.end_to_end_s, 1e-9) / 1e9

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["staging_gbps"] = self.staging_gbps
        d["end_to_end_gbps"] = self.end_to_end_gbps
        return d

    @classmethod
    def merge(cls, stats: "list[TransferStats] | tuple") -> "TransferStats":
        """Combine per-rank/per-session stats into one fleet view.

        Additive fields (bytes, datasets, blocked/open/close time) sum;
        wall-clock phases (``to_staging_s``, ``end_to_end_s``) take the
        max — concurrent sessions overlap, so summing them would invent
        serial time; ``peak_inflight_bytes`` also maxes (a high-water
        mark, not a flow); per-channel rows concatenate.
        """
        stats = list(stats)
        if not stats:
            return cls(engine="merged")
        out = cls(engine=stats[0].engine if len(
            {s.engine for s in stats}) == 1 else "merged")
        for s in stats:
            out.nbytes += s.nbytes
            out.n_datasets += s.n_datasets
            out.open_s += s.open_s
            out.close_s += s.close_s
            out.write_wait_s += s.write_wait_s
            out.to_staging_s = max(out.to_staging_s, s.to_staging_s)
            out.end_to_end_s = max(out.end_to_end_s, s.end_to_end_s)
            out.peak_inflight_bytes = max(out.peak_inflight_bytes,
                                          s.peak_inflight_bytes)
            out.replays += s.replays
            out.replay_dups += s.replay_dups
            out.channels.extend(s.channels)
            if s.gateway:
                out.gateway = dict(s.gateway)   # latest fleet snapshot
            if s.codec:
                c = out.codec
                c["name"] = s.codec.get("name", c.get("name"))
                for k in ("raw_bytes", "wire_bytes", "datasets",
                          "fallbacks"):
                    c[k] = c.get(k, 0) + int(s.codec.get(k, 0))
                c["encode_s"] = c.get("encode_s", 0.0) + \
                    float(s.codec.get("encode_s", 0.0))
        return out


# ---------------------------------------------------------------------------
# transport lifecycle
# ---------------------------------------------------------------------------


class Transport(abc.ABC):
    """Abstract egress engine: open / write / sync / drain / close.

    ``write`` is asynchronous and returns a handle with
    ``wait(timeout)`` / ``done`` / ``add_done_callback`` semantics (the
    FCFS :class:`~repro.core.queues.TaskHandle` satisfies this).  ``sync``
    blocks until every written buffer has reached the staging area (the
    paper's ``st.sync()``); ``drain`` blocks until data is queryable at
    the analytical endpoint.  Transports are single-open: ``close`` ends
    the lifecycle.
    """

    name: str = "abstract"
    # engines that thread a producer-assigned (name, epoch) identity down
    # to the receiver (idempotent replay, DESIGN.md §15) set this True
    # and override write_epoch; the session only journals writes when the
    # engine can actually replay them safely
    supports_replay: bool = False

    def __init__(self, cfg: TransportConfig):
        self.cfg = cfg

    @abc.abstractmethod
    def open(self) -> None:
        """Allocate connections / servers. Idempotence not required."""

    @abc.abstractmethod
    def write(self, name: str, dtype: str, buf) -> Any:
        """Enqueue one named buffer; returns a completion handle."""

    def write_epoch(self, name: str, dtype: str, buf, epoch: str,
                    replay: bool = False) -> Any:
        """``write`` carrying a replay identity. Engines without epoch
        support fall back to a plain write (``supports_replay`` stays
        False, so the session never journals against them)."""
        return self.write(name, dtype, buf)

    @abc.abstractmethod
    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until all written buffers reached staging."""

    @abc.abstractmethod
    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until all staged data is queryable at the endpoint."""

    @abc.abstractmethod
    def close(self) -> None:
        """Release sockets, pools and owned servers."""

    # -- optional control-plane hooks ----------------------------------
    def run_savime(self, q: str):
        """Run an analytical (SAVIME) operator, if this transport has a
        control path to the endpoint."""
        raise NotImplementedError(
            f"transport {self.name!r} has no analytical control path")

    def server_stats(self) -> dict:
        """Remote-side counters, when the transport exposes them."""
        return {}

    def channel_stats(self) -> list[dict]:
        """Per-channel breakdowns when this transport stripes across
        multiple connections (``cfg.n_channels > 1``); empty otherwise."""
        return []

    def page_stats(self) -> dict:
        """Page/spill/dedup counters when the staging side runs the paged
        store (``cfg.page_bytes > 0``); empty otherwise."""
        return {}

    def gateway_stats(self) -> dict:
        """Fleet snapshot (placement, tenancy, admission totals) when the
        transport rides a staging gateway (``cfg.gateway_addr``); empty
        otherwise."""
        return {}

    def codec_stats(self) -> dict:
        """Egress-codec accounting (raw vs wire bytes, encode time) when a
        reduction codec is configured (``cfg.codec != "none"``); empty
        otherwise."""
        return {}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class UnknownTransportError(KeyError):
    pass


_REGISTRY: dict[str, type] = {}


def register_transport(name: str) -> Callable[[type], type]:
    """Class decorator: ``@register_transport("scp_mem")``."""

    def deco(cls: type) -> type:
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"transport {name!r} already registered "
                             f"({_REGISTRY[name].__name__})")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available() -> tuple[str, ...]:
    """Registered engine names, sorted."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownTransportError(
            f"unknown transport {name!r}; available: {', '.join(available())}"
        ) from None


def create(name: str, cfg: TransportConfig) -> Transport:
    """Instantiate a registered transport (does not open it)."""
    return get(name)(cfg)
