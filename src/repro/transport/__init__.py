# Unified egress subsystem: one Transport lifecycle (open/write/sync/
# drain/close) behind a string-keyed registry, and TransferSession — the
# single user-facing way to move blocks from compute to analysis. The
# paper's staged-RDMA pipeline and its scp/ssh baselines are peers here;
# `create("scp_disk", cfg)` is the only way an engine is named.
# See DESIGN.md §7 for the API and the migration table from the old
# entry points (StagingClient+Dataset / run_* / InTransitSink internals).
#
# NB: base and session must be imported before the engine modules — the
# engine modules pull in repro.core, which re-enters this package for
# TransferSession/TransportConfig.
from repro.transport.base import (  # noqa: F401
    Transport, TransportConfig, TransferStats, UnknownTransportError,
    available, create, get, register_transport,
)
from repro.transport.session import (  # noqa: F401
    DatasetFuture, TransferSession, run_engine,
)
from repro.transport.channels import (  # noqa: F401
    ChannelGroup, ChannelStats,
)
from repro.transport import staged as _staged  # noqa: F401  (registers rdma_staged)
from repro.transport import copyemu as _copyemu  # noqa: F401  (registers scp_*, ssh_direct)
