"""``rdma_staged`` — the paper's pipeline as a registered Transport.

compute --libstaging(async, RDMA-emulated one-sided block writes)-->
staging tmpfs --(sendfile, FCFS pool)--> SAVIME.

Connects to an existing staging server (``cfg.staging_addr``) or owns a
fresh one against ``cfg.savime_addr`` (benchmark mode); an owned server is
stopped on close.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.core import wire
from repro.core.client import Communicator
from repro.core.staging import StagingServer
from repro.transport.base import Transport, register_transport


@register_transport("rdma_staged")
class StagedTransport(Transport):
    """Staged-RDMA egress over libstaging's Communicator."""

    # the Communicator threads (name, epoch) through write_req /
    # stripe_open / batch items and the server dedups on it — the
    # session's in-flight journal can replay safely (DESIGN.md §15)
    supports_replay = True

    def __init__(self, cfg):
        super().__init__(cfg)
        self._staging: Optional[StagingServer] = None   # owned, if any
        self.comm: Optional[Communicator] = None
        self._ctrl = None
        self._ctrl_lock = threading.Lock()

    # -- lifecycle ------------------------------------------------------
    def open(self) -> None:
        gateway = self.cfg.gateway_addr is not None
        if gateway:
            # pool mode (DESIGN.md §12): the gateway is the one address —
            # data admits per dataset (redirect protocol) and the control
            # conn rides the gateway so drain/run_savime/stats see the
            # whole fleet
            addr = self.cfg.gateway_addr
        else:
            addr = self.cfg.staging_addr
            if addr is None:
                if self.cfg.savime_addr is None:
                    raise ValueError("rdma_staged needs staging_addr "
                                     "(attach), savime_addr (own a staging "
                                     "server) or gateway_addr (pool)")
                self._staging = StagingServer(
                    self.cfg.savime_addr,
                    mem_capacity=self.cfg.mem_capacity,
                    send_threads=self.cfg.send_threads,
                    straggler_timeout=self.cfg.straggler_timeout,
                    page_bytes=self.cfg.page_bytes,
                    spill_dir=self.cfg.spill_dir,
                    dedup=self.cfg.dedup).start()
                addr = self._staging.addr
        self.comm = Communicator(addr, self.cfg.io_threads,
                                 self.cfg.block_size,
                                 self.cfg.straggler_timeout,
                                 n_channels=self.cfg.n_channels,
                                 stripe_bytes=self.cfg.stripe_bytes,
                                 credits=self.cfg.credits,
                                 wire_format=self.cfg.wire_format,
                                 coalesce_bytes=self.cfg.coalesce_bytes,
                                 linger_ms=self.cfg.linger_ms,
                                 gateway=gateway, tenant=self.cfg.tenant,
                                 codec=self.cfg.codec,
                                 decode_at=self.cfg.decode_at,
                                 retry=self.cfg.retry,
                                 deadline_s=self.cfg.deadline_s)
        self._ctrl = wire.connect(addr)
        if gateway and self.cfg.tenant:
            # bind the control conn to the tenant for proxied/DDL ops
            with self._ctrl_lock:  # lint: ignore[io-under-lock]
                wire.request(self._ctrl, {"op": "hello",
                                          "tenant": self.cfg.tenant})

    def close(self) -> None:
        if self.comm is not None:
            self.comm.stop()
        if self._ctrl is not None:
            try:
                self._ctrl.close()
            except OSError:
                pass
        if self._staging is not None:
            self._staging.stop()

    # -- data plane -----------------------------------------------------
    def write(self, name: str, dtype: str, buf):
        return self.comm.submit(name, dtype, buf)

    def write_epoch(self, name: str, dtype: str, buf, epoch: str,
                    replay: bool = False):
        return self.comm.submit(name, dtype, buf, epoch=epoch,
                                replay=replay)

    def sync(self, timeout: Optional[float] = None) -> None:
        self.comm.sync(timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        self._ctrl_request({"op": "drain", "timeout": timeout})

    # -- control plane --------------------------------------------------
    def run_savime(self, q: str):
        """Proxy a SAVIME operator through staging (compute nodes cannot
        reach the analytical network directly — paper §3.1)."""
        return self._ctrl_request({"op": "run_savime", "q": q}).get("result")

    def server_stats(self) -> dict:
        return self._ctrl_request({"op": "stats"})

    def channel_stats(self) -> list[dict]:
        return self.comm.channel_stats() if self.comm is not None else []

    def page_stats(self) -> dict:
        """Staging-side page/spill/dedup counters (paged store only)."""
        try:
            return self._ctrl_request({"op": "stats"}).get("pages") or {}
        except (RuntimeError, OSError):
            return {}

    def codec_stats(self) -> dict:
        """Sender-side codec accounting (raw vs wire bytes, encode time);
        empty when ``cfg.codec == "none"``."""
        return self.comm.codec_stats() if self.comm is not None else {}

    def gateway_stats(self) -> dict:
        """Fleet snapshot from the gateway ``stats`` op (placement,
        tenancy, per-backend admission totals); empty off-gateway."""
        if self.cfg.gateway_addr is None:
            return {}
        try:
            h = self._ctrl_request({"op": "stats"})
        except (RuntimeError, OSError):
            return {}
        return {k: v for k, v in h.items()
                if k not in ("ok", "nbytes")}

    def _ctrl_request(self, header: dict) -> dict:
        # the lock serializes request/reply pairs on the shared control
        # conn — blocking under it is the point
        with self._ctrl_lock:  # lint: ignore[io-under-lock]
            h, _ = wire.request(self._ctrl, header)
        if not h.get("ok"):
            from repro.gateway.tenancy import error_from_reply
            raise error_from_reply(h, "staging error")
        return h
