"""TransferSession — the one user-facing way to move data to analysis.

    from repro.transport import TransferSession, TransportConfig

    cfg = TransportConfig(staging_addr=staging.addr, io_threads=2)
    with TransferSession("rdma_staged", cfg) as sess:
        fut = sess.write("D", array)        # non-blocking, returns a future
        sess.sync()                         # all writes reached staging
        sess.drain()                        # queryable at the endpoint
    print(sess.stats.staging_gbps)

On top of any registered :class:`~repro.transport.base.Transport` the
session owns:

  * buffer pinning — a written buffer is referenced until its transfer
    completes (the paper's "must not be mutated until sync()" contract);
  * backpressure — ``cfg.max_inflight_bytes`` bounds pinned bytes;
    ``write`` blocks when the bound would be exceeded (a producer can
    never run arbitrarily far ahead of the network);
  * futures — every ``write`` returns a :class:`DatasetFuture`;
  * metrics — :class:`~repro.transport.base.TransferStats` with per-phase
    timings, plus optional ``on_event`` hooks for live instrumentation.
"""
from __future__ import annotations

import queue
import secrets
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.transport.base import (Transport, TransportConfig, TransferStats,
                                  create)


class DatasetFuture:
    """Completion future for one written dataset."""

    def __init__(self, name: str, nbytes: int, handle):
        self.name = name
        self.nbytes = nbytes
        self._handle = handle

    def wait(self, timeout: Optional[float] = None):
        """Block until this dataset reached staging; raises on failure."""
        return self._handle.wait(timeout)

    def done(self) -> bool:
        return self._handle.done.is_set()

    def add_done_callback(self, fn: Callable) -> None:
        self._handle.add_done_callback(lambda _h: fn(self))


class _ReplayHandle:
    """TaskHandle-shaped facade whose completion survives replays.

    The journal swaps the *inner* transport handle on every replay; this
    outer handle is what the :class:`DatasetFuture` holds, and it
    completes exactly once — with the first definitive outcome."""

    def __init__(self, name: str):
        self.name = name
        self.done = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._callbacks: list = []

    def complete(self, result=None, error=None) -> None:
        with self._lock:
            if self.done.is_set():
                return
            self.result, self.error = result, error
            callbacks, self._callbacks = self._callbacks, []
            self.done.set()
        for fn in callbacks:
            try:
                fn(self)
            except Exception:  # noqa: BLE001 — callbacks must not break acks
                pass

    def wait(self, timeout: Optional[float] = None):
        if not self.done.wait(timeout):
            raise TimeoutError(f"transfer {self.name!r} still in flight")
        if self.error is not None:
            raise self.error
        return self.result

    def add_done_callback(self, fn: Callable) -> None:
        with self._lock:
            if not self.done.is_set():
                self._callbacks.append(fn)
                return
        fn(self)


class _Journaled:
    """One in-flight journal entry: everything needed to replay a write."""

    __slots__ = ("name", "dtype", "arr", "epoch", "outer", "deadline",
                 "attempts")

    def __init__(self, name, dtype, arr, epoch, outer, deadline):
        self.name = name
        self.dtype = dtype
        self.arr = arr              # the pinned buffer — the replay source
        self.epoch = epoch
        self.outer = outer
        self.deadline = deadline
        self.attempts = 0


class TransferSession:
    """Context manager owning one transport lifecycle.

    May also be used non-contextually: ``sess = TransferSession(...).open()``
    then ``sess.close()``. On clean context exit the session syncs and
    drains before closing (durability by default); on exception it closes
    immediately.
    """

    def __init__(self, transport: "str | Transport",
                 cfg: Optional[TransportConfig] = None, *,
                 label: Optional[str] = None,
                 on_event: Optional[Callable[[dict], None]] = None):
        if isinstance(transport, Transport):
            self.transport = transport
        else:
            self.transport = create(transport, cfg or TransportConfig())
        self.cfg = self.transport.cfg
        self.stats = TransferStats(engine=label or self.transport.name)
        self.hooks: list[Callable[[dict], None]] = [on_event] if on_event else []
        self._opened = False
        self._closed = False
        self._t0: Optional[float] = None          # first-write clock
        self._unsynced = False                    # writes since last sync?
        self._undrained = False                   # writes since last drain?
        self._cond = threading.Condition()
        self._inflight = 0                        # pinned, not yet completed
        self._pinned: dict[int, object] = {}      # future id -> buffer ref
        # in-flight journal (DESIGN.md §15): every submitted dataset keeps
        # its pinned buffer under a monotonic (name, epoch) identity until
        # acked; a retryable failure re-submits it through the replay
        # worker and the receiver dedups on the epoch. Active only when
        # the engine can thread the epoch through (supports_replay).
        self._journal_on = bool(self.cfg.journal and
                                self.transport.supports_replay)
        self._journal: dict[str, _Journaled] = {}     # epoch -> entry
        self._epoch_tag = secrets.token_hex(4)
        self._epoch_seq = 0
        self._max_replays = max(1, self.cfg.retry)
        self._replay_q: queue.Queue = queue.Queue()
        self._replay_worker: Optional[threading.Thread] = None
        self._close_evt = threading.Event()

    # -- lifecycle ------------------------------------------------------
    def open(self) -> "TransferSession":
        if self._opened:
            return self
        t = time.perf_counter()
        self.transport.open()
        self.stats.open_s = time.perf_counter() - t
        self._opened = True
        if self._journal_on:
            self._replay_worker = threading.Thread(
                target=self._replay_loop, name="session-replay", daemon=True)
            self._replay_worker.start()
        self._emit("open")
        return self

    def __enter__(self) -> "TransferSession":
        return self.open()

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.sync()
            self.drain()
        self.close()

    def close(self) -> None:
        if self._closed or not self._opened:
            self._closed = True
            return
        self._close_evt.set()
        if self._replay_worker is not None:
            self._replay_q.put(None)              # shutdown sentinel
            self._replay_worker.join(5.0)
            self._replay_worker = None
        self._collect_durability_stats()
        self._collect_channel_stats()
        self._collect_page_stats()
        self._collect_gateway_stats()
        self._collect_codec_stats()
        t = time.perf_counter()
        try:
            self.transport.close()
        finally:
            self._closed = True
            self.stats.close_s = time.perf_counter() - t
            if self._t0 is not None and self.stats.end_to_end_s == 0.0:
                self.stats.end_to_end_s = t - self._t0
            self._emit("close")

    # -- data plane -----------------------------------------------------
    def write(self, name: str, buf, dtype: Optional[str] = None,
              nbytes: Optional[int] = None) -> DatasetFuture:
        """Non-blocking enqueue of one named buffer.

        Blocks only when ``cfg.max_inflight_bytes`` would be exceeded
        (backpressure); a single buffer larger than the bound is admitted
        alone rather than deadlocking.
        """
        self._check_live()
        arr = buf if isinstance(buf, np.ndarray) else \
            np.frombuffer(buf, dtype=np.uint8)
        if nbytes is not None:
            arr = arr.reshape(-1).view(np.uint8)[:nbytes]
        dtype = dtype or str(arr.dtype)
        size = arr.nbytes
        limit = self.cfg.max_inflight_bytes
        t_wait = time.perf_counter()
        with self._cond:
            while limit and self._inflight > 0 and \
                    self._inflight + size > limit:
                self._cond.wait(0.5)
            self._inflight += size
            self.stats.peak_inflight_bytes = max(
                self.stats.peak_inflight_bytes, self._inflight)
        self.stats.write_wait_s += time.perf_counter() - t_wait
        if self._t0 is None:
            self._t0 = time.perf_counter()
        epoch = None
        if self._journal_on:
            with self._cond:
                self._epoch_seq += 1
                epoch = f"{self._epoch_tag}-{self._epoch_seq}"
        try:
            if epoch is not None:
                entry = _Journaled(
                    name, dtype, arr, epoch, _ReplayHandle(name),
                    deadline=(time.monotonic() + self.cfg.deadline_s
                              if self.cfg.deadline_s else None))
                with self._cond:
                    self._journal[epoch] = entry
                inner = self.transport.write_epoch(name, dtype, arr, epoch)
                inner.add_done_callback(self._journal_chain(entry))
                handle = entry.outer
            else:
                handle = self.transport.write(name, dtype, arr)
        except BaseException:
            # striped transports can fail synchronously (stripe_open is a
            # control RTT); the reserved inflight bytes must be returned
            # or later writes block against a phantom reservation
            with self._cond:
                if epoch is not None:
                    self._journal.pop(epoch, None)
                self._inflight -= size
                self._cond.notify_all()
            raise
        fut = DatasetFuture(name, size, handle)
        with self._cond:
            self._pinned[id(fut)] = arr           # pin until completion
        handle.add_done_callback(lambda _h: self._release(fut))
        self._unsynced = self._undrained = True
        self.stats.nbytes += size
        self.stats.n_datasets += 1
        self._emit("write", name=name, nbytes=size)
        return fut

    def write_all(self, names: Sequence[str], buffers: Sequence) \
            -> list[DatasetFuture]:
        return [self.write(n, b) for n, b in zip(names, buffers)]

    def _release(self, fut: DatasetFuture) -> None:
        with self._cond:
            if self._pinned.pop(id(fut), None) is not None:
                self._inflight -= fut.nbytes
            self._cond.notify_all()

    # -- in-flight journal (DESIGN.md §15) -------------------------------
    def _journal_chain(self, entry: _Journaled) -> Callable:
        """Done-callback for one inner transport handle: settle the entry
        (ack, replay, or give up) when the attempt finishes."""
        return lambda h: self._settle(entry, getattr(h, "error", None),
                                      getattr(h, "result", None))

    def _settle(self, entry: _Journaled, err, result=None) -> None:
        if err is None:
            with self._cond:
                self._journal.pop(entry.epoch, None)
                self._cond.notify_all()
            entry.outer.complete(result=result)
            return
        retryable = isinstance(err, (ConnectionError, TimeoutError, OSError))
        expired = entry.deadline is not None and \
            time.monotonic() > entry.deadline
        if retryable and not expired and \
                entry.attempts < self._max_replays and \
                not self._close_evt.is_set():
            self._replay_q.put(entry.epoch)
            return
        with self._cond:
            self._journal.pop(entry.epoch, None)
            self._cond.notify_all()
        entry.outer.complete(error=err)

    def _replay_loop(self) -> None:
        """Single worker re-submitting failed journal entries with
        exponential backoff. The receiver dedups on (name, epoch), so a
        replay of a write whose ack was merely lost is a no-op there."""
        while True:
            epoch = self._replay_q.get()
            if epoch is None:
                return
            with self._cond:
                entry = self._journal.get(epoch)
            if entry is None:
                continue                 # settled while queued
            entry.attempts += 1
            self.stats.replays += 1
            delay = min(2.0, 0.05 * (1 << min(entry.attempts, 6)))
            if self._close_evt.wait(delay):
                return
            self._emit("replay", name=entry.name, epoch=epoch,
                       attempt=entry.attempts)
            try:
                inner = self.transport.write_epoch(
                    entry.name, entry.dtype, entry.arr, epoch, replay=True)
            except Exception as e:  # noqa: BLE001 — settle decides
                self._settle(entry, e)
                continue
            inner.add_done_callback(self._journal_chain(entry))

    def _collect_durability_stats(self) -> None:
        """Pull the receiver's replay-dedup counter into the stats (how
        many replays it recognised as already-acked epochs)."""
        if not self._journal_on:
            return
        try:
            ss = self.transport.server_stats()
        except Exception:  # noqa: BLE001 — stats must not break close
            return
        if isinstance(ss, dict):
            self.stats.replay_dups = int(ss.get("replay_dups") or 0)

    # -- barriers -------------------------------------------------------
    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until all written buffers reached staging — including
        journaled writes still being replayed after a reconnect."""
        self._check_live()
        deadline = time.monotonic() + timeout if timeout else None
        self.transport.sync(timeout)
        if self._journal_on:
            # a replaying write is out of the transport's queues (its
            # failed attempt completed there) but not yet durable — the
            # sync contract covers it too
            with self._cond:
                while self._journal:
                    remaining = None
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            raise TimeoutError(
                                f"{len(self._journal)} journaled writes "
                                "still replaying")
                    self._cond.wait(min(remaining, 0.25)
                                    if remaining else 0.25)
        # only the sync that follows new writes defines the phase timing —
        # the redundant sync on clean __exit__ must not inflate it
        if self._t0 is not None and self._unsynced:
            self.stats.to_staging_s = time.perf_counter() - self._t0
        self._unsynced = False
        self._collect_channel_stats()
        self._collect_codec_stats()
        self._emit("sync")

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until staged data is queryable at the endpoint."""
        self._check_live()
        self.transport.drain(timeout)
        if self._t0 is not None and self._undrained:
            self.stats.end_to_end_s = time.perf_counter() - self._t0
        self._undrained = False
        self._emit("drain")

    # -- control plane --------------------------------------------------
    def run_savime(self, q):
        """Run one analytical operator over this transport's control path.
        ``q`` may be a typed statement from :mod:`repro.analysis.query`
        (preferred) or raw mini-language text (deprecated as a user API —
        DESIGN.md §8)."""
        self._check_live()
        if hasattr(q, "compile"):
            q = q.compile()
        return self.transport.run_savime(q)

    def analysis(self, **kw) -> "object":
        """Open a typed :class:`~repro.analysis.AnalysisSession` riding
        this session's control path (compute nodes reach SAVIME only
        through staging — paper §3.1)."""
        from repro.analysis import AnalysisSession  # local: avoids cycle
        return AnalysisSession(via=self, **kw).open()

    def server_stats(self) -> dict:
        self._check_live()
        return self.transport.server_stats()

    # -- introspection --------------------------------------------------
    @property
    def inflight_bytes(self) -> int:
        with self._cond:
            return self._inflight

    def add_metrics_hook(self, fn: Callable[[dict], None]) -> None:
        self.hooks.append(fn)

    def _emit(self, event: str, **kw) -> None:
        if not self.hooks:
            return
        payload = {"event": event, "engine": self.stats.engine, **kw}
        for fn in self.hooks:
            try:
                fn(payload)
            except Exception:  # noqa: BLE001 — hooks must not break egress
                pass

    def _collect_channel_stats(self) -> None:
        """Snapshot per-channel byte/latency breakdowns into the stats
        (striped transports only; single-connection paths report [])."""
        try:
            ch = self.transport.channel_stats()
        except Exception:  # noqa: BLE001 — stats must not break egress
            return
        if ch:
            self.stats.channels = ch

    def _collect_page_stats(self) -> None:
        """Snapshot staging-side page/spill/dedup counters into the stats
        (paged staging only; flat paths report {})."""
        if self.cfg.page_bytes <= 0:
            return
        try:
            pg = self.transport.page_stats()
        except Exception:  # noqa: BLE001 — stats must not break egress
            return
        if pg:
            self.stats.pages = pg

    def _collect_gateway_stats(self) -> None:
        """Snapshot the gateway's fleet view (placement, tenancy,
        admission totals) into the stats (pool mode only; direct
        staging paths report {})."""
        if self.cfg.gateway_addr is None:
            return
        try:
            gw = self.transport.gateway_stats()
        except Exception:  # noqa: BLE001 — stats must not break egress
            return
        if gw:
            self.stats.gateway = gw

    def _collect_codec_stats(self) -> None:
        """Snapshot sender-side codec accounting (raw vs wire bytes,
        encode time) into the stats (``cfg.codec != "none"`` only)."""
        if self.cfg.codec == "none":
            return
        try:
            cs = self.transport.codec_stats()
        except Exception:  # noqa: BLE001 — stats must not break egress
            return
        if cs:
            self.stats.codec = cs

    def _check_live(self) -> None:
        if not self._opened:
            raise RuntimeError("TransferSession not opened "
                               "(use `with` or .open())")
        if self._closed:
            raise RuntimeError("TransferSession already closed")


def run_engine(engine: str, buffers: Sequence, names: Sequence[str],
               cfg: TransportConfig, *, label: Optional[str] = None,
               drain: bool = True) -> TransferStats:
    """One-shot convenience: ship ``buffers`` through ``engine``.

    This is what the old ``run_rdma_staged`` / ``run_scp`` /
    ``run_ssh_direct`` drivers collapse into.
    """
    with TransferSession(engine, cfg, label=label) as sess:
        for name, buf in zip(names, buffers):
            sess.write(name, buf)
        sess.sync()
        if drain:
            sess.drain()
    return sess.stats
