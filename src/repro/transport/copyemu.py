"""scp/ssh baseline engines (paper §4 / Fig 6) as registered Transports.

All use real sockets / real tmpfs files on this host — scaled datasets,
same mechanisms; see DESIGN.md §6 (scaling honesty):

  scp_mem      pdsh+scp emulation into tmpfs on the staging node: TCP with
               16 KiB userspace copies + per-chunk CRC (cipher-cost proxy).
  scp_disk     same but staging storage is disk, fsync'd ("huge overhead,
               18x slower" — paper Fig 6); ``cfg.disk_bw`` optionally caps
               store throughput to the paper's 2018 disk-array class.
  ssh_direct   SSH-tunnel emulation: two chained TCP hops (compute->staging
               ->SAVIME), userspace copies + CRC at every hop, no staging
               store ("about 4 minutes" — paper §4).

Connection hygiene: every thread-local socket / client created by the
emulation is tracked and closed when its owning pool stops or its
transport closes (they used to leak until process exit).

Striping (``cfg.n_channels > 1``): the emulation engines reuse the
generic :class:`~repro.transport.channels.ChannelGroup` — stripes are
round-robined across N concurrent connections with credit-based flow
control, and the copy servers reassemble them out of order before
storing/forwarding. The cost model is preserved at both ends: striped
sends go through 16K userspace chunk copies + CRC per stripe, and the
server side receives through the same copied path.

Wire format: the copy emulations are the paper's measured *baselines* —
they never negotiate the bin1 fast path or coalesce small datasets,
whatever ``cfg.wire_format`` / ``cfg.coalesce_bytes`` say (a baseline
that adopts the optimizations under test stops being a baseline). The
``ChannelGroup`` enforces this whenever a custom ``send_frame`` is
plugged in, and ``tests/test_wire_coalesce.py`` guards it. The same
holds for egress reduction codecs (DESIGN.md §13): these engines never
touch the :class:`~repro.core.client.Communicator`, so ``cfg.codec`` is
structurally inert — baselines always ship raw bytes and report no
codec stats (``tests/test_codec.py`` pins this).
"""
from __future__ import annotations

import json
import os
import secrets
import shutil
import socket
import struct
import threading
import time
import zlib
from typing import Optional

import numpy as np

from repro.core import wire
from repro.core.queues import FCFSPool
from repro.core.savime import SavimeClient
from repro.transport.base import Transport, register_transport

_SCP_CHUNK = 16 << 10   # scp/ssh move data through ~16K cipher blocks


# one TCP connection per I/O thread (like an ssh session), tracked so no
# connection outlives its pool — shared implementation in repro.core.wire
_SockCache = wire.ConnCache


# ---------------------------------------------------------------------------
# emulation servers
# ---------------------------------------------------------------------------


class _CopyServer:
    """Receives frames with userspace 16K copies + CRC; stores (scp) or
    forwards (ssh tunnel hop)."""

    _GUARDED_BY = {
        "_asm": "_asm_lock",
        "_threads": "_threads_lock",
        "_conns": "_conn_lock",
    }

    def __init__(self, store_dir: Optional[str], fsync: bool,
                 forward_addr: Optional[str] = None,
                 savime_addr: Optional[str] = None,
                 disk_bw: Optional[float] = None):
        self.store_dir = store_dir
        self.fsync = fsync
        self.forward_addr = forward_addr
        self.savime_addr = savime_addr
        self.disk_bw = disk_bw  # B/s cap modeling the paper's 2018 disk array
        self._fwd_socks = _SockCache()
        self._savime_clis = _SockCache()
        self._asm: dict[str, dict] = {}      # striped reassembly in progress
        self._asm_lock = threading.Lock()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(64)
        self.addr = f"127.0.0.1:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        # conn threads were fire-and-forget daemons until the lifecycle
        # lint flagged them: stop() now shuts live conns and joins, so a
        # transport close leaves no serve thread (or its socket) behind
        self._threads: list[threading.Thread] = []
        self._threads_lock = threading.Lock()
        self._conns: set[socket.socket] = set()
        self._conn_lock = threading.Lock()
        self._accept_thread = threading.Thread(
            target=self._accept, daemon=True, name="copysrv-accept")
        self._accept_thread.start()

    def stop(self, join_timeout: float = 2.0):
        self._stop.set()
        try:
            # shutdown (not just close) wakes a thread blocked in accept()
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._srv.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self._accept_thread.join(join_timeout)
        deadline = time.monotonic() + join_timeout
        with self._threads_lock:
            threads = list(self._threads)
        for t in threads:
            t.join(max(deadline - time.monotonic(), 0.0))
        with self._threads_lock:
            self._threads = [t for t in self._threads if t.is_alive()]
        self._fwd_socks.close_all()
        self._savime_clis.close_all()

    def _accept(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            if self._stop.is_set():
                # raced stop(): serving now would leave a thread (and a
                # conn) that stop() already walked past
                try:
                    conn.close()
                except OSError:
                    pass
                return
            with self._threads_lock:
                self._threads = [t for t in self._threads if t.is_alive()]
                t = threading.Thread(target=self._serve, args=(conn,),
                                     daemon=True, name="copysrv-conn")
                t.start()
                self._threads.append(t)

    def _serve(self, conn: socket.socket):
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._conn_lock:
            self._conns.add(conn)
        try:
            with conn:
                while True:
                    try:
                        header, payload = self._recv_copied(conn)
                    except (ConnectionError, OSError):
                        return
                    try:
                        reply = self._handle_frame(header, payload)
                    except Exception as e:  # noqa: BLE001
                        reply = {"ok": False, "error": str(e),
                                 "code": "error"}
                    try:
                        wire.send_frame(conn, reply)
                    except OSError:
                        return
        finally:
            with self._conn_lock:
                self._conns.discard(conn)

    def _handle_frame(self, header, payload) -> dict:
        op = header.get("op")
        if op == "stripe_open":
            return self._stripe_open(header)
        if op == "stripe":
            return self._stripe(header, payload)
        self._sink(header, payload)
        return {"ok": True}

    # -- striped reassembly (same protocol the staging server speaks) ----
    def _stripe_open(self, h) -> dict:
        fid = secrets.token_hex(8)
        need = int(h["n_stripes"])
        asm = {"name": h["name"], "dtype": h.get("dtype", "uint8"),
               "buf": bytearray(int(h["size"])), "need": need,
               "seen": set(), "done": False,
               "wanted": max(1, int(h.get("credits", 4)))}
        if need == 0:                       # empty dataset: sink at open
            self._sink({"name": asm["name"], "dtype": asm["dtype"]},
                       asm["buf"])
        else:
            with self._asm_lock:
                self._asm[fid] = asm
        return {"ok": True, "file_id": fid,
                "credits": max(1, int(h.get("credits", 4)))}

    def _stripe(self, h, payload) -> dict:
        idx, off = int(h["stripe_idx"]), int(h["offset"])
        with self._asm_lock:
            asm = self._asm.get(h["file_id"])
            if asm is None:
                raise ValueError(f"unknown striped file {h['file_id']!r}")
            dup = idx in asm["seen"]
            if off < 0 or off + len(payload) > len(asm["buf"]):
                raise ValueError(
                    f"stripe [{off},{off + len(payload)}) outside dataset "
                    f"[0,{len(asm['buf'])})")
        # the copy emulation has no staging-memory model: grant whatever
        # window the sender asked for at stripe_open (never 0)
        reply = {"ok": True, "stripe_idx": idx, "dup": dup, "done": False,
                 "credits": asm["wanted"]}
        if dup:
            return reply
        asm["buf"][off:off + len(payload)] = payload   # land at its offset
        with self._asm_lock:
            asm["seen"].add(idx)
            if len(asm["seen"]) >= asm["need"] and not asm["done"]:
                asm["done"] = True
                self._asm.pop(h["file_id"], None)
                reply["done"] = True
        if reply["done"]:
            self._sink({"name": asm["name"], "dtype": asm["dtype"]},
                       asm["buf"])
        return reply

    def _recv_copied(self, conn):
        """recv with deliberate userspace chunk copies + CRC per chunk —
        models scp/ssh's copy+cipher CPU path (vs sendfile/RDMA zero-copy)."""
        raw = b""
        while len(raw) < 8:
            r = conn.recv(8 - len(raw))
            if not r:
                raise ConnectionError("closed")
            raw += r
        hlen = struct.unpack(">Q", raw)[0]
        hb = b""
        while len(hb) < hlen:
            r = conn.recv(hlen - len(hb))
            if not r:
                raise ConnectionError("closed")
            hb += r
        header = json.loads(hb)
        nbytes = header.get("nbytes", 0)
        out = bytearray()
        crc = 0
        while len(out) < nbytes:
            chunk = conn.recv(min(_SCP_CHUNK, nbytes - len(out)))
            if not chunk:
                raise ConnectionError("closed")
            crc = zlib.crc32(chunk, crc)          # cipher-cost proxy
            out += chunk                           # userspace copy
        header["crc"] = crc
        return header, out

    def _sink(self, header, payload):
        if self.store_dir is not None:            # scp: store at staging
            path = os.path.join(self.store_dir, header["name"])
            t0 = time.perf_counter()
            with open(path, "wb") as f:
                f.write(payload)
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
            if self.disk_bw:  # container disk is NVMe-fast; model the
                # paper's spinning-disk staging storage when asked to
                budget = len(payload) / self.disk_bw
                spent = time.perf_counter() - t0
                if budget > spent:
                    time.sleep(budget - spent)
            header["path"] = path
        elif self.forward_addr:                    # ssh hop: forward copied
            sock = self._fwd_socks.get(self.forward_addr)
            h, _ = wire.request(sock, {"op": "fwd", "name": header["name"],
                                       "dtype": header.get("dtype", "uint8")},
                                payload)
            if not h.get("ok"):
                raise RuntimeError(h.get("error"))
        elif self.savime_addr:                     # final hop into SAVIME
            cli = self._savime_clis.get(self.savime_addr, SavimeClient)
            cli.load_dataset(header["name"], header.get("dtype", "uint8"),
                             payload)


class _CopyServerFwdToSavime(_CopyServer):
    """Second tunnel hop: copied recv, then SAVIME ingest."""

    def __init__(self, savime_addr: str):
        super().__init__(store_dir=None, fsync=False,
                         savime_addr=savime_addr)

    def _sink(self, header, payload):
        op = header.get("op")
        if op != "fwd":   # only the first hop may talk to this endpoint
            raise ValueError(
                f"tunnel hop rejected frame with op={op!r} (expected 'fwd')")
        cli = self._savime_clis.get(self.savime_addr, SavimeClient)
        cli.load_dataset(header["name"], header.get("dtype", "uint8"),
                         payload)


def _copied_send_frame(sock: socket.socket, header: dict, payload) -> None:
    """Frame writer with the scp/ssh cost model: 16K userspace chunk
    copies + CRC per chunk (vs ``wire.send_frame``'s direct sendall).
    Plugged into ChannelGroup so striped sends keep the same CPU path."""
    mv = memoryview(payload).cast("B") if not isinstance(payload, memoryview) \
        else payload.cast("B")
    hb = json.dumps(dict(header, nbytes=len(mv))).encode()
    sock.sendall(struct.pack(">Q", len(hb)) + hb)
    crc = 0
    for off in range(0, len(mv), _SCP_CHUNK):
        chunk = bytes(mv[off:off + _SCP_CHUNK])       # userspace copy
        crc = zlib.crc32(chunk, crc)                  # cipher-cost proxy
        sock.sendall(chunk)


def _copy_send(socks: _SockCache, addr: str, name: str,
               dtype: str, buf: np.ndarray):
    """Client side of the scp/ssh emulation: chunked sendall with CRC."""
    sock = socks.get(addr)
    payload = memoryview(buf.reshape(-1).view(np.uint8))
    _copied_send_frame(sock, {"name": name, "dtype": dtype}, payload)
    h, _ = wire.recv_frame(sock)
    if not h.get("ok"):
        raise RuntimeError(h.get("error"))


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


class _CopyTransportBase(Transport):
    """Shared plumbing for the copy-emulation engines."""

    def __init__(self, cfg):
        super().__init__(cfg)
        if cfg.savime_addr is None:
            raise ValueError(f"{self.name} needs cfg.savime_addr")
        self._pool: Optional[FCFSPool] = None
        self._socks = _SockCache()
        self._group = None                  # striped channels, if enabled
        self._ctrl_savime: Optional[SavimeClient] = None
        self._ctrl_lock = threading.Lock()

    def _make_pool(self, name: str) -> FCFSPool:
        pool = FCFSPool(self.cfg.io_threads, name,
                        straggler_timeout=self.cfg.straggler_timeout)
        pool.add_stop_callback(self._socks.close_all)
        return pool

    def _make_group(self, addr: str):
        """Striped ChannelGroup against ``addr`` when cfg asks for more
        than one channel — with the copied-send cost model per stripe.
        ``cfg.wire_format`` is deliberately not forwarded: the custom
        ``send_frame`` pins the group to JSON (baseline honesty)."""
        if self.cfg.n_channels <= 1:
            return None
        from repro.transport.channels import ChannelGroup
        return ChannelGroup(
            addr, n_channels=self.cfg.n_channels,
            stripe_bytes=self.cfg.stripe_bytes or self.cfg.block_size,
            credits=self.cfg.credits,
            send_frame=_copied_send_frame).open()

    def channel_stats(self) -> list[dict]:
        return self._group.channel_stats() if self._group is not None else []

    def sync(self, timeout: Optional[float] = None) -> None:
        self._pool.sync(timeout)

    # scp/ssh have no staging proxy; the analytical endpoint is reached
    # directly (that is exactly what the paper's baselines do).
    def run_savime(self, q: str):
        with self._ctrl_lock:
            if self._ctrl_savime is None:
                self._ctrl_savime = SavimeClient(self.cfg.savime_addr)
            return self._ctrl_savime.run(q)

    def _close_ctrl(self) -> None:
        with self._ctrl_lock:
            if self._ctrl_savime is not None:
                try:
                    self._ctrl_savime.close()
                except (OSError, RuntimeError):
                    pass
                self._ctrl_savime = None


class _ScpTransport(_CopyTransportBase):
    """pdsh+scp emulation: copy files to staging storage (mem|disk), then
    the staging side forwards to SAVIME on drain."""

    storage = "mem"

    def open(self) -> None:
        uid = secrets.token_hex(3)
        self._store = (f"/dev/shm/scp-{uid}" if self.storage == "mem"
                       else f"/tmp/scp-{uid}")
        os.makedirs(self._store, exist_ok=True)
        self._srv = _CopyServer(
            store_dir=self._store, fsync=(self.storage == "disk"),
            disk_bw=self.cfg.disk_bw if self.storage == "disk" else None)
        self._pool = self._make_pool(self.name)
        self._group = self._make_group(self._srv.addr)
        self._fwd_pool = FCFSPool(self.cfg.send_threads, f"{self.name}-fwd")
        self._fwd_savime = _SockCache()
        self._fwd_pool.add_stop_callback(self._fwd_savime.close_all)
        self._written: list[tuple[str, str, int]] = []
        self._forwarded = 0

    def write(self, name: str, dtype: str, buf):
        self._written.append((name, dtype, buf.nbytes))
        if self._group is not None:
            return self._pool.submit(self._group.send_dataset, name, dtype,
                                     buf, name=f"{self.name}-{name}")
        return self._pool.submit(_copy_send, self._socks, self._srv.addr,
                                 name, dtype, buf, name=f"{self.name}-{name}")

    def drain(self, timeout: Optional[float] = None) -> None:
        """Forward everything stored at staging into SAVIME (FCFS pool)."""
        self.sync(timeout)

        def forward(name, dtype, nbytes):
            cli = self._fwd_savime.get(self.cfg.savime_addr, SavimeClient)
            path = os.path.join(self._store, name)
            fd = os.open(path, os.O_RDONLY)
            try:
                cli.load_dataset_from_file(name, dtype, fd, nbytes)
            finally:
                os.close(fd)
                os.unlink(path)

        todo, self._forwarded = \
            self._written[self._forwarded:], len(self._written)
        for name, dtype, nbytes in todo:
            self._fwd_pool.submit(forward, name, dtype, nbytes,
                                  name=f"fwd-{name}")
        self._fwd_pool.sync(timeout)

    def close(self) -> None:
        self._pool.stop()
        self._fwd_pool.stop()
        if self._group is not None:
            self._group.close()
        self._srv.stop()
        self._close_ctrl()
        shutil.rmtree(self._store, ignore_errors=True)


@register_transport("scp_mem")
class ScpMemTransport(_ScpTransport):
    storage = "mem"


@register_transport("scp_disk")
class ScpDiskTransport(_ScpTransport):
    storage = "disk"


@register_transport("ssh_direct")
class SshDirectTransport(_CopyTransportBase):
    """SSH-tunnel emulation: compute -> staging hop -> SAVIME, userspace
    copies + CRC at both hops, no staging store (paper §4 last baseline).
    Data reaches SAVIME synchronously with each write, so sync == drained."""

    def open(self) -> None:
        self._hop2 = _CopyServerFwdToSavime(self.cfg.savime_addr)
        self._hop1 = _CopyServer(store_dir=None, fsync=False,
                                 forward_addr=self._hop2.addr)
        self._pool = self._make_pool(self.name)
        # stripes ride the first (compute->staging) hop; hop1 reassembles
        # and forwards whole datasets to the SAVIME hop as before
        self._group = self._make_group(self._hop1.addr)

    def write(self, name: str, dtype: str, buf):
        if self._group is not None:
            return self._pool.submit(self._group.send_dataset, name, dtype,
                                     buf, name=f"ssh-{name}")
        return self._pool.submit(_copy_send, self._socks, self._hop1.addr,
                                 name, dtype, buf, name=f"ssh-{name}")

    def drain(self, timeout: Optional[float] = None) -> None:
        self.sync(timeout)   # no staging store: synced data is already in

    def close(self) -> None:
        self._pool.stop()
        if self._group is not None:
            self._group.close()
        self._hop1.stop()
        self._hop2.stop()
        self._close_ctrl()
