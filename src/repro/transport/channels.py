"""Striped multi-channel transfers with credit-based flow control.

The paper's block-size experiment (§3–4) probes how transfer granularity
amortizes per-block costs; this module adds the orthogonal axis the
bandwidth-saturation regime needs: **parallelism across connections**
(Catalyst-ADIOS2 stripes in-transit traffic across concurrent streams;
SDN-for-Big-Data shows multi-path transfer as the scaling lever).

    ChannelGroup(addr, n_channels=4, stripe_bytes=4 << 20, credits=4)

splits each dataset into stripes round-robined across N concurrent
connections. Per channel the sender pipelines up to ``window`` unacked
stripes; every ack replenishes one credit and carries the receiver's new
grant, so a receiver under pressure (staging memory filling because the
SAVIME hop is slow) shrinks the window toward 1 and the producers slow
down instead of ballooning staging memory. The receiver reassembles
stripes out of order — each stripe frame carries ``(name, stripe_idx,
n_stripes, offset)`` and lands at its offset whatever channel or order it
arrives in.

Wire protocol (speaks the generic frame format in :mod:`repro.core.wire`;
both the staging server and the copy-emulation servers implement it):

    stripe_open  {name, dtype, size, n_stripes, credits}
                 -> {ok, file_id, credits[, path]}   (control connection)
    stripe       {file_id, name, stripe_idx, n_stripes, offset} + payload
                 -> {ok, stripe_idx, done, dup, credits}   (data channels,
                 pipelined; acks return in order per channel)

The server must always grant >= 1 credit: a zero grant with an empty
pipeline would leave no ack to ever raise it again.

With ``wire_format="bin1"`` (negotiated on the control connection at
open — the handshake with an old server falls back to JSON) the stripe
and ack frames ride the struct-packed fast path of
:mod:`repro.core.wire`, the sender scatter-gathers every credit-admitted
stripe waiting in its queue into a single ``sendmsg``
(``send_frames_vectored``), and the receiver honours unsolicited
``credit`` frames the staging server pushes when a SAVIME forward frees
memory (window update without consuming an ack). Engines that plug in a
custom ``send_frame`` (the copy emulations and their 16K-copy + CRC cost
model) never negotiate binary and never vector — their measured per-frame
overhead *is* the baseline.

Two data planes per stripe, chosen automatically per dataset:

  * **one-sided** — when ``stripe_open`` returns a ``path`` that exists
    locally (the staging server's tmpfs region, reachable because client
    and server share the emulated RDMA fabric), the sender performs the
    stripe as a one-sided mmap write at its offset and the channel frame
    is control-only (``sided=1``, no payload). Per-byte cost equals the
    block path's single memcpy; the credit window plays the role of a
    QP's send-queue depth.
  * **payload** — otherwise the stripe's bytes ride the channel socket
    and the receiver reassembles them at their offset (the copy-emulation
    engines, or a staging server across a real network).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import queue
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core import wire
from repro.core.blocks import plan_blocks
from repro.core.rdma import RdmaWriter, writer_for_reply
from repro.core.retry import RetryPolicy

DEFAULT_STRIPE_BYTES = 4 << 20
DEFAULT_CREDITS = 4

# consecutive CRC-rejected stripes before a bin1 channel falls back to
# JSON frames (persistent corruption on the binary path — DESIGN.md §15)
_CRC_FALLBACK_AFTER = 3


@dataclasses.dataclass
class ChannelStats:
    """Per-channel byte/latency breakdown (surfaced in TransferStats)."""

    channel: int
    nbytes: int = 0             # payload bytes acked on this channel
    n_stripes: int = 0          # stripes acked
    stripe_s: float = 0.0       # sum of send->ack wall time per stripe
    credit_wait_s: float = 0.0  # time the sender blocked waiting for credit
    peak_unacked: int = 0       # high-water mark of in-flight stripes
    window: int = 0             # last grant from the receiver
    failed_over: int = 0        # stripes re-homed away when this chan died
    adopted: int = 0            # stripes re-homed onto this channel
    crc_retries: int = 0        # stripes resent after a CRC rejection
    wire_fallbacks: int = 0     # bin1 -> JSON downgrades (persistent CRC)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Transfer:
    """Completion tracker for one striped dataset."""

    _GUARDED_BY = {
        "_remaining": "_lock",
        "_finished": "_lock",
        "_callbacks": "_lock",
    }

    def __init__(self, name: str, n_stripes: int, nbytes: int,
                 on_done: Optional[Callable[["_Transfer"], None]] = None,
                 writer: Optional[RdmaWriter] = None):
        self.name = name
        self.nbytes = nbytes
        self.event = threading.Event()
        self.error: Optional[BaseException] = None
        self._remaining = n_stripes
        self._lock = threading.Lock()
        self._finished = False
        self._callbacks: list[Callable[["_Transfer"], None]] = \
            [on_done] if on_done else []
        self._writer = writer
        if n_stripes == 0:
            self._finished = True
            self._finish()

    def stripe_done(self) -> None:
        with self._lock:
            self._remaining -= 1
            if self._remaining != 0 or self._finished:
                return
            self._finished = True
        self._finish()

    def fail(self, exc: BaseException) -> None:
        with self._lock:
            if self.error is None:
                self.error = exc
            if self._finished:
                return
            self._finished = True
        self._finish()

    def add_done_callback(self, fn: Callable[["_Transfer"], None]) -> None:
        with self._lock:
            if not self._finished:
                self._callbacks.append(fn)
                return
        fn(self)

    def _finish(self) -> None:
        # release the one-sided mapping before signalling: a producer that
        # frees/mutates the region file on completion must not race a
        # still-open writer view
        if self._writer is not None:
            try:
                self._writer.close()
            except Exception:  # noqa: BLE001 — completion must not throw
                pass
            self._writer = None
        with self._lock:
            callbacks, self._callbacks = self._callbacks, []
        self.event.set()
        for fn in callbacks:
            try:
                fn(self)
            except Exception:  # noqa: BLE001 — callbacks must not break acks
                pass


class _Stripe:
    __slots__ = ("transfer", "file_id", "name", "idx", "n_stripes",
                 "offset", "view", "writer", "enc")

    def __init__(self, transfer, file_id, name, idx, n_stripes, offset,
                 view, writer=None, enc=False):
        self.transfer = transfer
        self.file_id = file_id
        self.name = name
        self.idx = idx
        self.n_stripes = n_stripes
        self.offset = offset
        self.view = view
        self.writer = writer        # RdmaWriter => one-sided data plane
        self.enc = enc              # payload is codec-encoded (F_ENC flag)


_MAX_VECTOR = 64        # frames per sendmsg burst (2 iovecs each, < IOV cap)


class _Channel:
    """One connection + sender/receiver thread pair with a credit window."""

    # ``_dead`` is deliberately *not* declared: it is published under both
    # _inflight_lock and _cond (see _fail) and the sender's top-of-loop
    # read is a benign racy fast-path — the authoritative check-and-append
    # happens under _inflight_lock.
    _GUARDED_BY = {
        "_unacked": "_cond",
        "_window": "_cond",
        "_closing": "_cond",
        "_inflight": "_inflight_lock",
    }

    def __init__(self, index: int, addr: str, credits: int,
                 connect: Callable, send_frame: Callable,
                 wire_format: str = wire.WIRE_JSON,
                 on_fail: Optional[Callable] = None):
        self.index = index
        self.stats = ChannelStats(channel=index, window=credits)
        self._send_frame = send_frame
        self._fmt = wire_format
        # when a channel dies, its queued + in-flight stripes are handed
        # to this group hook for re-homing on surviving channels instead
        # of failing their transfers (None keeps the fail-fast behaviour)
        self._on_fail = on_fail
        # vectored bursts re-encode frames; only safe on the stock frame
        # writer (a custom send_frame carries an engine's own cost model)
        self._can_vector = send_frame is wire.send_frame
        self.sock = connect(addr)
        # data channels block until shutdown, not until an idle timeout:
        # an idle receiver parked in recv must not kill a healthy channel
        self.sock.settimeout(None)
        self._crc = False
        if self._can_vector:
            # per-connection handshake *before* the receiver thread owns
            # the socket: CRC verification is gated on this connection's
            # negotiated caps (an old server just leaves caps empty)
            try:
                wire.negotiate(self.sock, formats=(self._fmt,),
                               caps=wire.SUPPORTED_CAPS)
            except (ConnectionError, OSError):
                pass          # stays uncapped; frames still self-describe
            self._crc = wire.CAP_CRC in wire.negotiated_caps(self.sock)
        self._consecutive_crc = 0
        self.q: queue.Queue = queue.Queue()
        self._cond = threading.Condition()
        self._window = max(1, credits)
        self._unacked = 0
        self._inflight: collections.deque = collections.deque()
        self._inflight_lock = threading.Lock()
        self._dead: Optional[BaseException] = None
        self._closing = False
        self._sender = threading.Thread(target=self._send_loop,
                                        name=f"chan{index}-send", daemon=True)
        self._receiver = threading.Thread(target=self._recv_loop,
                                          name=f"chan{index}-recv",
                                          daemon=True)
        self._sender.start()
        self._receiver.start()

    # -- sender ---------------------------------------------------------
    _ADMITTED, _FAILED, _DEFER = range(3)

    def _admit(self, item, block: bool) -> int:
        """Acquire one credit for ``item`` (blocking or opportunistic).

        Tri-state so the caller's requeue decision is unambiguous:
        ``_FAILED`` means the channel is dead or closing (the caller
        hands the untouched item to ``_handoff``); ``_DEFER``
        (non-blocking only) means no credit was free and the item is
        untouched."""
        t0 = time.perf_counter()
        with self._cond:
            if self._dead is None and not self._closing \
                    and not block and self._unacked >= self._window:
                return self._DEFER
            while self._unacked >= self._window and self._dead is None \
                    and not self._closing:
                self._cond.wait(0.5)
            if self._dead is not None or self._closing:
                return self._FAILED
            self._unacked += 1
            self.stats.peak_unacked = max(self.stats.peak_unacked,
                                          self._unacked)
        self.stats.credit_wait_s += time.perf_counter() - t0
        return self._ADMITTED

    def _release_credit(self) -> None:
        with self._cond:
            self._unacked -= 1
            self._cond.notify_all()

    def _prepare(self, item) -> Optional[tuple]:
        """Build one stripe frame; performs the one-sided mmap store for
        sided items. Returns ``(header, payload)`` or None on an
        item-local failure (credit released, transfer failed)."""
        header = {"op": "stripe", "file_id": item.file_id,
                  "name": item.name, "stripe_idx": item.idx,
                  "n_stripes": item.n_stripes, "offset": item.offset}
        if item.enc:
            header["enc"] = 1       # rides the F_ENC flag on bin1
        payload = item.view
        if item.writer is not None:
            # one-sided plane: the stripe is a raw mmap store (numpy
            # copyto releases the GIL, so channels copy concurrently);
            # only the control frame rides the socket
            try:
                item.writer.write(item.offset, item.view)
            except Exception as e:  # noqa: BLE001 — item-local failure
                self._release_credit()
                item.transfer.fail(e)
                return None
            header["sided"] = 1
            header["size"] = len(item.view)
            payload = None
        elif self._crc and len(item.view):
            header["crc"] = wire.crc32(item.view)
        return header, payload

    def _handoff(self, items, exc: BaseException) -> None:
        """Route stripes a dead channel cannot carry: re-home them via the
        group hook (degrade to fewer channels), or fail their transfers
        when there is no hook / the group is closing."""
        if not items:
            return
        with self._cond:
            closing = self._closing
        if self._on_fail is not None and not closing:
            self.stats.failed_over += len(items)
            self._on_fail(self, exc, items)
            return
        for it in items:
            it.transfer.fail(exc)

    def _send_loop(self) -> None:
        while True:
            item = self.q.get()
            if item is None:
                return
            if self._dead is not None:
                self._handoff([item], self._dead)
                continue
            if self._admit(item, block=True) is not self._ADMITTED:
                self._handoff([item], self._dead
                              or ConnectionError("channel closed"))
                continue
            batch = [item]
            # opportunistic burst: drain further queued stripes while the
            # credit window allows, so a run of small stripes becomes one
            # scatter-gather sendmsg instead of 2 syscalls per stripe
            if self._can_vector:
                while len(batch) < _MAX_VECTOR:
                    try:
                        nxt = self.q.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is None:           # shutdown sentinel: requeue
                        self.q.put(None)
                        break
                    admitted = self._admit(nxt, block=False)
                    if admitted is self._DEFER:
                        # out of credits, item untouched: requeue so it is
                        # either sent later or re-homed by the top-of-loop
                        # dead-check — never silently dropped
                        self.q.put(nxt)
                        break
                    if admitted is self._FAILED:
                        self._handoff([nxt], self._dead
                                      or ConnectionError("channel closed"))
                        break
                    batch.append(nxt)
            frames = []
            admitted = []
            for it in batch:
                prep = self._prepare(it)
                if prep is not None:
                    frames.append(prep)
                    admitted.append(it)
            if not frames:
                continue
            # append before sending: one sender per channel, so deque order
            # matches wire order and the receiver can match acks FIFO.
            # The dead-check must share the inflight lock with _fail's
            # drain — otherwise an item appended just after the receiver
            # failed the channel is never failed and its transfer (and any
            # untimed sync on it) hangs forever.
            with self._inflight_lock:
                if self._dead is not None:
                    for it in admitted:
                        self._release_credit()
                        it.transfer.fail(self._dead)
                    continue
                now = time.perf_counter()
                for it in admitted:
                    self._inflight.append((it, now))
            try:
                if len(frames) == 1:
                    header, payload = frames[0]
                    if self._fmt == wire.WIRE_BIN1:
                        wire.send_frame_bin(self.sock, header, payload)
                    else:
                        self._send_frame(self.sock, header, payload)
                else:
                    wire.send_frames_vectored(self.sock, frames,
                                              fmt=self._fmt)
            except (OSError, ValueError) as e:
                self._fail(e)

    # -- receiver -------------------------------------------------------
    def _recv_loop(self) -> None:
        while True:
            try:
                h, _ = wire.recv_frame(self.sock)
            except (ConnectionError, OSError) as e:
                # fail any stripes still awaiting acks even on a shutdown
                # race — a sender parked on credits must not wait forever
                with self._cond:
                    closing = self._closing
                self._fail(e if not closing
                           else ConnectionError("channel closed"))
                return
            if h.get("op") == "credit":
                # unsolicited server push (staging memory freed): adopt
                # the new grant without consuming an ack
                with self._cond:
                    self._window = max(1, int(h.get("credits",
                                                    self._window)))
                    self.stats.window = self._window
                    self._cond.notify_all()
                continue
            with self._inflight_lock:
                head = self._inflight[0][0] if self._inflight else None
                # a dup ack that does not match the FIFO head is
                # *unsolicited*: the server deduped a duplicated frame
                # (fault-injected, or a stripe delivered both on its dying
                # channel and on the one it was re-homed to). That frame
                # never consumed a credit here, so skip it without popping
                # or decrementing — popping would desync every later ack.
                unsolicited = bool(h.get("dup")) and (
                    head is None or head.idx != h.get("stripe_idx"))
                item, t_sent = (None, None) if unsolicited else (
                    self._inflight.popleft() if self._inflight
                    else (None, None))
            with self._cond:
                if not unsolicited:
                    self._unacked -= 1
                self._window = max(1, int(h.get("credits", self._window)))
                self.stats.window = self._window
                self._cond.notify_all()
            if unsolicited:
                continue
            if item is None:       # ack with no matching stripe: corrupt
                self._fail(wire.ProtocolError("unmatched stripe ack"))
                return
            self.stats.stripe_s += time.perf_counter() - t_sent
            if h.get("ok"):
                self._consecutive_crc = 0
                self.stats.nbytes += len(item.view)
                self.stats.n_stripes += 1
                item.transfer.stripe_done()
            elif h.get("code") == "corrupt" or \
                    "crc mismatch" in str(h.get("error") or ""):
                # CRC rejection: the server dropped the stripe (it is NOT
                # in stripes_seen), so resending is safe and required.
                # After a run of consecutive rejections the binary path
                # itself is suspect — degrade this channel to JSON frames
                # (DESIGN.md §15 degradation ladder).
                self.stats.crc_retries += 1
                self._consecutive_crc += 1
                if self._consecutive_crc >= _CRC_FALLBACK_AFTER \
                        and self._fmt == wire.WIRE_BIN1:
                    self._fmt = wire.WIRE_JSON
                    self.stats.wire_fallbacks += 1
                self.q.put(item)
            else:
                item.transfer.fail(
                    RuntimeError(f"stripe rejected: {h.get('error')}"))

    def set_window(self, grant: int) -> None:
        """Adopt a receiver grant arriving out of band (stripe_open)."""
        with self._cond:
            self._window = max(1, int(grant))
            self.stats.window = self._window
            self._cond.notify_all()

    # -- failure / shutdown --------------------------------------------
    def _fail(self, exc: BaseException) -> None:
        with self._inflight_lock:
            # _dead is published under the inflight lock so the sender's
            # check-and-append is atomic against this drain
            with self._cond:
                if self._dead is None:
                    self._dead = exc
                self._cond.notify_all()
            inflight, self._inflight = list(self._inflight), \
                collections.deque()
        orphans = [item for item, _t in inflight]
        # queued-but-unsent stripes would otherwise wait for the sender's
        # top-of-loop dead-check; drain them now so re-homing is prompt
        while True:
            try:
                nxt = self.q.get_nowait()
            except queue.Empty:
                break
            if nxt is None:
                self.q.put(None)    # keep the shutdown sentinel
                break
            orphans.append(nxt)
        self._handoff(orphans, exc)

    def close(self) -> None:
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        self.q.put(None)
        # close() can run on this channel's own sender/receiver thread:
        # when the *last* live channel dies, its _fail -> _adopt_orphans
        # -> _rebuild_channels chain closes the old set (including
        # itself) before the failing thread unwinds. Joining yourself
        # raises and strands the orphans mid-handoff, so skip the
        # self-join — the thread exits as soon as the unwind finishes.
        me = threading.current_thread()
        if self._sender is not me:
            self._sender.join(5.0)
        try:
            self.sock.shutdown(2)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
        if self._receiver is not me:
            self._receiver.join(5.0)


class ChannelGroup:
    """N concurrent striped channels + one control connection.

    ``send_dataset`` is thread-safe and blocking (it returns when every
    stripe is acked), which makes it a drop-in task body for the FCFS I/O
    pools — sync()/TaskHandle semantics are unchanged while each dataset's
    stripes fan out across all channels.

    ``connect`` / ``send_frame`` are pluggable so the copy-emulation
    engines can keep their cost model (16K userspace chunk copies + CRC
    per stripe) while reusing the striping/credit machinery.
    """

    _GUARDED_BY = {
        "_rr": "_ctrl_lock",
        "_outstanding": "_outstanding_cond",
    }

    def __init__(self, addr: str, n_channels: int,
                 stripe_bytes: int = DEFAULT_STRIPE_BYTES,
                 credits: int = DEFAULT_CREDITS,
                 connect: Callable = wire.connect,
                 send_frame: Callable = wire.send_frame,
                 transfer_timeout: float = 300.0,
                 wire_format: str = wire.WIRE_JSON,
                 retry: Optional[RetryPolicy] = None):
        if n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {n_channels}")
        if stripe_bytes < 1:
            raise ValueError(f"stripe_bytes must be >= 1, got {stripe_bytes}")
        self.addr = addr
        self.n_channels = n_channels
        self.stripe_bytes = stripe_bytes
        self.credits = max(1, credits)
        self.transfer_timeout = transfer_timeout
        self._connect = connect
        self._send_frame = send_frame
        # engines with a custom frame writer (copy emulations) keep their
        # cost model: they never negotiate the binary fast path
        self.wire_format = wire_format \
            if send_frame is wire.send_frame else wire.WIRE_JSON
        self._retry = retry or RetryPolicy()
        self._channels: list[_Channel] = []
        self._ctrl = None                     # set once in open()
        self._ctrl_lock = threading.Lock()
        self._rebuild_lock = threading.Lock()
        self._retired: list[dict] = []        # stats of replaced channels
        self._rr = 0
        self._opened = False
        self._closed = False
        self._outstanding = 0                 # submitted, not yet finished
        self._outstanding_cond = threading.Condition()

    # -- lifecycle ------------------------------------------------------
    def open(self) -> "ChannelGroup":
        if self._opened:
            return self
        self._ctrl = self._connect(self.addr)
        if self.wire_format == wire.WIRE_BIN1:
            # per-connection handshake on the control conn: an old server
            # answers the unknown hello op with an error and every
            # connection of this group stays on JSON
            self.wire_format = wire.negotiate(self._ctrl)
        self._channels = [
            _Channel(i, self.addr, self.credits, self._connect,
                     self._send_frame, wire_format=self.wire_format,
                     on_fail=self._adopt_orphans)
            for i in range(self.n_channels)
        ]
        self._opened = True
        return self

    def close(self, drain_timeout: float = 30.0) -> None:
        if self._closed:
            return
        self._closed = True
        # let in-flight transfers finish before tearing the sockets down —
        # a write that was going to succeed must still succeed when the
        # producer closes immediately after submitting (pool-stop parity)
        try:
            self.sync(drain_timeout)
        except TimeoutError:
            pass
        for ch in self._channels:
            ch.close()
        if self._ctrl is not None:
            try:
                self._ctrl.close()
            except OSError:
                pass

    # -- failover -------------------------------------------------------
    def _live_channels(self) -> list[_Channel]:
        return [ch for ch in self._channels if ch._dead is None]

    def _adopt_orphans(self, dead_ch: _Channel, exc: BaseException,
                       items: list) -> None:
        """Re-home a dead channel's queued + in-flight stripes onto the
        survivors — the striped transfer collapses to fewer channels
        instead of failing. A re-sent stripe the server already holds
        comes back as a dup ack (idempotent), so replaying
        maybe-delivered in-flight stripes is safe. Only when *every*
        channel is dead is the set rebuilt from scratch; if that fails
        too, the transfers fail with the rebuild error."""
        if self._closed:
            for it in items:
                it.transfer.fail(exc)
            return
        live = self._live_channels()
        if not live:
            try:
                live = self._rebuild_channels()
            except (ConnectionError, OSError) as e:
                for it in items:
                    it.transfer.fail(e)
                return
        for i, it in enumerate(items):
            tgt = live[i % len(live)]
            tgt.stats.adopted += 1
            tgt.q.put(it)

    def _rebuild_channels(self) -> list[_Channel]:
        """Every channel is dead: build a fresh set with backoff and swap
        it in. Serialised on its own lock so concurrent handoffs elect one
        rebuilder; latecomers adopt its result."""
        with self._rebuild_lock:
            live = self._live_channels()
            if live:                    # another thread already rebuilt
                return live
            fresh: list[_Channel] = []
            for attempt in self._retry.attempts("channel rebuild"):
                fresh = []
                try:
                    for i in range(self.n_channels):
                        fresh.append(_Channel(
                            i, self.addr, self.credits, self._connect,
                            self._send_frame,
                            wire_format=self.wire_format,
                            on_fail=self._adopt_orphans))
                    break
                except (ConnectionError, OSError) as e:
                    for ch in fresh:    # all-or-nothing construction
                        ch.close()
                    attempt.backoff(e)  # raises RetryExhausted at the end
            old, self._channels = self._channels, fresh
            self._retired.extend(ch.stats.as_dict() for ch in old)
            for ch in old:
                ch.close()
            return fresh

    def _reopen_ctrl(self) -> None:
        """Replace a dead control connection (stripe_open retry path).
        The reconnect + re-handshake round-trip under the lock *is* the
        serialisation against concurrent submitters."""
        with self._ctrl_lock:  # lint: ignore[io-under-lock]
            if self._ctrl is not None:
                try:
                    self._ctrl.close()
                except OSError:
                    pass
                self._ctrl = None
            ctrl = self._connect(self.addr)
            if self._send_frame is wire.send_frame and \
                    self.wire_format == wire.WIRE_BIN1:
                self.wire_format = wire.negotiate(ctrl)
            self._ctrl = ctrl

    # -- data plane -----------------------------------------------------
    def _plan_stripes(self, nbytes: int) -> list[tuple[int, int]]:
        """Stripe plan: at most ``stripe_bytes`` each, but small enough
        that every dataset spans all channels — a dataset shorter than
        ``n_channels * stripe_bytes`` would otherwise leave channels idle
        (64 KiB floor so tiny writes do not shatter into confetti)."""
        per_channel = -(-nbytes // self.n_channels)     # ceil div
        floor = min(self.stripe_bytes, 64 << 10)  # never override the knob
        stripe = max(min(self.stripe_bytes, per_channel), floor, 1)
        return plan_blocks(nbytes, stripe)

    def submit_dataset(self, name: str, dtype: str, buf,
                       codec_info: Optional[dict] = None,
                       epoch: Optional[str] = None) -> _Transfer:
        """Asynchronously stripe one named buffer across all channels.

        Returns the :class:`_Transfer` tracker immediately after the
        stripes are enqueued — datasets pipeline through the channels
        back-to-back (stripes of the next dataset flow while the previous
        one's acks are still in flight), which is where the striped path's
        throughput comes from: a blocking per-dataset send would drain the
        pipeline between datasets.

        ``codec_info`` (codec/cmeta/raw_size/decode_at from the sender's
        encode stage) rides the ``stripe_open`` control frame; the stripes
        themselves are then flagged ``enc`` so receivers can sanity-check
        that encoded payloads only land in codec-opened datasets.
        """
        if not self._opened or self._closed:
            raise RuntimeError("ChannelGroup not open")
        arr = buf if isinstance(buf, np.ndarray) else \
            np.frombuffer(buf, dtype=np.uint8)
        flat = arr.reshape(-1).view(np.uint8)
        nbytes = flat.nbytes
        stripes = self._plan_stripes(nbytes)
        req = dict({"op": "stripe_open", "name": name, "dtype": dtype,
                    "size": nbytes, "n_stripes": len(stripes),
                    "credits": self.credits}, **(codec_info or {}))
        if epoch is not None:
            req["epoch"] = epoch
        for attempt in self._retry.attempts(f"stripe_open {name!r}"):
            try:
                # request/reply on the shared control conn must be
                # serialized; the blocking round-trip under the lock is
                # the serialization itself
                with self._ctrl_lock:  # lint: ignore[io-under-lock]
                    if self._ctrl is None:
                        raise ConnectionError("control connection down")
                    h, _ = wire.request(self._ctrl, req)
                break
            except (ConnectionError, OSError) as e:
                try:
                    self._reopen_ctrl()
                except (ConnectionError, OSError):
                    pass          # next attempt finds _ctrl None, retries
                attempt.backoff(e)  # raises RetryExhausted when spent
        if not h.get("ok"):
            # typed: a gateway's quota/auth rejection surfaces as
            # QuotaExceededError/AuthError, not a generic RuntimeError
            from repro.gateway.tenancy import error_from_reply
            raise error_from_reply(h, "stripe_open failed")
        if h.get("dup"):
            # replayed epoch the server already acked: nothing to send.
            # The zero-stripe transfer completes in its constructor, so
            # account for it *before* building it.
            with self._outstanding_cond:
                self._outstanding += 1
            return _Transfer(name, 0, nbytes, on_done=self._transfer_done)
        file_id = h["file_id"]
        for ch in self._channels:       # adopt the receiver's current grant
            ch.set_window(int(h.get("credits", self.credits)))
        # a locally-reachable region path selects the one-sided data plane
        # (shared emulated-RDMA fabric); otherwise stripes carry payload
        path = h.get("path")
        writer = writer_for_reply(h, nbytes) \
            if nbytes and path and os.path.exists(path) else None
        with self._outstanding_cond:
            self._outstanding += 1
        tr = _Transfer(name, len(stripes), nbytes,
                       on_done=self._transfer_done, writer=writer)
        # round-robin with a moving base so concurrent datasets do not all
        # pile their first (and for short writes, only) stripe on channel 0.
        # Route over live channels only — stripes queued on a dead channel
        # would just bounce through its handoff path.
        live = self._live_channels() or self._channels
        with self._ctrl_lock:
            base, self._rr = self._rr, (self._rr + len(stripes)) \
                % len(live)
        for i, (off, size) in enumerate(stripes):
            ch = live[(base + i) % len(live)]
            ch.q.put(_Stripe(tr, file_id, name, i, len(stripes), off,
                             flat[off:off + size], writer,
                             enc=codec_info is not None))
        return tr

    def _transfer_done(self, _tr: _Transfer) -> None:
        with self._outstanding_cond:
            self._outstanding -= 1
            self._outstanding_cond.notify_all()

    def send_dataset(self, name: str, dtype: str, buf,
                     timeout: Optional[float] = None) -> int:
        """Blocking form of :meth:`submit_dataset` (FCFS-pool task body):
        returns the byte count once every stripe is acked."""
        tr = self.submit_dataset(name, dtype, buf)
        if not tr.event.wait(timeout or self.transfer_timeout):
            raise TimeoutError(
                f"striped transfer {name!r} not acked within "
                f"{timeout or self.transfer_timeout}s")
        if tr.error is not None:
            raise tr.error
        return tr.nbytes

    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted dataset finished (acked or failed)."""
        deadline = time.monotonic() + timeout if timeout else None
        with self._outstanding_cond:
            while self._outstanding > 0:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"{self._outstanding} striped transfers "
                            "still in flight")
                self._outstanding_cond.wait(remaining)

    # -- introspection --------------------------------------------------
    def channel_stats(self) -> list[dict]:
        """Current channels plus any retired (failed-over) generations."""
        return list(self._retired) + \
            [ch.stats.as_dict() for ch in self._channels]
