"""Wire protocol shared by SAVIME / staging / clients.

Two frame encodings share every connection (DESIGN.md §10):

  * **JSON** (legacy, the control/compat path) —
    8-byte big-endian header length | JSON header | raw payload
    (payload size in header["nbytes"], 0 if none).
  * **bin1** (the data fast path) — a fixed 48-byte struct-packed header
    for the hot data ops (``stripe``, ``reg_block``, ``ack``,
    ``credit``) followed by the raw payload. The first byte is the
    ``BIN_MAGIC`` discriminator: JSON frames always start with 0x00
    (their header length is capped at ``MAX_HEADER_LEN``), so both
    encodings interleave safely on one stream — binary for the per-block
    hot loop, JSON for everything else.

A peer may only *send* bin1 after :func:`negotiate` (the ``hello`` op)
confirmed the other side speaks it; a pre-bin1 server answers ``hello``
with an unknown-op error and the connection stays on JSON. Receivers
need no negotiation — the magic byte is self-describing.

``send_frame_from_file`` streams the payload with ``os.sendfile`` — on Linux
this is the splice/sendfile zero-copy path the paper uses for the
staging→SAVIME hop (§2: "SAVIME uses standard TCP for control operations
combined with the splice syscall for sending data").

``send_frames_vectored`` scatter-gathers many frames (and multi-buffer
payloads) into single ``sendmsg`` calls — the small-frame regime pays one
syscall for a burst of stripes instead of two per frame.

Receive is split into ``recv_header`` / ``recv_payload`` /
``recv_payload_into`` so servers can parse the header first and land the
payload straight into its destination buffer (the striped staging path
recv's into the mmap'd memory region — one copy, like the RDMA path).
Header bytes land in a per-thread scratch buffer and payloads can be
leased from a :class:`BufferPool`, so the per-frame ``bytearray``
allocations are gone from the hot loops.
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import weakref
import zlib
from typing import Any, Iterable, Optional, Sequence

_LEN = struct.Struct(">Q")
CHUNK = 1 << 20

# ---------------------------------------------------------------------------
# fault-injection hook (repro.faults installs / uninstalls it)
# ---------------------------------------------------------------------------

# When non-None, every frame sent on a socket *registered* with the
# injector (wire.connect registers new sockets while a hook is up) passes
# through it first — the injector may delay, duplicate, corrupt a copy of
# the payload, or sever the connection (drop / partition).  None (the
# default) is a zero-branch fast path.
_FAULT_INJECTOR = None


def set_fault_injector(inj) -> None:
    global _FAULT_INJECTOR
    _FAULT_INJECTOR = inj


def fault_injector():
    return _FAULT_INJECTOR

# JSON headers are small dicts; a length prefix beyond this is a corrupt
# or hostile stream, not a real frame — without the cap a bad 8-byte
# prefix makes the header recv allocate gigabytes before failing.  The
# cap also guarantees byte 0 of a JSON frame is 0x00, which is what lets
# BIN_MAGIC discriminate the binary encoding in-band.
MAX_HEADER_LEN = 1 << 20
# Payloads are bounded by staging capacity / block sizes in practice; a
# declared size beyond this is corrupt, and the allocation would happen
# before a single payload byte arrives.
MAX_PAYLOAD_LEN = 8 << 30

# ---------------------------------------------------------------------------
# binary fast path (bin1)
# ---------------------------------------------------------------------------

WIRE_JSON = "json"
WIRE_BIN1 = "bin1"
SUPPORTED_WIRE = (WIRE_BIN1, WIRE_JSON)     # preference order

BIN_MAGIC = 0xB1
BIN_VERSION = 1
# magic | version | op | flags | stripe_idx | file_id/rkey | n_stripes |
# credits | offset | size | payload nbytes  — 48 bytes, no padding
_BIN = struct.Struct(">BBBBI8sIIQQQ")
BIN_HEADER_LEN = _BIN.size

OP_STRIPE, OP_BLOCK, OP_ACK, OP_CREDIT = 1, 2, 3, 4
_OP_NAME = {OP_STRIPE: "stripe", OP_BLOCK: "reg_block", OP_ACK: "ack",
            OP_CREDIT: "credit"}
# low nibble: op flags; high nibble: id length in bytes (0-8), so an id
# whose raw bytes end in 0x00 survives the fixed-width padding exactly
F_SIDED, F_DUP, F_DONE, F_OK = 1, 2, 4, 8
# F_ENC shares bit 1 with F_DUP: F_DUP is only meaningful on acks, F_ENC
# only on stripes (the payload carries codec-encoded bytes), so the bit is
# unambiguous per op and the 48-byte layout stays frozen.
F_ENC = 2


class ProtocolError(ConnectionError):
    """The byte stream is not a valid frame (framing unrecoverable)."""


def _pack_id(tok: str) -> Optional[tuple[bytes, int]]:
    """Hex token (file_id / rkey) -> (8 padded raw bytes, true length),
    or None if it doesn't fit the fixed layout (caller falls back to
    JSON)."""
    if not tok:
        return b"\0" * 8, 0
    try:
        raw = bytes.fromhex(tok)
    except (ValueError, TypeError):
        return None
    if len(raw) > 8:
        return None
    return raw.ljust(8, b"\0"), len(raw)


def encode_bin_header(header: dict[str, Any], nbytes: int) -> Optional[bytes]:
    """Pack one hot-op header into the fixed bin1 layout.

    Returns ``None`` when the header does not fit the fast path (unknown
    op, oversized identifier) — the caller must fall back to JSON.  The
    four ops mirror the dict shapes the servers already produce, so the
    binary path is purely an encoding change.
    """
    op = header.get("op")
    flags = idx = n_stripes = credits = offset = size = 0
    packed = (b"\0" * 8, 0)
    if op == "stripe":
        code = OP_STRIPE
        packed = _pack_id(header.get("file_id", ""))
        idx = int(header.get("stripe_idx", 0))
        n_stripes = int(header.get("n_stripes", 0))
        offset = int(header.get("offset", 0))
        if header.get("sided"):
            flags |= F_SIDED
            size = int(header.get("size", 0))
        else:
            # non-sided stripes never used `size`; under CAP_CRC it
            # carries the payload checksum (0 when crc is off — exactly
            # what pre-crc senders always put there)
            size = int(header.get("crc", 0))
        if header.get("enc"):
            flags |= F_ENC
    elif op == "reg_block":
        code = OP_BLOCK
        packed = _pack_id(header.get("file_id", ""))
        offset = int(header.get("offset", 0))
        size = int(header.get("size", 0))
    elif op == "ack":
        code = OP_ACK
        flags |= F_OK if header.get("ok") else 0
        flags |= F_DUP if header.get("dup") else 0
        flags |= F_DONE if header.get("done") else 0
        idx = int(header.get("stripe_idx") or 0)
        credits = int(header.get("credits") or 0)
        offset = int(header.get("offset") or 0)
        size = int(header.get("size") or 0)
        packed = _pack_id(header.get("rkey", ""))
    elif op == "credit":
        code = OP_CREDIT
        credits = int(header.get("credits") or 0)
    else:
        return None
    if packed is None:
        return None
    fid, id_len = packed
    try:
        return _BIN.pack(BIN_MAGIC, BIN_VERSION, code, flags | (id_len << 4),
                         idx, fid, n_stripes, credits, offset, size, nbytes)
    except struct.error:        # out-of-range field (negative / too wide)
        return None


def decode_bin_header(buf) -> dict[str, Any]:
    """Unpack a 48-byte bin1 header into the equivalent JSON-header dict.

    The resulting dict carries ``"_bin": True`` so servers can reply in
    kind; the marker is stripped before any JSON re-encoding.
    """
    (magic, ver, code, flags, idx, fid, n_stripes, credits, offset, size,
     nbytes) = _BIN.unpack_from(buf, 0)
    if magic != BIN_MAGIC:
        raise ProtocolError(f"bad binary frame magic 0x{magic:02x}")
    if ver != BIN_VERSION:
        raise ProtocolError(f"unsupported binary wire version {ver}")
    op = _OP_NAME.get(code)
    if op is None:
        raise ProtocolError(f"unknown binary op {code}")
    ident = fid[:flags >> 4].hex()
    h: dict[str, Any] = {"op": op, "nbytes": nbytes, "_bin": True}
    if op == "stripe":
        h.update(file_id=ident, stripe_idx=idx, n_stripes=n_stripes,
                 offset=offset)
        if flags & F_SIDED:
            h.update(sided=1, size=size)
        elif size:
            # `size` on a non-sided stripe is the CAP_CRC checksum; the
            # receiver only *verifies* it on connections that negotiated
            # the capability, so a stray value from a buggy peer is inert
            h["crc"] = size
        if flags & F_ENC:
            h["enc"] = 1
    elif op == "reg_block":
        h.update(file_id=ident, offset=offset, size=size)
    elif op == "ack":
        h.update(ok=bool(flags & F_OK), dup=bool(flags & F_DUP),
                 done=bool(flags & F_DONE), stripe_idx=idx, credits=credits,
                 offset=offset, size=size)
        if ident:
            h["rkey"] = ident
    elif op == "credit":
        h.update(credits=credits)
    return h


# -- per-connection negotiation (the hello handshake) -----------------------

# Sockets that completed a hello handshake, mapped to the agreed format.
# Weak keys: entries die with their sockets, no unbounded registry.
_NEGOTIATED: "weakref.WeakKeyDictionary[socket.socket, str]" = \
    weakref.WeakKeyDictionary()
# Sockets mapped to the codec names the peer accepted (DESIGN.md §13).
# Absent / empty means "no codec": a pre-codec server ignores the offer
# (or errors on hello entirely) and the sender falls back to `none`.
_NEGOTIATED_CODECS: "weakref.WeakKeyDictionary[socket.socket, tuple]" = \
    weakref.WeakKeyDictionary()
# Sockets mapped to extra capability names both peers agreed on (today
# just CAP_CRC — payload checksums on stripe frames, DESIGN.md §15).
_NEGOTIATED_CAPS: "weakref.WeakKeyDictionary[socket.socket, tuple]" = \
    weakref.WeakKeyDictionary()

# With CAP_CRC agreed, every non-sided stripe frame carries a CRC32 of
# its payload: JSON stripes in header["crc"], bin1 stripes in the `size`
# struct field (unused for non-sided stripes — sided stripes keep `size`
# for the real region size and skip the checksum; their payload doesn't
# ride this socket).  The capability gate is what keeps the 48-byte bin1
# layout frozen: old peers never see a repurposed field.
CAP_CRC = "crc32"
SUPPORTED_CAPS = (CAP_CRC,)


def crc32(payload) -> int:
    """CRC32 over a payload (bytes-like or list of bytes-like)."""
    parts = (payload if isinstance(payload, (list, tuple))
             else [] if payload is None else [payload])
    c = 0
    for p in parts:
        c = zlib.crc32(memoryview(p).cast("B"), c)
    return c & 0xFFFFFFFF


def negotiate(sock: socket.socket,
              formats: Sequence[str] = SUPPORTED_WIRE,
              codecs: Sequence[str] = (),
              caps: Sequence[str] = ()) -> str:
    """Wire-format (+ codec) handshake: offer, adopt the server's pick.

    A server that predates the handshake answers the unknown ``hello`` op
    with an error — that *is* the negotiation: the connection stays on
    JSON. Likewise a pre-codec server simply omits ``codecs`` from its
    reply and the sender keeps shipping raw bytes (codec ``none``). The
    results are recorded per socket (:func:`negotiated`,
    :func:`negotiated_codecs`)."""
    offer: dict[str, Any] = {"op": "hello", "wire": list(formats)}
    if codecs:
        offer["codecs"] = list(codecs)
    if caps:
        offer["caps"] = list(caps)
    h, _ = request(sock, offer)
    fmt = h.get("wire") if h.get("ok") else None
    if fmt not in formats:
        fmt = WIRE_JSON
    _NEGOTIATED[sock] = fmt
    accepted = h.get("codecs") if h.get("ok") else None
    _NEGOTIATED_CODECS[sock] = tuple(
        c for c in (accepted or ()) if c in codecs)
    agreed = h.get("caps") if h.get("ok") else None
    _NEGOTIATED_CAPS[sock] = tuple(
        c for c in (agreed or ()) if c in caps)
    return fmt


def negotiated(sock: socket.socket) -> str:
    """The format agreed on ``sock`` (JSON when never negotiated)."""
    return _NEGOTIATED.get(sock, WIRE_JSON)


def negotiated_codecs(sock: socket.socket) -> tuple:
    """Codec names both peers speak (empty when never negotiated)."""
    return _NEGOTIATED_CODECS.get(sock, ())


def negotiated_caps(sock: socket.socket) -> tuple:
    """Extra capabilities both peers agreed on (empty pre-handshake)."""
    return _NEGOTIATED_CAPS.get(sock, ())


def set_negotiated_caps(sock: socket.socket, caps: Sequence[str]) -> None:
    """Record the agreed capability set server-side (the server learns
    the intersection when it builds its ``hello`` reply)."""
    _NEGOTIATED_CAPS[sock] = tuple(caps)


def hello_reply(header: dict[str, Any],
                supported: Sequence[str] = SUPPORTED_WIRE,
                codecs: Sequence[str] = (),
                caps: Sequence[str] = ()) -> dict[str, Any]:
    """Server side of the handshake: pick the client's most-preferred
    format this server also speaks (JSON is always common ground), and
    echo the subset of offered codecs this server can decode. Old clients
    never send ``codecs``; old servers never reply with it — either way
    the connection degrades to codec ``none`` silently."""
    reply: dict[str, Any] = {"ok": True, "wire": WIRE_JSON}
    for fmt in header.get("wire") or ():
        if fmt in supported:
            reply["wire"] = fmt
            break
    offered = header.get("codecs")
    if offered and codecs:
        reply["codecs"] = [c for c in offered if c in codecs]
    offered_caps = header.get("caps")
    if offered_caps and caps:
        reply["caps"] = [c for c in offered_caps if c in caps]
    return reply


# ---------------------------------------------------------------------------
# buffer reuse: per-thread header scratch + payload pool
# ---------------------------------------------------------------------------


class _Scratch(threading.local):
    """Per-thread reusable receive buffer for frame headers and drains."""

    def get(self, n: int) -> bytearray:
        buf = getattr(self, "buf", None)
        if buf is None or len(buf) < n:
            buf = self.buf = bytearray(max(n, 4096))
        return buf


_scratch = _Scratch()


class BufferPool:
    """Reusable payload buffers, power-of-two buckets, bounded.

    ``acquire(n)`` leases a length-``n`` memoryview over a pooled
    bytearray; ``release(view)`` returns the backing buffer for reuse.
    Never-released leases degrade to plain allocation — only callers that
    fully consume a payload before the next frame should release, so a
    handler that retains the payload simply keeps it.
    """

    def __init__(self, max_per_bucket: int = 8, max_bytes: int = 64 << 20):
        self._buckets: dict[int, list[bytearray]] = {}
        self._lock = threading.Lock()
        self._max_per_bucket = max_per_bucket
        self._max_bytes = max_bytes
        self._held_bytes = 0

    def acquire(self, n: int) -> memoryview:
        if n <= 0:
            return memoryview(bytearray())
        size = 1 << (n - 1).bit_length()
        with self._lock:
            bucket = self._buckets.get(size)
            if bucket:
                buf = bucket.pop()
                self._held_bytes -= size
            else:
                buf = None
        return memoryview(buf if buf is not None else bytearray(size))[:n]

    def release(self, view: memoryview) -> None:
        buf = view.obj
        view.release()
        if not isinstance(buf, bytearray):
            return
        size = len(buf)
        if size & (size - 1):           # not one of our pow2 buffers
            return
        with self._lock:
            bucket = self._buckets.setdefault(size, [])
            if len(bucket) < self._max_per_bucket and \
                    self._held_bytes + size <= self._max_bytes:
                bucket.append(buf)
                self._held_bytes += size


# ---------------------------------------------------------------------------
# send side
# ---------------------------------------------------------------------------


def _payload_views(payload) -> list[memoryview]:
    """Normalize a payload (None | bytes-like | list of bytes-like) into
    contiguous byte views for scatter-gather I/O."""
    if payload is None:
        return []
    parts = payload if isinstance(payload, (list, tuple)) else [payload]
    views = []
    for p in parts:
        v = p if isinstance(p, memoryview) else memoryview(p)
        v = v.cast("B")
        if len(v):
            views.append(v)
    return views


def encode_frame(header: dict[str, Any], payload=None,
                 fmt: str = WIRE_JSON) -> list:
    """Encode one frame into an iovec list (header bytes + payload views,
    payload never copied). ``fmt=bin1`` uses the fixed fast-path layout
    for hot ops and falls back to JSON for everything else — JSON remains
    the control path on binary connections."""
    views = _payload_views(payload)
    nbytes = sum(len(v) for v in views)
    if fmt == WIRE_BIN1:
        hb = encode_bin_header(header, nbytes)
        if hb is not None:
            # binary error acks carry the message as their payload
            if header.get("op") == "ack" and not header.get("ok") \
                    and not views and header.get("error"):
                err = str(header["error"]).encode("utf-8", "replace")
                hb = encode_bin_header(header, len(err))
                return [hb, err]
            return [hb, *views]
    clean = {k: v for k, v in header.items() if not k.startswith("_")}
    hb = json.dumps(dict(clean, nbytes=nbytes)).encode()
    return [_LEN.pack(len(hb)) + hb, *views]


_HAS_SENDMSG = hasattr(socket.socket, "sendmsg")
_IOV_CAP = 512      # stay well under IOV_MAX (1024 on Linux)


def sendmsg_all(sock: socket.socket, bufs: Sequence) -> None:
    """Send every buffer, scatter-gather, handling partial sends."""
    views = [v for v in (b if isinstance(b, memoryview) else memoryview(b)
                         for b in bufs) if len(v)]
    if not _HAS_SENDMSG:
        for v in views:
            sock.sendall(v)
        return
    i, off = 0, 0
    while i < len(views):
        batch = [views[i][off:] if off else views[i]]
        batch.extend(views[i + 1:i + _IOV_CAP])
        n = sock.sendmsg(batch)
        if n == 0:
            raise ConnectionError("sendmsg: peer closed")
        while n and i < len(views):
            rem = len(views[i]) - off
            if n >= rem:
                n -= rem
                i += 1
                off = 0
            else:
                off += n
                n = 0


def send_frame(sock: socket.socket, header: dict[str, Any],
               payload: Optional[memoryview | bytes] = None) -> None:
    """Legacy JSON frame send (byte-identical to the pre-bin1 wire)."""
    inj = _FAULT_INJECTOR
    frames = [(header, payload)]
    if inj is not None:
        frames = inj.on_send(sock, frames)
    for header, payload in frames:
        payload = b"" if payload is None else payload
        clean = {k: v for k, v in header.items() if not k.startswith("_")}
        hb = json.dumps(dict(clean, nbytes=len(payload))).encode()
        sock.sendall(_LEN.pack(len(hb)) + hb)
        if len(payload):
            sock.sendall(payload)


def send_frame_bin(sock: socket.socket, header: dict[str, Any],
                   payload=None) -> None:
    """Send one frame on the bin1 fast path (one ``sendmsg`` for header +
    payload); non-hot headers transparently ride JSON."""
    inj = _FAULT_INJECTOR
    frames = [(header, payload)]
    if inj is not None:
        frames = inj.on_send(sock, frames)
    bufs: list = []
    for h, p in frames:
        bufs.extend(encode_frame(h, p, WIRE_BIN1))
    sendmsg_all(sock, bufs)


def send_frames_vectored(sock: socket.socket,
                         frames: Iterable[tuple], fmt: str = WIRE_JSON) -> int:
    """Scatter-gather many ``(header, payload)`` frames into as few
    ``sendmsg`` calls as possible (one, below the iovec cap).  ``payload``
    may itself be a list of buffers — nothing is concatenated in user
    space.  Returns the number of frames sent."""
    inj = _FAULT_INJECTOR
    if inj is not None:
        frames = inj.on_send(sock, list(frames))
    bufs: list = []
    n = 0
    for header, payload in frames:
        bufs.extend(encode_frame(header, payload, fmt))
        n += 1
    if bufs:
        sendmsg_all(sock, bufs)
    return n


def send_frame_from_file(sock: socket.socket, header: dict[str, Any],
                         fd: int, count: int, offset: int = 0,
                         timeout: float = 30.0) -> None:
    """Zero-copy payload path (os.sendfile == splice on Linux).

    Sockets with a timeout are internally non-blocking: sendfile raises
    EAGAIN when the send buffer fills — wait for writability and resume.
    A peer that never drains makes writability never arrive; that is a
    ``TimeoutError``, not a spin.
    """
    import select
    header = dict(header, nbytes=count)
    hb = json.dumps(header).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb)
    sent = 0
    while sent < count:
        try:
            n = os.sendfile(sock.fileno(), fd, offset + sent, count - sent)
        except BlockingIOError:
            _, writable, _ = select.select([], [sock], [], timeout)
            if not writable:
                raise TimeoutError(
                    f"sendfile: peer not writable for {timeout}s "
                    f"({sent}/{count} bytes sent)") from None
            continue
        if n == 0:
            raise ConnectionError("sendfile: peer closed")
        sent += n


# ---------------------------------------------------------------------------
# receive side
# ---------------------------------------------------------------------------


def recv_into(sock: socket.socket, view) -> None:
    """Receive exactly ``len(view)`` bytes into a writable buffer."""
    mv = memoryview(view).cast("B")
    n = len(mv)
    got = 0
    while got < n:
        r = sock.recv_into(mv[got:], min(n - got, CHUNK))
        if r == 0:
            raise ConnectionError("recv: peer closed")
        got += r


def recv_header(sock: socket.socket) -> dict[str, Any]:
    """Read one frame header — bin1 (magic-discriminated) or JSON.

    Header bytes land in a per-thread scratch buffer: no per-frame
    allocation, and the JSON text is decoded straight from the scratch
    view (the old path materialized the buffer twice via ``bytes()``).
    Binary headers set ``"_bin": True`` so servers can reply in kind.
    """
    scratch = _scratch.get(BIN_HEADER_LEN)
    recv_into(sock, memoryview(scratch)[:8])
    if scratch[0] == BIN_MAGIC:
        recv_into(sock, memoryview(scratch)[8:BIN_HEADER_LEN])
        return decode_bin_header(scratch)
    hlen = _LEN.unpack_from(scratch, 0)[0]
    if hlen > MAX_HEADER_LEN:
        raise ProtocolError(
            f"frame header length {hlen} exceeds {MAX_HEADER_LEN} "
            "(corrupt or hostile length prefix)")
    scratch = _scratch.get(hlen)
    recv_into(sock, memoryview(scratch)[:hlen])
    return json.loads(str(memoryview(scratch)[:hlen], "utf-8"))


def recv_payload(sock: socket.socket, header: dict[str, Any],
                 pool: Optional[BufferPool] = None):
    """Receive a frame's payload. With ``pool``, the buffer is leased
    from it (caller releases when done); otherwise a fresh bytearray."""
    n = int(header.get("nbytes") or 0)
    if n > MAX_PAYLOAD_LEN:
        raise ProtocolError(
            f"frame payload length {n} exceeds {MAX_PAYLOAD_LEN} "
            "(corrupt or hostile header)")
    if pool is not None:
        buf = pool.acquire(n)
        if n:
            recv_into(sock, buf)
        return buf
    buf = bytearray(n)
    if n:
        recv_into(sock, buf)
    return buf


def drain_payload(sock: socket.socket, header: dict[str, Any]) -> None:
    """Consume and discard a frame's payload in bounded chunks — for
    rejecting a frame whose declared size should not be trusted with a
    single up-front allocation. Reuses the per-thread scratch buffer
    instead of allocating per call."""
    n = int(header.get("nbytes") or 0)
    if n > MAX_PAYLOAD_LEN:
        raise ProtocolError(
            f"frame payload length {n} exceeds {MAX_PAYLOAD_LEN} "
            "(corrupt or hostile header)")
    if not n:
        return
    view = memoryview(_scratch.get(min(n, CHUNK)))
    got = 0
    while got < n:
        r = sock.recv_into(view[:min(n - got, CHUNK)])
        if r == 0:
            raise ConnectionError("recv: peer closed")
        got += r


def recv_frame(sock: socket.socket,
               pool: Optional[BufferPool] = None) -> tuple[dict[str, Any], Any]:
    header = recv_header(sock)
    inj = _FAULT_INJECTOR
    if inj is not None:
        inj.on_recv(sock, header)
    payload = recv_payload(sock, header, pool)
    # binary error acks carry their message as the payload
    if header.get("_bin") and header.get("op") == "ack" \
            and not header.get("ok") and len(payload):
        header["error"] = bytes(payload).decode("utf-8", "replace")
    return header, payload


def request(sock: socket.socket, header: dict[str, Any],
            payload: Optional[memoryview | bytes] = None):
    send_frame(sock, header, payload)
    return recv_frame(sock)


def connect(addr: str, timeout: float = 30.0) -> socket.socket:
    inj = _FAULT_INJECTOR
    if inj is not None:
        inj.check_connect(addr)      # active partition => ConnectionError
    host, port = addr.rsplit(":", 1)
    s = socket.create_connection((host, int(port)), timeout=timeout)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    if inj is not None:
        inj.register(s, addr)        # bring the new conn into fault scope
    return s


class ConnCache:
    """One cached connection per (calling thread, addr), tracked for close.

    The I/O pools want one connection per worker thread (≈ an RC QP, or
    an ssh session in the copy emulation); ``close_all`` is hooked to the
    owner's stop path so no connection outlives its pool.  ``factory``
    may build anything with a ``close()`` method (sockets, clients).

    The per-thread cache is keyed by ``addr``: a thread that talks to two
    endpoints gets two connections — it used to silently reuse whichever
    connection it opened first, sending frames to the wrong server.
    """

    def __init__(self):
        import threading
        self._local = threading.local()
        self._all: list = []
        self._lock = threading.Lock()

    def get(self, addr: str, factory=connect):
        objs = getattr(self._local, "objs", None)
        if objs is None:
            objs = self._local.objs = {}
        obj = objs.get(addr)
        if obj is None:
            obj = objs[addr] = factory(addr)
            with self._lock:
                self._all.append(obj)
        return obj

    def invalidate(self, addr: str) -> None:
        """Drop (and close) the *calling thread's* cached connection to
        ``addr`` — the reconnect path after a send/recv error, so the next
        ``get`` builds a fresh one instead of reusing a dead socket."""
        objs = getattr(self._local, "objs", None)
        obj = objs.pop(addr, None) if objs else None
        if obj is None:
            return
        with self._lock:
            try:
                self._all.remove(obj)
            except ValueError:
                pass
        try:
            obj.close()
        except (OSError, RuntimeError):
            pass

    def close_all(self) -> None:
        with self._lock:
            objs, self._all = self._all, []
        for o in objs:
            try:
                o.close()
            except (OSError, RuntimeError):
                pass
