"""Wire protocol shared by SAVIME / staging / clients.

Frame = 8-byte big-endian header length | JSON header | raw payload
(payload size in header["nbytes"], 0 if none).

``send_frame_from_file`` streams the payload with ``os.sendfile`` — on Linux
this is the splice/sendfile zero-copy path the paper uses for the
staging→SAVIME hop (§2: "SAVIME uses standard TCP for control operations
combined with the splice syscall for sending data").

Receive is split into ``recv_header`` / ``recv_payload`` /
``recv_payload_into`` so servers can parse the header first and land the
payload straight into its destination buffer (the striped staging path
recv's into the mmap'd memory region — one copy, like the RDMA path).
"""
from __future__ import annotations

import json
import os
import socket
import struct
from typing import Any, Optional

_LEN = struct.Struct(">Q")
CHUNK = 1 << 20

# JSON headers are small dicts; a length prefix beyond this is a corrupt
# or hostile stream, not a real frame — without the cap a bad 8-byte
# prefix makes _recv_exact allocate gigabytes before failing.
MAX_HEADER_LEN = 1 << 20
# Payloads are bounded by staging capacity / block sizes in practice; a
# declared size beyond this is corrupt, and the allocation would happen
# before a single payload byte arrives.
MAX_PAYLOAD_LEN = 8 << 30


class ProtocolError(ConnectionError):
    """The byte stream is not a valid frame (framing unrecoverable)."""


def send_frame(sock: socket.socket, header: dict[str, Any],
               payload: Optional[memoryview | bytes] = None) -> None:
    payload = b"" if payload is None else payload
    header = dict(header, nbytes=len(payload))
    hb = json.dumps(header).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb)
    if len(payload):
        sock.sendall(payload)


def send_frame_from_file(sock: socket.socket, header: dict[str, Any],
                         fd: int, count: int, offset: int = 0,
                         timeout: float = 30.0) -> None:
    """Zero-copy payload path (os.sendfile == splice on Linux).

    Sockets with a timeout are internally non-blocking: sendfile raises
    EAGAIN when the send buffer fills — wait for writability and resume.
    A peer that never drains makes writability never arrive; that is a
    ``TimeoutError``, not a spin.
    """
    import select
    header = dict(header, nbytes=count)
    hb = json.dumps(header).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb)
    sent = 0
    while sent < count:
        try:
            n = os.sendfile(sock.fileno(), fd, offset + sent, count - sent)
        except BlockingIOError:
            _, writable, _ = select.select([], [sock], [], timeout)
            if not writable:
                raise TimeoutError(
                    f"sendfile: peer not writable for {timeout}s "
                    f"({sent}/{count} bytes sent)") from None
            continue
        if n == 0:
            raise ConnectionError("sendfile: peer closed")
        sent += n


def _recv_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    recv_into(sock, buf)
    return buf


def recv_into(sock: socket.socket, view) -> None:
    """Receive exactly ``len(view)`` bytes into a writable buffer."""
    mv = memoryview(view).cast("B")
    n = len(mv)
    got = 0
    while got < n:
        r = sock.recv_into(mv[got:], min(n - got, CHUNK))
        if r == 0:
            raise ConnectionError("recv: peer closed")
        got += r


def recv_header(sock: socket.socket) -> dict[str, Any]:
    hlen = _LEN.unpack(bytes(_recv_exact(sock, 8)))[0]
    if hlen > MAX_HEADER_LEN:
        raise ProtocolError(
            f"frame header length {hlen} exceeds {MAX_HEADER_LEN} "
            "(corrupt or hostile length prefix)")
    return json.loads(bytes(_recv_exact(sock, hlen)))


def recv_payload(sock: socket.socket, header: dict[str, Any]) -> bytearray:
    n = int(header.get("nbytes") or 0)
    if n > MAX_PAYLOAD_LEN:
        raise ProtocolError(
            f"frame payload length {n} exceeds {MAX_PAYLOAD_LEN} "
            "(corrupt or hostile header)")
    return _recv_exact(sock, n) if n else bytearray()


def drain_payload(sock: socket.socket, header: dict[str, Any]) -> None:
    """Consume and discard a frame's payload in bounded chunks — for
    rejecting a frame whose declared size should not be trusted with a
    single up-front allocation."""
    n = int(header.get("nbytes") or 0)
    if n > MAX_PAYLOAD_LEN:
        raise ProtocolError(
            f"frame payload length {n} exceeds {MAX_PAYLOAD_LEN} "
            "(corrupt or hostile header)")
    scratch = bytearray(min(n, CHUNK))
    view = memoryview(scratch)
    got = 0
    while got < n:
        r = sock.recv_into(view[:min(n - got, CHUNK)])
        if r == 0:
            raise ConnectionError("recv: peer closed")
        got += r


def recv_frame(sock: socket.socket) -> tuple[dict[str, Any], bytearray]:
    header = recv_header(sock)
    return header, recv_payload(sock, header)


def request(sock: socket.socket, header: dict[str, Any],
            payload: Optional[memoryview | bytes] = None):
    send_frame(sock, header, payload)
    return recv_frame(sock)


def connect(addr: str, timeout: float = 30.0) -> socket.socket:
    host, port = addr.rsplit(":", 1)
    s = socket.create_connection((host, int(port)), timeout=timeout)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


class ConnCache:
    """One cached connection per (calling thread, addr), tracked for close.

    The I/O pools want one connection per worker thread (≈ an RC QP, or
    an ssh session in the copy emulation); ``close_all`` is hooked to the
    owner's stop path so no connection outlives its pool.  ``factory``
    may build anything with a ``close()`` method (sockets, clients).

    The per-thread cache is keyed by ``addr``: a thread that talks to two
    endpoints gets two connections — it used to silently reuse whichever
    connection it opened first, sending frames to the wrong server.
    """

    def __init__(self):
        import threading
        self._local = threading.local()
        self._all: list = []
        self._lock = threading.Lock()

    def get(self, addr: str, factory=connect):
        objs = getattr(self._local, "objs", None)
        if objs is None:
            objs = self._local.objs = {}
        obj = objs.get(addr)
        if obj is None:
            obj = objs[addr] = factory(addr)
            with self._lock:
                self._all.append(obj)
        return obj

    def close_all(self) -> None:
        with self._lock:
            objs, self._all = self._all, []
        for o in objs:
            try:
                o.close()
            except (OSError, RuntimeError):
                pass
