"""Wire protocol shared by SAVIME / staging / clients.

Frame = 8-byte big-endian header length | JSON header | raw payload
(payload size in header["nbytes"], 0 if none).

``send_frame_from_file`` streams the payload with ``os.sendfile`` — on Linux
this is the splice/sendfile zero-copy path the paper uses for the
staging→SAVIME hop (§2: "SAVIME uses standard TCP for control operations
combined with the splice syscall for sending data").
"""
from __future__ import annotations

import json
import os
import socket
import struct
from typing import Any, Optional

_LEN = struct.Struct(">Q")
CHUNK = 1 << 20


def send_frame(sock: socket.socket, header: dict[str, Any],
               payload: Optional[memoryview | bytes] = None) -> None:
    payload = b"" if payload is None else payload
    header = dict(header, nbytes=len(payload))
    hb = json.dumps(header).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb)
    if len(payload):
        sock.sendall(payload)


def send_frame_from_file(sock: socket.socket, header: dict[str, Any],
                         fd: int, count: int, offset: int = 0) -> None:
    """Zero-copy payload path (os.sendfile == splice on Linux).

    Sockets with a timeout are internally non-blocking: sendfile raises
    EAGAIN when the send buffer fills — wait for writability and resume.
    """
    import select
    header = dict(header, nbytes=count)
    hb = json.dumps(header).encode()
    sock.sendall(_LEN.pack(len(hb)) + hb)
    sent = 0
    while sent < count:
        try:
            n = os.sendfile(sock.fileno(), fd, offset + sent, count - sent)
        except BlockingIOError:
            select.select([], [sock], [], 30.0)
            continue
        if n == 0:
            raise ConnectionError("sendfile: peer closed")
        sent += n


def _recv_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], min(n - got, CHUNK))
        if r == 0:
            raise ConnectionError("recv: peer closed")
        got += r
    return buf


def recv_frame(sock: socket.socket) -> tuple[dict[str, Any], bytearray]:
    hlen = _LEN.unpack(bytes(_recv_exact(sock, 8)))[0]
    header = json.loads(bytes(_recv_exact(sock, hlen)))
    payload = _recv_exact(sock, header.get("nbytes", 0)) \
        if header.get("nbytes") else bytearray()
    return header, payload


def request(sock: socket.socket, header: dict[str, Any],
            payload: Optional[memoryview | bytes] = None):
    send_frame(sock, header, payload)
    return recv_frame(sock)


def connect(addr: str, timeout: float = 30.0) -> socket.socket:
    host, port = addr.rsplit(":", 1)
    s = socket.create_connection((host, int(port)), timeout=timeout)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


class ConnCache:
    """One cached connection per calling thread, all tracked for close.

    The I/O pools want one connection per worker thread (≈ an RC QP, or
    an ssh session in the copy emulation); ``close_all`` is hooked to the
    owner's stop path so no connection outlives its pool.  ``factory``
    may build anything with a ``close()`` method (sockets, clients).
    """

    def __init__(self):
        import threading
        self._local = threading.local()
        self._all: list = []
        self._lock = threading.Lock()

    def get(self, addr: str, factory=connect):
        obj = getattr(self._local, "obj", None)
        if obj is None:
            obj = factory(addr)
            self._local.obj = obj
            with self._lock:
                self._all.append(obj)
        return obj

    def close_all(self) -> None:
        with self._lock:
            objs, self._all = self._all, []
        for o in objs:
            try:
                o.close()
            except (OSError, RuntimeError):
                pass
