"""RDMA emulation over POSIX shared memory (/dev/shm mmap).

Faithful to the paper's §3.2 data path on a single Linux host:

  * the staging server ``mmap()``s an in-memory file *without touching the
    mapped memory or registering it* (lazy);
  * blocks are *registered on demand* when the client asks for them —
    emulated by populating the block's pages (page pinning is the dominant
    cost of ibv_reg_mr) and minting an rkey;
  * the client maps the same file and performs **one-sided writes** — raw
    memory stores into the server's region with zero server-CPU involvement
    (numpy ``copyto`` releases the GIL, so I/O threads truly overlap);
  * a two-sided sync message (over the TCP control channel, = the RC QP's
    send/recv) ends the transfer, after which the server may deregister.

What intentionally does NOT transfer from real verbs hardware: QP state
machines, MTU segmentation, CQ polling (see DESIGN.md §2).
"""
from __future__ import annotations

import mmap
import os
import secrets
import threading
from typing import Optional

import numpy as np


class MemoryRegion:
    """Server-side registered memory region backed by a (tmpfs) file."""

    paged = False

    def __init__(self, path: str, nbytes: int, create: bool = True):
        self.path = path
        self.nbytes = nbytes
        flags = os.O_RDWR | (os.O_CREAT if create else 0)
        self._fd = os.open(path, flags, 0o600)
        if create:
            os.ftruncate(self._fd, nbytes)
        self._mm = mmap.mmap(self._fd, nbytes) if nbytes else None
        self._registered: dict[tuple[int, int], str] = {}
        self._lock = threading.Lock()

    @property
    def fd(self) -> int:
        return self._fd

    def view(self) -> np.ndarray:
        return np.frombuffer(self._mm, dtype=np.uint8)

    def segments(self, offset: int = 0, size: Optional[int] = None) \
            -> list[np.ndarray]:
        """Writable views covering a byte range (one contiguous view for
        a flat region; the paged variant scatters across frames)."""
        if size is None:
            size = self.nbytes - offset
        if size == 0:
            return []
        return [self.view()[offset:offset + size]]

    def register_block(self, offset: int, size: int) -> dict:
        """On-demand registration (paper: "the server register each block as
        needed before sending the remote memory address information")."""
        if offset < 0 or offset + size > self.nbytes:
            raise ValueError(f"block [{offset},{offset + size}) outside MR")
        with self._lock:
            key = (offset, size)
            if key not in self._registered:
                # populate pages = the pinning cost of ibv_reg_mr
                v = self.view()[offset:offset + size]
                v[::mmap.PAGESIZE] = v[::mmap.PAGESIZE]
                self._registered[key] = secrets.token_hex(4)
            return {"offset": offset, "size": size,
                    "rkey": self._registered[key]}

    def deregister_all(self) -> None:
        with self._lock:
            self._registered.clear()

    def is_registered(self, offset: int, size: int, rkey: str) -> bool:
        with self._lock:
            return self._registered.get((offset, size)) == rkey

    def close(self, unlink: bool = False) -> None:
        if self._mm is not None:
            try:
                self._mm.close()
            except BufferError:
                # a numpy view is still exported; the mapping is reclaimed
                # when the last view dies — safe to continue (file still
                # unlinked below, memory freed on last unmap)
                pass
            self._mm = None
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1
        if unlink:
            try:
                os.unlink(self.path)
            except OSError:
                pass


class PagedMemoryRegion:
    """MemoryRegion-compatible facade over a :class:`~repro.core.
    pagestore.PageStore` page table (DESIGN.md §11).

    The dataset's bytes live in fixed-size pages scattered across the
    store's arena (and, once sealed and cold, its spill tier); views
    gather/scatter across the non-contiguous frames.  ``path`` is the
    *arena* path — a one-sided client maps the arena once and translates
    dataset offsets through the ``frame_offsets()`` table
    (:class:`PagedRdmaWriter` is that translation on the client side).
    """

    paged = True

    def __init__(self, store, table):
        self.store = store
        self.table = table
        self.path = store.arena_path
        self.nbytes = table.nbytes
        self._registered: dict[tuple[int, int], str] = {}
        self._lock = threading.Lock()
        self._freed = False

    @property
    def fd(self) -> None:
        return None          # no flat file: forward gathers page views

    def segments(self, offset: int = 0, size: Optional[int] = None):
        return self.store.segments(self.table, offset, size)

    def frame_offsets(self) -> list[int]:
        return self.store.frame_offsets(self.table)

    def register_block(self, offset: int, size: int) -> dict:
        """On-demand registration, page-granular: populating each frame
        emulates the pinning cost of ibv_reg_mr exactly like the flat
        region — just over scattered pages."""
        if offset < 0 or offset + size > self.nbytes:
            raise ValueError(f"block [{offset},{offset + size}) outside MR")
        with self._lock:
            key = (offset, size)
            if key not in self._registered:
                for seg in self.segments(offset, size):
                    seg[::mmap.PAGESIZE] = seg[::mmap.PAGESIZE]
                self._registered[key] = secrets.token_hex(4)
            return {"offset": offset, "size": size,
                    "rkey": self._registered[key]}

    def deregister_all(self) -> None:
        with self._lock:
            self._registered.clear()

    def is_registered(self, offset: int, size: int, rkey: str) -> bool:
        with self._lock:
            return self._registered.get((offset, size)) == rkey

    # -- paged lifecycle -------------------------------------------------
    def seal(self) -> None:
        """Mark fully received: pages become spillable and dedup-able."""
        self.store.seal(self.table)

    def pin(self) -> None:
        self.store.pin(self.table)

    def unpin(self) -> None:
        self.store.unpin(self.table)

    def page_views(self) -> list:
        """Gather list for the forward path (pin first)."""
        return self.store.page_views(self.table)

    def read(self, offset: int = 0, size: Optional[int] = None) -> bytearray:
        return self.store.read(self.table, offset, size)

    def close(self, unlink: bool = False) -> None:
        if self._freed:
            return
        self._freed = True
        self.store.free(self.table)


def writer_for_reply(h: dict, nbytes: int):
    """Pick the client-side writer a reservation reply calls for: a
    paged server ships ``frames`` (its page-translation table) and gets
    a :class:`PagedRdmaWriter`; a flat one gets :class:`RdmaWriter`."""
    frames = h.get("frames")
    if frames is not None:
        return PagedRdmaWriter(h["path"], int(h["page_bytes"]), frames,
                               nbytes)
    return RdmaWriter(h["path"], nbytes)


class RdmaWriter:
    """Client-side endpoint for one-sided writes into a remote MR."""

    def __init__(self, path: str, nbytes: int):
        self._mr = MemoryRegion(path, nbytes, create=False)
        self._view: Optional[np.ndarray] = self._mr.view()

    def write(self, offset: int, buf: np.ndarray | memoryview | bytes,
              rkey: Optional[str] = None) -> int:
        """One-sided RDMA write: raw store into the remote region.
        numpy copyto releases the GIL -> concurrent I/O threads overlap."""
        src = np.frombuffer(buf, dtype=np.uint8) if not isinstance(buf, np.ndarray) \
            else buf.view(np.uint8).reshape(-1)
        np.copyto(self._view[offset:offset + src.size], src)
        return src.size

    def close(self) -> None:
        self._view = None  # drop the buffer export before unmapping
        self._mr.close()


class PagedRdmaWriter:
    """One-sided writer into a *paged* remote MR.

    Maps the server's page arena once and translates dataset offsets to
    frame offsets through the page table the server shipped at
    reservation time (``frames``: arena byte offset of each page) — the
    client-side half of scatter/gather over non-contiguous pages.  Same
    contract as :class:`RdmaWriter`: raw stores, no server CPU.
    """

    def __init__(self, path: str, page_bytes: int, frames: list[int],
                 nbytes: int):
        if page_bytes < 1:
            raise ValueError(f"page_bytes must be >= 1, got {page_bytes}")
        self.page_bytes = page_bytes
        self.frames = [int(f) for f in frames]
        self.nbytes = nbytes
        self._mr = MemoryRegion(path, os.path.getsize(path), create=False)
        self._view: Optional[np.ndarray] = self._mr.view()

    def write(self, offset: int, buf, rkey: Optional[str] = None) -> int:
        src = np.frombuffer(buf, dtype=np.uint8) \
            if not isinstance(buf, np.ndarray) \
            else buf.reshape(-1).view(np.uint8)
        if offset < 0 or offset + src.size > self.nbytes:
            raise ValueError(
                f"write [{offset},{offset + src.size}) outside MR "
                f"[0,{self.nbytes})")
        pos = 0
        while pos < src.size:
            idx, in_off = divmod(offset + pos, self.page_bytes)
            n = min(self.page_bytes - in_off, src.size - pos)
            dst = self.frames[idx] + in_off
            np.copyto(self._view[dst:dst + n], src[pos:pos + n])
            pos += n
        return src.size

    def close(self) -> None:
        self._view = None
        self._mr.close()
