"""RDMA emulation over POSIX shared memory (/dev/shm mmap).

Faithful to the paper's §3.2 data path on a single Linux host:

  * the staging server ``mmap()``s an in-memory file *without touching the
    mapped memory or registering it* (lazy);
  * blocks are *registered on demand* when the client asks for them —
    emulated by populating the block's pages (page pinning is the dominant
    cost of ibv_reg_mr) and minting an rkey;
  * the client maps the same file and performs **one-sided writes** — raw
    memory stores into the server's region with zero server-CPU involvement
    (numpy ``copyto`` releases the GIL, so I/O threads truly overlap);
  * a two-sided sync message (over the TCP control channel, = the RC QP's
    send/recv) ends the transfer, after which the server may deregister.

What intentionally does NOT transfer from real verbs hardware: QP state
machines, MTU segmentation, CQ polling (see DESIGN.md §2).
"""
from __future__ import annotations

import mmap
import os
import secrets
import threading
from typing import Optional

import numpy as np


class MemoryRegion:
    """Server-side registered memory region backed by a (tmpfs) file."""

    def __init__(self, path: str, nbytes: int, create: bool = True):
        self.path = path
        self.nbytes = nbytes
        flags = os.O_RDWR | (os.O_CREAT if create else 0)
        self._fd = os.open(path, flags, 0o600)
        if create:
            os.ftruncate(self._fd, nbytes)
        self._mm = mmap.mmap(self._fd, nbytes) if nbytes else None
        self._registered: dict[tuple[int, int], str] = {}
        self._lock = threading.Lock()

    @property
    def fd(self) -> int:
        return self._fd

    def view(self) -> np.ndarray:
        return np.frombuffer(self._mm, dtype=np.uint8)

    def register_block(self, offset: int, size: int) -> dict:
        """On-demand registration (paper: "the server register each block as
        needed before sending the remote memory address information")."""
        if offset < 0 or offset + size > self.nbytes:
            raise ValueError(f"block [{offset},{offset + size}) outside MR")
        with self._lock:
            key = (offset, size)
            if key not in self._registered:
                # populate pages = the pinning cost of ibv_reg_mr
                v = self.view()[offset:offset + size]
                v[::mmap.PAGESIZE] = v[::mmap.PAGESIZE]
                self._registered[key] = secrets.token_hex(4)
            return {"offset": offset, "size": size,
                    "rkey": self._registered[key]}

    def deregister_all(self) -> None:
        with self._lock:
            self._registered.clear()

    def is_registered(self, offset: int, size: int, rkey: str) -> bool:
        with self._lock:
            return self._registered.get((offset, size)) == rkey

    def close(self, unlink: bool = False) -> None:
        if self._mm is not None:
            try:
                self._mm.close()
            except BufferError:
                # a numpy view is still exported; the mapping is reclaimed
                # when the last view dies — safe to continue (file still
                # unlinked below, memory freed on last unmap)
                pass
            self._mm = None
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1
        if unlink:
            try:
                os.unlink(self.path)
            except OSError:
                pass


class RdmaWriter:
    """Client-side endpoint for one-sided writes into a remote MR."""

    def __init__(self, path: str, nbytes: int):
        self._mr = MemoryRegion(path, nbytes, create=False)
        self._view: Optional[np.ndarray] = self._mr.view()

    def write(self, offset: int, buf: np.ndarray | memoryview | bytes,
              rkey: Optional[str] = None) -> int:
        """One-sided RDMA write: raw store into the remote region.
        numpy copyto releases the GIL -> concurrent I/O threads overlap."""
        src = np.frombuffer(buf, dtype=np.uint8) if not isinstance(buf, np.ndarray) \
            else buf.view(np.uint8).reshape(-1)
        np.copyto(self._view[offset:offset + src.size], src)
        return src.size

    def close(self) -> None:
        self._view = None  # drop the buffer export before unmapping
        self._mr.close()
