"""Transfer engines: the staged-RDMA path vs the paper's §4 baselines.

Engines (all use real sockets / real tmpfs files / real sendfile on this
host — scaled datasets, same mechanisms; see DESIGN.md §6 scaling honesty):

  rdma_staged  libstaging -> staging server (shm one-sided writes, block
               knob, FCFS pool) -> SAVIME via sendfile.      [the paper]
  scp_mem      pdsh+scp emulation into tmpfs on the staging node: TCP with
               16 KiB userspace copies + per-chunk CRC (cipher-cost proxy).
  scp_disk     same but staging storage is disk, fsync'd ("huge overhead,
               18x slower" — paper Fig 6).
  ssh_direct   SSH-tunnel emulation: two chained TCP hops (compute->staging
               ->SAVIME), userspace copies + CRC at every hop, no staging
               store ("about 4 minutes" — paper §4).

Each engine reports wall-clock to-staging and end-to-end (drained) times.
"""
from __future__ import annotations

import dataclasses
import os
import secrets
import socket
import threading
import time
import zlib
from typing import Optional

import numpy as np

from repro.core import wire
from repro.core.client import Dataset, StagingClient
from repro.core.queues import FCFSPool
from repro.core.savime import SavimeClient
from repro.core.staging import StagingServer

_SCP_CHUNK = 16 << 10   # scp/ssh move data through ~16K cipher blocks


@dataclasses.dataclass
class TransferResult:
    engine: str
    nbytes: int
    n_datasets: int
    to_staging_s: float
    end_to_end_s: float

    @property
    def staging_gbps(self) -> float:
        return self.nbytes / max(self.to_staging_s, 1e-9) / 1e9


# ---------------------------------------------------------------------------
# scp / ssh emulation servers
# ---------------------------------------------------------------------------


class _CopyServer:
    """Receives frames with userspace 16K copies + CRC; stores (scp) or
    forwards (ssh tunnel hop)."""

    def __init__(self, store_dir: Optional[str], fsync: bool,
                 forward_addr: Optional[str] = None,
                 savime_addr: Optional[str] = None,
                 disk_bw: Optional[float] = None):
        self.store_dir = store_dir
        self.fsync = fsync
        self.forward_addr = forward_addr
        self.savime_addr = savime_addr
        self.disk_bw = disk_bw  # B/s cap modeling the paper's 2018 disk array
        self._local = threading.local()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(64)
        self.addr = f"127.0.0.1:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        threading.Thread(target=self._accept, daemon=True,
                         name="copysrv-accept").start()

    def stop(self):
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass

    def _accept(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            threading.Thread(target=self._serve, args=(conn,), daemon=True,
                             name="copysrv-conn").start()

    def _serve(self, conn: socket.socket):
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with conn:
            while True:
                try:
                    header, payload = self._recv_copied(conn)
                except (ConnectionError, OSError):
                    return
                try:
                    self._sink(header, payload)
                    wire.send_frame(conn, {"ok": True})
                except Exception as e:  # noqa: BLE001
                    try:
                        wire.send_frame(conn, {"ok": False, "error": str(e)})
                    except OSError:
                        return

    def _recv_copied(self, conn):
        """recv with deliberate userspace chunk copies + CRC per chunk —
        models scp/ssh's copy+cipher CPU path (vs sendfile/RDMA zero-copy)."""
        import json
        import struct
        raw = b""
        while len(raw) < 8:
            r = conn.recv(8 - len(raw))
            if not r:
                raise ConnectionError("closed")
            raw += r
        hlen = struct.unpack(">Q", raw)[0]
        hb = b""
        while len(hb) < hlen:
            r = conn.recv(hlen - len(hb))
            if not r:
                raise ConnectionError("closed")
            hb += r
        header = json.loads(hb)
        nbytes = header.get("nbytes", 0)
        out = bytearray()
        crc = 0
        while len(out) < nbytes:
            chunk = conn.recv(min(_SCP_CHUNK, nbytes - len(out)))
            if not chunk:
                raise ConnectionError("closed")
            crc = zlib.crc32(chunk, crc)          # cipher-cost proxy
            out += chunk                           # userspace copy
        header["crc"] = crc
        return header, out

    def _sink(self, header, payload):
        if self.store_dir is not None:            # scp: store at staging
            path = os.path.join(self.store_dir, header["name"])
            t0 = time.perf_counter()
            with open(path, "wb") as f:
                f.write(payload)
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
            if self.disk_bw:  # container disk is NVMe-fast; model the
                # paper's spinning-disk staging storage when asked to
                budget = len(payload) / self.disk_bw
                spent = time.perf_counter() - t0
                if budget > spent:
                    time.sleep(budget - spent)
            header["path"] = path
        elif self.forward_addr:                    # ssh hop: forward copied
            sock = getattr(self._local, "fwd", None)
            if sock is None:
                sock = wire.connect(self.forward_addr)
                self._local.fwd = sock
            h, _ = wire.request(sock, {"op": "fwd", "name": header["name"],
                                       "dtype": header.get("dtype", "uint8")},
                                payload)
            if not h.get("ok"):
                raise RuntimeError(h.get("error"))
        elif self.savime_addr:                     # final hop into SAVIME
            cli = getattr(self._local, "savime", None)
            if cli is None:
                cli = SavimeClient(self.savime_addr)
                self._local.savime = cli
            cli.load_dataset(header["name"], header.get("dtype", "uint8"),
                             payload)


def _copy_send(addr_local: threading.local, addr: str, name: str,
               dtype: str, buf: np.ndarray):
    """Client side of the scp/ssh emulation: chunked sendall with CRC."""
    sock = getattr(addr_local, "sock", None)
    if sock is None:
        sock = wire.connect(addr)
        addr_local.sock = sock
    payload = memoryview(buf.reshape(-1).view(np.uint8))
    import json
    import struct
    hb = json.dumps({"name": name, "dtype": dtype,
                     "nbytes": len(payload)}).encode()
    sock.sendall(struct.pack(">Q", len(hb)) + hb)
    crc = 0
    for off in range(0, len(payload), _SCP_CHUNK):
        chunk = bytes(payload[off:off + _SCP_CHUNK])  # userspace copy
        crc = zlib.crc32(chunk, crc)                  # cipher-cost proxy
        sock.sendall(chunk)
    h, _ = wire.recv_frame(sock)
    if not h.get("ok"):
        raise RuntimeError(h.get("error"))


# ---------------------------------------------------------------------------
# engine drivers
# ---------------------------------------------------------------------------


def run_rdma_staged(buffers: list[np.ndarray], names: list[str], *,
                    savime_addr: str, block_size: int, io_threads: int,
                    mem_capacity: int = 8 << 30,
                    staging: Optional[StagingServer] = None) -> TransferResult:
    own = staging is None
    if own:
        staging = StagingServer(savime_addr, mem_capacity=mem_capacity,
                                send_threads=2).start()
    client = StagingClient(staging.addr, io_threads=io_threads,
                           block_size=block_size)
    try:
        t0 = time.perf_counter()
        for name, buf in zip(names, buffers):
            Dataset(name, str(buf.dtype), client).write(buf)
        client.sync()
        t_staging = time.perf_counter() - t0
        client.drain()
        t_total = time.perf_counter() - t0
    finally:
        client.close()
        if own:
            staging.stop()
    n = sum(b.nbytes for b in buffers)
    return TransferResult("rdma_staged", n, len(buffers), t_staging, t_total)


def run_scp(buffers: list[np.ndarray], names: list[str], *,
            savime_addr: str, storage: str, io_threads: int,
            disk_bw: Optional[float] = None) -> TransferResult:
    """pdsh+scp emulation: copy files to staging storage (mem|disk), then
    staging forwards to SAVIME via the normal (sendfile) API. `disk_bw`
    optionally caps store throughput to the paper's disk hardware class."""
    uid = secrets.token_hex(3)
    store = (f"/dev/shm/scp-{uid}" if storage == "mem" else f"/tmp/scp-{uid}")
    os.makedirs(store, exist_ok=True)
    srv = _CopyServer(store_dir=store, fsync=(storage == "disk"),
                      disk_bw=disk_bw if storage == "disk" else None)
    tls = threading.local()
    pool = FCFSPool(io_threads, "scp")
    fwd_pool = FCFSPool(2, "scp-fwd")
    savime_local = threading.local()

    def forward(name, dtype, path, nbytes):
        cli = getattr(savime_local, "cli", None)
        if cli is None:
            cli = SavimeClient(savime_addr)
            savime_local.cli = cli
        fd = os.open(path, os.O_RDONLY)
        try:
            cli.load_dataset_from_file(name, dtype, fd, nbytes)
        finally:
            os.close(fd)
            os.unlink(path)

    try:
        t0 = time.perf_counter()
        for name, buf in zip(names, buffers):
            pool.submit(_copy_send, tls, srv.addr, name, str(buf.dtype), buf,
                        name=f"scp-{name}")
        pool.sync()
        t_staging = time.perf_counter() - t0
        for name, buf in zip(names, buffers):
            fwd_pool.submit(forward, name, str(buf.dtype),
                            os.path.join(store, name), buf.nbytes,
                            name=f"fwd-{name}")
        fwd_pool.sync()
        t_total = time.perf_counter() - t0
    finally:
        pool.stop()
        fwd_pool.stop()
        srv.stop()
    n = sum(b.nbytes for b in buffers)
    return TransferResult(f"scp_{storage}", n, len(buffers), t_staging, t_total)


def run_ssh_direct(buffers: list[np.ndarray], names: list[str], *,
                   savime_addr: str, io_threads: int) -> TransferResult:
    """SSH-tunnel emulation: compute -> staging hop -> SAVIME, userspace
    copies + CRC at both hops, no staging store (paper §4 last baseline)."""
    hop2 = _CopyServerFwdToSavime(savime_addr)
    hop1 = _CopyServer(store_dir=None, fsync=False, forward_addr=hop2.addr)
    tls = threading.local()
    pool = FCFSPool(io_threads, "ssh")
    try:
        t0 = time.perf_counter()
        for name, buf in zip(names, buffers):
            pool.submit(_copy_send, tls, hop1.addr, name, str(buf.dtype), buf,
                        name=f"ssh-{name}")
        pool.sync()
        t_total = time.perf_counter() - t0
    finally:
        pool.stop()
        hop1.stop()
        hop2.stop()
    n = sum(b.nbytes for b in buffers)
    return TransferResult("ssh_direct", n, len(buffers), t_total, t_total)


class _CopyServerFwdToSavime(_CopyServer):
    """Second tunnel hop: copied recv, then SAVIME ingest."""

    def __init__(self, savime_addr: str):
        super().__init__(store_dir=None, fsync=False,
                         savime_addr=savime_addr)

    def _sink(self, header, payload):
        if header.get("op") == "fwd" or True:
            cli = getattr(self._local, "savime", None)
            if cli is None:
                cli = SavimeClient(self.savime_addr)
                self._local.savime = cli
            cli.load_dataset(header["name"], header.get("dtype", "uint8"),
                             payload)


ENGINES = {
    "rdma_staged": run_rdma_staged,
    "scp_mem": lambda *a, **k: run_scp(*a, storage="mem", **k),
    "scp_disk": lambda *a, **k: run_scp(*a, storage="disk", **k),
    "ssh_direct": run_ssh_direct,
}
