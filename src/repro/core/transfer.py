"""DEPRECATED — transfer engines live in :mod:`repro.transport` now.

The staged-RDMA path and the paper's §4 baselines (scp_mem, scp_disk,
ssh_direct — see DESIGN.md §6 scaling honesty) are registered transports:

    from repro.transport import TransferSession, TransportConfig, create

    cfg = TransportConfig(savime_addr=sv.addr, block_size=16 << 20)
    with TransferSession("scp_disk", cfg) as sess:
        sess.write("D", buf)
        sess.sync(); sess.drain()
    stats = sess.stats          # TransferStats, per-phase timings

This module keeps the old entry points (``run_rdma_staged`` /
``run_scp`` / ``run_ssh_direct`` / ``ENGINES``) working for one release;
every call emits a :class:`DeprecationWarning`.  ``TransferResult`` is an
alias of :class:`repro.transport.TransferStats` (same leading fields).
The emulation internals (``_CopyServer`` et al.) moved to
:mod:`repro.transport.copyemu` and are re-exported for back-compat.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.core.staging import StagingServer
from repro.transport import TransferStats, TransportConfig, run_engine
from repro.transport.copyemu import (  # noqa: F401 — back-compat re-exports
    _SCP_CHUNK, _CopyServer, _CopyServerFwdToSavime, _copy_send,
)

TransferResult = TransferStats   # old name, same leading fields


def _deprecated(old: str, engine: str) -> None:
    warnings.warn(
        f"repro.core.transfer.{old}() is deprecated; use "
        f"repro.transport.TransferSession({engine!r}, cfg) or "
        f"repro.transport.run_engine({engine!r}, ...)",
        DeprecationWarning, stacklevel=3)


def run_rdma_staged(buffers: list[np.ndarray], names: list[str], *,
                    savime_addr: str, block_size: int, io_threads: int,
                    mem_capacity: int = 8 << 30,
                    staging: Optional[StagingServer] = None) -> TransferStats:
    _deprecated("run_rdma_staged", "rdma_staged")
    cfg = TransportConfig(savime_addr=savime_addr,
                          staging_addr=staging.addr if staging else None,
                          block_size=block_size, io_threads=io_threads,
                          mem_capacity=mem_capacity)
    return run_engine("rdma_staged", buffers, names, cfg)


def run_scp(buffers: list[np.ndarray], names: list[str], *,
            savime_addr: str, storage: str, io_threads: int,
            disk_bw: Optional[float] = None) -> TransferStats:
    _deprecated("run_scp", f"scp_{storage}")
    cfg = TransportConfig(savime_addr=savime_addr, io_threads=io_threads,
                          disk_bw=disk_bw)
    return run_engine(f"scp_{storage}", buffers, names, cfg)


def run_ssh_direct(buffers: list[np.ndarray], names: list[str], *,
                   savime_addr: str, io_threads: int) -> TransferStats:
    _deprecated("run_ssh_direct", "ssh_direct")
    cfg = TransportConfig(savime_addr=savime_addr, io_threads=io_threads)
    return run_engine("ssh_direct", buffers, names, cfg)


ENGINES = {
    "rdma_staged": run_rdma_staged,
    "scp_mem": lambda *a, **k: run_scp(*a, storage="mem", **k),
    "scp_disk": lambda *a, **k: run_scp(*a, storage="disk", **k),
    "ssh_direct": run_ssh_direct,
}
