"""Shared retry policy: exponential backoff, full jitter, deadline budgets.

Every reconnect loop in the stack (``Communicator``, ``ChannelGroup``,
``AnalysisSession``, ``GatewayClient``) used to roll its own linear
sleep; they now share this one policy so behaviour under faults is
uniform and testable (DESIGN.md §15).

The backoff follows the "full jitter" scheme: attempt ``k`` sleeps a
uniform random draw from ``[0, min(cap, base * 2**k)]``.  Jitter is what
prevents a fleet of producers that lost the same staging server from
reconnecting in lockstep; the deadline budget is what turns "hangs
forever" into a typed, catchable :class:`RetryExhausted`.

Callers drive the policy through :meth:`RetryPolicy.attempts`::

    for attempt in policy.attempts("staging reconnect"):
        try:
            return do_io()
        except ConnectionError as e:
            attempt.backoff(e)      # sleeps, or raises RetryExhausted

``attempt.backoff`` never sleeps while the caller holds a lock unless
the caller does — the policy itself takes none.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Iterator, Optional


class RetryExhausted(ConnectionError):
    """All retry attempts (or the deadline budget) were consumed.

    ``last`` carries the final underlying error so callers can still
    branch on the root cause after the policy gives up.
    """

    def __init__(self, msg: str, last: Optional[BaseException] = None):
        super().__init__(msg)
        self.last = last


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff + full jitter, bounded by retries and a deadline.

    ``retries``     — max re-attempts after the first try (0 = fail fast).
    ``base_s``      — backoff scale: attempt k waits U(0, base * 2**k).
    ``cap_s``       — ceiling on a single sleep.
    ``deadline_s``  — total budget across all attempts incl. sleeps
                      (None = unbounded by time).
    ``seed``        — optional deterministic jitter (tests / chaos runs).
    """

    retries: int = 3
    base_s: float = 0.05
    cap_s: float = 2.0
    deadline_s: Optional[float] = None
    seed: Optional[int] = None

    def attempts(self, what: str = "operation") -> Iterator["_Attempt"]:
        """Yield one :class:`_Attempt` per try (``retries + 1`` total)."""
        rng = random.Random(self.seed) if self.seed is not None else random
        start = time.monotonic()
        k = 0
        while True:
            yield _Attempt(self, what, k, start, rng)
            k += 1

    def remaining(self, start: float) -> Optional[float]:
        if self.deadline_s is None:
            return None
        return self.deadline_s - (time.monotonic() - start)


class _Attempt:
    """One try under a :class:`RetryPolicy`; ``backoff`` sleeps or raises."""

    __slots__ = ("policy", "what", "index", "start", "_rng")

    def __init__(self, policy: RetryPolicy, what: str, index: int,
                 start: float, rng):
        self.policy = policy
        self.what = what
        self.index = index
        self.start = start
        self._rng = rng

    def backoff(self, err: Optional[BaseException] = None) -> None:
        """Record a failure: sleep before the next attempt, or raise
        :class:`RetryExhausted` when retries / the deadline ran out."""
        p = self.policy
        if self.index >= p.retries:
            raise RetryExhausted(
                f"{self.what}: gave up after {self.index + 1} attempts"
                + (f" ({err})" if err else ""), last=err) from err
        delay = self._rng.uniform(0.0, min(p.cap_s, p.base_s * (2 ** self.index)))
        left = p.remaining(self.start)
        if left is not None:
            if left <= 0:
                raise RetryExhausted(
                    f"{self.what}: deadline {p.deadline_s}s exhausted after "
                    f"{self.index + 1} attempts" + (f" ({err})" if err else ""),
                    last=err) from err
            delay = min(delay, left)
        if delay > 0:
            time.sleep(delay)
