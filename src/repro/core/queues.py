"""FCFS task queues + I/O thread pools with straggler mitigation.

The paper (§3.1): "Received datasets are queued and a pool of threads sends
them in a FCFS fashion. Similarly, the client has a queue of datasets and a
pool of I/O threads sending them to staging."

Beyond the paper (large-scale runnability): speculative re-execution of
stragglers — a watchdog re-enqueues tasks that exceed `straggler_timeout`
(transfer tasks are idempotent: same bytes / same dataset name), first
completion wins; plus bounded retries on failure (fault tolerance for
transient link errors).
"""
from __future__ import annotations

import collections
import threading
import time
import queue as _queue
from typing import Any, Callable, Optional


class TaskHandle:
    _GUARDED_BY = {"_callbacks": "_lock"}

    def __init__(self, fn: Callable, args: tuple, name: str):
        self.fn = fn
        self.args = args
        self.name = name
        self.done = threading.Event()
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.attempts = 0
        self.speculative = 0
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self._lock = threading.Lock()
        self._callbacks: list[Callable[["TaskHandle"], None]] = []

    def complete(self, result=None, error=None) -> bool:
        """First completion wins (duplicate speculative runs are ignored)."""
        with self._lock:
            if self.done.is_set():
                return False
            self.result, self.error = result, error
            self.finished_at = time.perf_counter()
            self.done.set()
            callbacks, self._callbacks = self._callbacks, []
        for cb in callbacks:
            try:
                cb(self)
            except Exception:  # noqa: BLE001 — callbacks must not kill workers
                pass
        return True

    def add_done_callback(self, fn: Callable[["TaskHandle"], None]) -> None:
        """Run ``fn(handle)`` once on completion (immediately if done)."""
        with self._lock:
            if not self.done.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def wait(self, timeout: Optional[float] = None):
        if not self.done.wait(timeout):
            raise TimeoutError(f"task {self.name} not done")
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at and self.started_at:
            return self.finished_at - self.started_at
        return None


class FCFSPool:
    """Fixed pool of worker threads consuming a FIFO queue."""

    # aggregate counters share _pending_lock because _worker updates them
    # in the same critical section that decrements _pending
    _GUARDED_BY = {
        "_inflight": "_inflight_lock",
        "_pending": "_pending_lock",
        "n_completed": "_pending_lock",
        "n_failed": "_pending_lock",
        "_lat_sum": "_pending_lock",
        "_lat_count": "_pending_lock",
    }

    def __init__(self, n_threads: int, name: str = "pool",
                 straggler_timeout: Optional[float] = None,
                 max_retries: int = 2, completed_cap: int = 512):
        self.name = name
        self.straggler_timeout = straggler_timeout
        self.max_retries = max_retries
        self._q: _queue.Queue = _queue.Queue()
        self._inflight: dict[int, TaskHandle] = {}
        self._inflight_lock = threading.Lock()
        self._pending = 0
        self._pending_lock = threading.Condition()
        self._stop = threading.Event()
        self._stop_callbacks: list[Callable[[], None]] = []
        # bounded history: long-running servers complete millions of tasks —
        # keep aggregate latency stats plus a capped ring of recent handles
        # (each handle pins its fn/args, so an unbounded list leaks memory)
        self.completed: collections.deque = collections.deque(
            maxlen=completed_cap)
        self.n_completed = 0
        self.n_failed = 0
        self._lat_sum = 0.0
        self._lat_count = 0
        self._threads = [
            threading.Thread(target=self._worker, name=f"{name}-{i}",
                             daemon=True)
            for i in range(n_threads)
        ]
        for t in self._threads:
            t.start()
        self._watchdog = None
        if straggler_timeout:
            self._watchdog = threading.Thread(
                target=self._watch, name=f"{name}-watchdog", daemon=True)
            self._watchdog.start()

    # -- submission ---------------------------------------------------------
    def submit(self, fn: Callable, *args, name: str = "task") -> TaskHandle:
        h = TaskHandle(fn, args, name)
        with self._pending_lock:
            self._pending += 1
        self._q.put(h)
        return h

    def pending(self) -> int:
        """Tasks submitted but not yet completed (queued + in flight)."""
        with self._pending_lock:
            return self._pending

    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted task completed (paper's st.sync())."""
        deadline = time.monotonic() + timeout if timeout else None
        with self._pending_lock:
            while self._pending > 0:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(f"{self.name}.sync timed out")
                self._pending_lock.wait(remaining)

    def add_stop_callback(self, fn: Callable[[], None]) -> None:
        """Resource cleanup to run when the pool stops (e.g. closing the
        thread-local sockets its workers opened)."""
        self._stop_callbacks.append(fn)

    def stop(self, timeout: Optional[float] = 30.0) -> None:
        """Stop accepting work, let in-flight tasks finish, then run the
        cleanup callbacks.  Joining before cleanup matters: callbacks close
        the workers' thread-local sockets, which must not happen while a
        worker is mid-transfer (a task that was going to succeed would
        fail).  ``timeout`` bounds the total join wait (socket timeouts
        bound each task anyway); queued-but-unstarted tasks are abandoned,
        as before."""
        self._stop.set()
        for _ in self._threads:
            self._q.put(None)
        deadline = time.monotonic() + timeout if timeout else None
        for t in self._threads:
            if t is threading.current_thread() or not t.is_alive():
                continue
            remaining = None if deadline is None else \
                max(deadline - time.monotonic(), 0.0)
            t.join(remaining)
        if self._watchdog is not None \
                and self._watchdog is not threading.current_thread():
            # _stop is set, so the watchdog's wait() returns within
            # straggler_timeout/4 — bound the join the same way anyway
            remaining = None if deadline is None else \
                max(deadline - time.monotonic(), 0.0)
            self._watchdog.join(remaining)
        for fn in self._stop_callbacks:
            try:
                fn()
            except Exception:  # noqa: BLE001 — best-effort cleanup
                pass

    # -- internals -----------------------------------------------------------
    def _worker(self) -> None:
        while not self._stop.is_set():
            h = self._q.get()
            if h is None:
                return
            if h.done.is_set():             # speculative duplicate already won
                self._q.task_done()
                continue
            h.started_at = h.started_at or time.perf_counter()
            h.attempts += 1
            tid = id(h)
            with self._inflight_lock:
                self._inflight[tid] = h
            try:
                res = h.fn(*h.args)
                first = h.complete(result=res)
            except BaseException as e:  # noqa: BLE001 — retried below
                # no retry once stop() was called: the re-enqueued task
                # would sit behind the shutdown sentinels forever, leaving
                # _pending stuck and hanging every later sync()
                if h.attempts <= self.max_retries and not h.done.is_set() \
                        and not self._stop.is_set():
                    self._q.put(h)          # bounded retry
                    first = False
                else:
                    first = h.complete(error=e)
            finally:
                with self._inflight_lock:
                    self._inflight.pop(tid, None)
                self._q.task_done()
            if first:
                self.completed.append(h)
                with self._pending_lock:
                    self.n_completed += 1
                    if h.error is not None:
                        self.n_failed += 1
                    lat = h.latency
                    if lat is not None:
                        self._lat_sum += lat
                        self._lat_count += 1
                    self._pending -= 1
                    self._pending_lock.notify_all()

    def _watch(self) -> None:
        assert self.straggler_timeout
        while not self._stop.wait(self.straggler_timeout / 4):
            now = time.perf_counter()
            with self._inflight_lock:
                slow = [h for h in self._inflight.values()
                        if h.started_at and not h.done.is_set()
                        and now - h.started_at > self.straggler_timeout
                        and h.speculative == 0]
            for h in slow:                  # speculative re-execution
                h.speculative += 1
                self._q.put(h)

    # -- stats ----------------------------------------------------------------
    def latencies(self) -> list[float]:
        """Latencies of the most recent completions (capped ring)."""
        return [h.latency for h in list(self.completed)
                if h.latency is not None]

    def latency_stats(self) -> dict:
        """Aggregate latency counters over *all* completions (unbounded
        count, bounded memory — the ring only keeps recent handles)."""
        with self._pending_lock:
            return {"count": self._lat_count,
                    "total_s": self._lat_sum,
                    "mean_s": self._lat_sum / self._lat_count
                    if self._lat_count else 0.0,
                    "failed": self.n_failed}
