# The paper's primary contribution: asynchronous in-transit staging from
# compute jobs to an in-memory analytical array DBMS (SAVIME/TARS), with
# RDMA-emulated one-sided block writes, tmpfs staging + disk fallback,
# FCFS send pools, and sendfile/splice forwarding. See DESIGN.md.
from repro.core.blocks import TransferCostModel, plan_blocks, vmem_tile  # noqa: F401
from repro.core.client import Dataset, StagingClient  # noqa: F401
from repro.core.intransit import InTransitConfig, InTransitSink  # noqa: F401
from repro.core.savime import SavimeClient, SavimeEngine, SavimeServer  # noqa: F401
from repro.core.staging import StagingServer  # noqa: F401
from repro.core.tars import TAR, Attribute, Dimension  # noqa: F401
