"""TARS — Typed ARray Schema (Lustosa et al. 2017), the SAVIME data model.

A TAR (Typed ARray) is a named multidimensional array with:
  * dimensions — name + [lower, upper] index range, plus an affine *mapping
    function* (offset + stride·i) supporting non-integer coordinates;
  * attributes — named, typed value fields over the same index space;
  * subtars    — rectangular regions holding the actual payload (dense
    numpy arrays per attribute). Data arrives one subtar at a time
    (the paper's ``load_subtar``), so ingestion is append-only and cheap.

Queries (dimension/range filter, attribute predicate, aggregation) execute
against the set of subtars intersecting the query box. Concurrent readers
are supported (RLock; writers only append subtars).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dimension:
    name: str
    lower: int
    upper: int                      # inclusive
    offset: float = 0.0             # mapping function: coord = offset + i*stride
    stride: float = 1.0

    @property
    def length(self) -> int:
        return self.upper - self.lower + 1

    def to_coord(self, i: np.ndarray | int):
        return self.offset + np.asarray(i, np.float64) * self.stride

    def to_index(self, coord: float) -> int:
        return int(round((coord - self.offset) / self.stride))


@dataclasses.dataclass(frozen=True)
class Attribute:
    name: str
    dtype: str                      # numpy dtype string

    @property
    def np_dtype(self):
        return np.dtype(self.dtype)


@dataclasses.dataclass
class SubTar:
    """Rectangular region [origin, origin+shape) with dense payloads."""
    origin: tuple[int, ...]
    shape: tuple[int, ...]
    data: dict[str, np.ndarray]     # attribute name -> array of `shape`

    def box(self) -> tuple[tuple[int, int], ...]:
        return tuple((o, o + s - 1) for o, s in zip(self.origin, self.shape))

    def intersect(self, lo: tuple[int, ...], hi: tuple[int, ...]):
        """Intersection with query box [lo, hi] (inclusive); None if empty."""
        slo = tuple(max(o, l) for o, l in zip(self.origin, lo))
        shi = tuple(min(o + s - 1, h) for o, s, h in zip(self.origin, self.shape, hi))
        if any(a > b for a, b in zip(slo, shi)):
            return None
        sl = tuple(slice(a - o, b - o + 1)
                   for a, b, o in zip(slo, shi, self.origin))
        return slo, shi, sl


class TAR:
    def __init__(self, name: str, dims: list[Dimension], attrs: list[Attribute]):
        self.name = name
        self.dims = dims
        self.attrs = {a.name: a for a in attrs}
        self.subtars: list[SubTar] = []
        self._lock = threading.RLock()

    # -- ingestion ---------------------------------------------------------
    def load_subtar(self, origin: tuple[int, ...], shape: tuple[int, ...],
                    data: dict[str, np.ndarray]) -> None:
        assert len(origin) == len(self.dims) == len(shape)
        for aname, arr in data.items():
            attr = self.attrs[aname]
            arr = np.asarray(arr, attr.np_dtype).reshape(shape)
            data[aname] = arr
        for d, o, s in zip(self.dims, origin, shape):
            if o < d.lower or o + s - 1 > d.upper:
                raise ValueError(
                    f"subtar box {origin}+{shape} outside dim {d.name} "
                    f"[{d.lower},{d.upper}]")
        with self._lock:
            self.subtars.append(SubTar(tuple(origin), tuple(shape), data))

    # -- queries -----------------------------------------------------------
    def data_box(self) -> Optional[tuple[tuple[int, ...], tuple[int, ...]]]:
        """Bounding box of loaded subtars ((lo...), (hi...)), or None."""
        with self._lock:
            if not self.subtars:
                return None
            boxes = [st.box() for st in self.subtars]
        lo = tuple(min(b[i][0] for b in boxes) for i in range(len(self.dims)))
        hi = tuple(max(b[i][1] for b in boxes) for i in range(len(self.dims)))
        return lo, hi

    def select(self, attr: str, lo: Optional[tuple[int, ...]] = None,
               hi: Optional[tuple[int, ...]] = None) -> np.ndarray:
        """Materialize attribute over query box (missing cells = 0).
        Unbounded queries clip to the loaded-data bounding box (declared
        dims may be huge, e.g. an unbounded `step` dimension)."""
        box = self.data_box()
        if box is None:
            return np.zeros((0,) * len(self.dims), self.attrs[attr].np_dtype)
        lo = box[0] if lo is None else tuple(lo)
        hi = box[1] if hi is None else tuple(hi)
        shape = tuple(h - l + 1 for l, h in zip(lo, hi))
        out = np.zeros(shape, self.attrs[attr].np_dtype)
        with self._lock:
            subtars = list(self.subtars)
        for st in subtars:
            isect = st.intersect(lo, hi)
            if isect is None or attr not in st.data:
                continue
            slo, shi, sl = isect
            dst = tuple(slice(a - l, b - l + 1) for a, b, l in zip(slo, shi, lo))
            out[dst] = st.data[attr][sl]
        return out

    def aggregate(self, attr: str, op: str,
                  lo: Optional[tuple[int, ...]] = None,
                  hi: Optional[tuple[int, ...]] = None) -> float:
        ops: dict[str, Callable] = {
            "sum": np.sum, "mean": np.mean, "max": np.max, "min": np.min,
            "std": np.std, "count": np.size,
        }
        return float(ops[op](self.select(attr, lo, hi)))

    def filter(self, attr: str, pred: Callable[[np.ndarray], np.ndarray],
               lo=None, hi=None) -> np.ndarray:
        """Returns (n_hits, ndim+1) array: index coords + value per hit."""
        box = self.select(attr, lo, hi)
        lo = tuple(d.lower for d in self.dims) if lo is None else tuple(lo)
        idx = np.argwhere(pred(box))
        vals = box[tuple(idx.T)]
        return np.concatenate([idx + np.asarray(lo), vals[:, None]], axis=1)

    def cells(self) -> int:
        with self._lock:
            return int(sum(np.prod(st.shape) for st in self.subtars))

    def nbytes(self) -> int:
        with self._lock:
            return int(sum(a.nbytes for st in self.subtars
                           for a in st.data.values()))
