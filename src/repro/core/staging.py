"""Staging server — the paper's §3 architecture, component 2 of 2.

Receives datasets from compute-node clients via emulated-RDMA one-sided
writes into mmap'd in-memory files (tmpfs, capacity-limited, disk
fallback), then forwards them to SAVIME in the background over TCP with
sendfile/splice, FCFS, from a pool of send threads. In-memory files are
unlinked after ingest to release memory (paper §3.2). Also proxies SAVIME
control commands for clients that cannot reach the analytical network.

Striped ingest (DESIGN.md §9): ``stripe_open`` allocates the region and
declares ``n_stripes``; each ``stripe`` frame carries ``(name,
stripe_idx, n_stripes, offset)`` and its payload is received *directly
into the mmap'd region at its offset* — stripes reassemble out of order,
from any number of concurrent channel connections, with one copy (same
per-byte cost as the one-sided RDMA path). Every stripe ack returns a
credit grant computed from current memory pressure: when the SAVIME hop
is slow and tmpfs fills, grants shrink toward 1 and senders stall
instead of ballooning staging memory.
"""
from __future__ import annotations

import math
import os
import secrets
import socket
import threading
import time
from typing import Optional

from repro.core import wire
from repro.core.queues import FCFSPool
from repro.core.rdma import MemoryRegion
from repro.core.savime import SavimeClient


class _Dataset:
    def __init__(self, file_id: str, name: str, dtype: str, nbytes: int,
                 region: MemoryRegion, in_memory: bool):
        self.file_id = file_id
        self.name = name
        self.dtype = dtype
        self.nbytes = nbytes
        self.region = region
        self.in_memory = in_memory
        self.received_at: Optional[float] = None
        # striped-ingest bookkeeping (None for the RDMA block path)
        self.n_stripes: Optional[int] = None
        self.stripes_seen: set[int] = set()
        self.credits_wanted: int = 4
        self.finished = False
        self.last_stripe_at: float = 0.0


class StagingServer:
    def __init__(self, savime_addr: str, host: str = "127.0.0.1",
                 port: int = 0, mem_capacity: int = 1 << 30,
                 mem_dir: Optional[str] = None,
                 disk_dir: Optional[str] = None,
                 send_threads: int = 2,
                 straggler_timeout: Optional[float] = None,
                 auto_subtar: bool = True,
                 stripe_ttl: float = 300.0):
        self.savime_addr = savime_addr
        uid = f"{os.getpid()}-{secrets.token_hex(3)}"
        self.mem_dir = mem_dir or f"/dev/shm/staging-{uid}"
        self.disk_dir = disk_dir or f"/tmp/staging-{uid}"
        os.makedirs(self.mem_dir, exist_ok=True)
        os.makedirs(self.disk_dir, exist_ok=True)
        self.mem_capacity = mem_capacity
        self._mem_used = 0
        self._alloc_lock = threading.Lock()
        # _datasets is written by connection threads and popped by send
        # threads — every mutation goes through _ds_lock
        self._ds_lock = threading.Lock()
        self._datasets: dict[str, _Dataset] = {}
        self._send_pool = FCFSPool(send_threads, "staging-send",
                                   straggler_timeout=straggler_timeout)
        self._savime_local = threading.local()
        self.auto_subtar = auto_subtar
        self.stripe_ttl = stripe_ttl
        self.stats = {"datasets": 0, "bytes_in": 0, "bytes_to_savime": 0,
                      "disk_fallbacks": 0, "registrations": 0,
                      "stripes": 0, "stripe_dups": 0, "stripe_aborts": 0}

        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(128)
        self.addr = f"{host}:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._conns: set[socket.socket] = set()
        self._conn_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def start(self) -> "StagingServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="staging-accept", daemon=True)
        self._accept_thread.start()
        return self

    def stop(self, join_timeout: float = 2.0) -> None:
        self._stop.set()
        self._send_pool.stop()
        try:
            # shutdown (not just close) wakes a thread blocked in accept()
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._srv.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(join_timeout)
        deadline = time.monotonic() + join_timeout
        for t in self._threads:
            t.join(max(deadline - time.monotonic(), 0.0))
        self._threads = [t for t in self._threads if t.is_alive()]
        with self._ds_lock:
            datasets = list(self._datasets.values())
        for ds in datasets:
            ds.region.close(unlink=True)

    def live_threads(self) -> int:
        return sum(t.is_alive() for t in self._threads)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until the send queue is empty (staging→SAVIME finished)."""
        self._send_pool.sync(timeout)

    # ------------------------------------------------------------------
    def _savime(self) -> SavimeClient:
        cli = getattr(self._savime_local, "cli", None)
        if cli is None:  # one connection per send/serve thread
            cli = SavimeClient(self.savime_addr)
            self._savime_local.cli = cli
        return cli

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            self._threads = [t for t in self._threads if t.is_alive()]
            t = threading.Thread(target=self._serve, args=(conn,),
                                 name="staging-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _serve(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._conn_lock:
            self._conns.add(conn)
        try:
            with conn:
                while True:
                    try:
                        header = wire.recv_header(conn)
                        if header.get("op") == "stripe":
                            # the stripe handler receives its own payload —
                            # straight into the mmap'd region at its offset
                            try:
                                reply = self._op_stripe(conn, header)
                            except (ConnectionError, OSError):
                                raise
                            except Exception as e:  # noqa: BLE001
                                # post-validation failure (e.g. region
                                # closed by stop() mid-stripe): report it,
                                # then drop the conn — the payload may not
                                # be fully consumed, so framing is gone
                                try:
                                    wire.send_frame(
                                        conn,
                                        {"ok": False, "error": str(e)})
                                except OSError:
                                    pass
                                return
                        else:
                            payload = wire.recv_payload(conn, header)
                            try:
                                reply = self._handle(header, payload)
                            except Exception as e:  # noqa: BLE001
                                reply = {"ok": False, "error": str(e)}
                    except (ConnectionError, OSError):
                        return
                    try:
                        wire.send_frame(conn, reply)
                    except OSError:
                        return
        finally:
            with self._conn_lock:
                self._conns.discard(conn)

    # ------------------------------------------------------------------
    def _handle(self, h: dict, payload) -> dict:
        op = h.get("op")
        if op == "ping":
            return {"ok": True}
        if op == "write_req":
            return self._op_write_req(h)
        if op == "reg_block":
            return self._op_reg_block(h)
        if op == "client_sync":
            return self._op_client_sync(h)
        if op == "stripe_open":
            return self._op_stripe_open(h)
        if op == "run_savime":
            res = self._savime().run(h["q"])
            if hasattr(res, "tolist"):
                res = res.tolist()
            return {"ok": True, "result": res}
        if op == "drain":
            self.drain(h.get("timeout"))
            return {"ok": True}
        if op == "stats":
            return {"ok": True, **self.stats,
                    "mem_used": self._mem_used,
                    "queued": len(self._datasets)}
        raise ValueError(f"unknown op {op!r}")

    def _op_write_req(self, h: dict) -> dict:
        nbytes = int(h["size"])
        with self._alloc_lock:
            in_memory = self._mem_used + nbytes <= self.mem_capacity
            if in_memory:
                self._mem_used += nbytes
            else:
                self.stats["disk_fallbacks"] += 1  # paper: disk as fallback
        file_id = secrets.token_hex(8)
        base = self.mem_dir if in_memory else self.disk_dir
        path = os.path.join(base, file_id)
        try:
            region = MemoryRegion(path, nbytes, create=True)
        except BaseException:
            # mmap/ftruncate can fail after the capacity reservation was
            # taken; without the rollback the bytes leak until restart
            if in_memory:
                with self._alloc_lock:
                    self._mem_used -= nbytes
            raise
        ds = _Dataset(file_id, h["name"], h.get("dtype", "uint8"), nbytes,
                      region, in_memory)
        with self._ds_lock:
            self._datasets[file_id] = ds
        return {"ok": True, "file_id": file_id, "path": path,
                "in_memory": in_memory}

    def _op_reg_block(self, h: dict) -> dict:
        with self._ds_lock:
            ds = self._datasets[h["file_id"]]
        grant = ds.region.register_block(int(h["offset"]), int(h["size"]))
        self.stats["registrations"] += 1
        return {"ok": True, **grant}

    def _op_client_sync(self, h: dict) -> dict:
        with self._ds_lock:
            ds = self._datasets[h["file_id"]]
        self._finish_dataset(ds)
        return {"ok": True}

    def _finish_dataset(self, ds: _Dataset) -> None:
        """Dataset fully received (block-path sync or last stripe): account
        it and queue the staging→SAVIME forward."""
        ds.received_at = time.perf_counter()
        ds.region.deregister_all()   # paper: undo registration after sync
        self.stats["datasets"] += 1
        self.stats["bytes_in"] += ds.nbytes
        self._send_pool.submit(self._send_to_savime, ds,
                               name=f"send-{ds.name}")

    # -- striped ingest (DESIGN.md §9) -----------------------------------
    def _op_stripe_open(self, h: dict) -> dict:
        self._gc_stale_stripes()
        rep = self._op_write_req(h)
        n_stripes = int(h["n_stripes"])
        with self._ds_lock:
            ds = self._datasets[rep["file_id"]]
            ds.n_stripes = n_stripes
            ds.credits_wanted = max(1, int(h.get("credits", 4)))
            ds.last_stripe_at = time.monotonic()
        if n_stripes == 0:           # empty dataset: complete at open
            with self._ds_lock:
                ds.finished = True
            self._finish_dataset(ds)
        rep["credits"] = self._credit_grant(ds.credits_wanted)
        return rep

    def _op_stripe(self, conn: socket.socket, h: dict) -> dict:
        """Receive one stripe payload directly into the dataset's region.

        Any validation failure must still drain the payload bytes before
        replying, or the connection's framing desynchronizes.
        """
        nbytes = int(h.get("nbytes") or 0)
        try:
            with self._ds_lock:
                ds = self._datasets[h["file_id"]]
                dup = int(h["stripe_idx"]) in ds.stripes_seen
            idx = int(h["stripe_idx"])
            off = int(h["offset"])
            # one-sided stripes (sided=1) landed via a direct memory write;
            # the frame is control-only and declares its extent in "size"
            if h.get("sided"):
                if nbytes:
                    raise ValueError("sided stripe must not carry payload")
                span = int(h.get("size") or 0)
            else:
                span = nbytes
            if ds.n_stripes is None:
                raise ValueError("dataset was not opened with stripe_open")
            if off < 0 or off + span > ds.nbytes:
                raise ValueError(
                    f"stripe [{off},{off + span}) outside dataset "
                    f"[0,{ds.nbytes})")
        except (KeyError, ValueError, TypeError) as e:
            wire.drain_payload(conn, h)       # keep the stream framed
            return {"ok": False, "error": str(e)}
        grant = self._credit_grant(ds.credits_wanted)
        if dup:
            # duplicate (retry / speculative re-send): ack idempotently,
            # do not touch the region — it may already be forwarding
            wire.drain_payload(conn, h)
            self.stats["stripe_dups"] += 1
            return {"ok": True, "stripe_idx": idx, "dup": True,
                    "done": False, "credits": grant}
        if nbytes:
            wire.recv_into(conn, ds.region.view()[off:off + nbytes])
        if span:
            # on-demand registration per stripe (paper: "the server
            # register each block as needed") — credit-granted rather than
            # request/reply, so it pipelines with the writes instead of
            # costing a serialized RTT + cold zero-fill pass per block
            ds.region.register_block(off, span)
            self.stats["registrations"] += 1
        done = False
        with self._ds_lock:
            ds.stripes_seen.add(idx)
            ds.last_stripe_at = time.monotonic()
            if len(ds.stripes_seen) >= ds.n_stripes and not ds.finished:
                ds.finished = done = True
        self.stats["stripes"] += 1
        if done:
            self._finish_dataset(ds)
        return {"ok": True, "stripe_idx": idx, "dup": False, "done": done,
                "credits": grant}

    def _gc_stale_stripes(self) -> None:
        """Reap striped datasets abandoned mid-transfer (client or channel
        died): without this their capacity reservation never releases, and
        since credit grants derive from ``_mem_used`` a few dead transfers
        would permanently throttle every healthy client. Activity-based:
        a credit-stalled sender still trickles stripes (grants are never
        0), so only truly dead transfers age past the TTL."""
        now = time.monotonic()
        with self._ds_lock:
            stale = [ds for ds in self._datasets.values()
                     if ds.n_stripes is not None and not ds.finished
                     and now - ds.last_stripe_at > self.stripe_ttl]
            for ds in stale:
                self._datasets.pop(ds.file_id, None)
        for ds in stale:
            ds.region.close(unlink=True)
            if ds.in_memory:
                with self._alloc_lock:
                    self._mem_used -= ds.nbytes
            self.stats["stripe_aborts"] += 1

    def _credit_grant(self, wanted: int) -> int:
        """Per-channel window grant: full when tmpfs is empty, shrinking
        toward 1 as it fills (a slow SAVIME hop keeps memory occupied, so
        producers stall on credits instead of overrunning the staging
        area). Never 0 — a zero grant with an empty pipeline would leave
        no ack to ever raise it again."""
        with self._alloc_lock:
            used = self._mem_used
        frac_free = 1.0 - used / self.mem_capacity if self.mem_capacity \
            else 1.0
        return max(1, min(wanted, math.ceil(wanted * max(frac_free, 0.0))))

    # -- background forward (FCFS pool) ---------------------------------
    def _send_to_savime(self, ds: _Dataset) -> None:
        try:
            cli = self._savime()
            cli.load_dataset_from_file(ds.name, ds.dtype, ds.region.fd,
                                       ds.nbytes)
        except OSError:
            if self._stop.is_set():
                return    # stop() already closed the regions mid-forward
            raise
        self.stats["bytes_to_savime"] += ds.nbytes
        ds.region.close(unlink=True)  # release tmpfs memory (paper §3.2)
        with self._ds_lock:
            self._datasets.pop(ds.file_id, None)
        if ds.in_memory:
            with self._alloc_lock:
                self._mem_used -= ds.nbytes
