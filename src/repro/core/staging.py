"""Staging server — the paper's §3 architecture, component 2 of 2.

Receives datasets from compute-node clients via emulated-RDMA one-sided
writes into mmap'd in-memory files (tmpfs, capacity-limited, disk
fallback), then forwards them to SAVIME in the background over TCP with
sendfile/splice, FCFS, from a pool of send threads. In-memory files are
unlinked after ingest to release memory (paper §3.2). Also proxies SAVIME
control commands for clients that cannot reach the analytical network.

Striped ingest (DESIGN.md §9): ``stripe_open`` allocates the region and
declares ``n_stripes``; each ``stripe`` frame carries ``(name,
stripe_idx, n_stripes, offset)`` and its payload is received *directly
into the mmap'd region at its offset* — stripes reassemble out of order,
from any number of concurrent channel connections, with one copy (same
per-byte cost as the one-sided RDMA path). Every stripe ack returns a
credit grant computed from current memory pressure: when the SAVIME hop
is slow and tmpfs fills, grants shrink toward 1 and senders stall
instead of ballooning staging memory.

Small-dataset fast path (DESIGN.md §10): ``hello`` negotiates the bin1
wire format per connection (stripe / reg_block frames then arrive
struct-packed and are acked in kind); ``batch_open`` reserves regions
for N datasets in one round-trip (rolled back as a unit if any
reservation fails) and the following ``batch_write`` lands the
concatenated payloads straight into those regions and feeds each
sub-dataset into the existing finish/forward pipeline — SAVIME ingest is
unchanged. Connections that speak bin1 also receive proactive ``credit``
frames when a forward to SAVIME releases staging memory, so stalled
windows recover without waiting for the next ack.
"""
from __future__ import annotations

import collections
import logging
import math
import os
import secrets
import socket
import threading
import time
import zlib
from typing import Optional

from repro import codec as codec_mod
from repro.core import wire
from repro.core.pagestore import PageStore, PageStoreFull
from repro.core.queues import FCFSPool, TaskHandle
from repro.core.rdma import MemoryRegion, PagedMemoryRegion
from repro.core.savime import SavimeClient

log = logging.getLogger(__name__)

# bounded (name, epoch) replay-dedup log: large enough to cover every
# epoch a producer could still replay (its journal is far smaller), small
# enough to never matter for memory. A miss only means a re-ingest, which
# SAVIME's last-write-wins load absorbs.
_ACKED_CAP = 4096


class _Dataset:
    def __init__(self, file_id: str, name: str, dtype: str, nbytes: int,
                 region: MemoryRegion, in_memory: bool):
        self.file_id = file_id
        self.name = name
        self.dtype = dtype
        self.nbytes = nbytes
        self.region = region
        self.in_memory = in_memory
        self.received_at: Optional[float] = None
        # striped-ingest bookkeeping (None for the RDMA block path)
        self.n_stripes: Optional[int] = None
        self.stripes_seen: set[int] = set()
        self.credits_wanted: int = 4
        self.finished = False
        # activity clock for the abandoned-reservation reaper: starts at
        # creation so an idle block-path reservation ages out too (0.0
        # would make every fresh dataset instantly stale)
        self.last_stripe_at: float = time.monotonic()
        # producer-assigned replay identity (None for epoch-less writes)
        self.epoch: Optional[str] = None
        # egress-codec state (DESIGN.md §13): nbytes is always the *wire*
        # size of the region; raw_size the decoded size it stands for
        self.codec: Optional[str] = None
        self.cmeta: dict = {}
        self.raw_size: int = 0
        self.decode_at: str = "staging"
        self.decoded = False


class StagingServer:
    # lock->attribute protection map, enforced by `python -m repro.lint`
    # (DESIGN.md §14).  The plain-counter `stats` dict is deliberately
    # unguarded: increments are best-effort telemetry and the `stats` op
    # snapshots the authoritative watermarks under their own locks.
    _GUARDED_BY = {
        "_mem_used": "_alloc_lock",
        "_disk_used": "_alloc_lock",
        "_datasets": "_ds_lock",
        "_acked": "_ds_lock",
        "_threads": "_threads_lock",
        "_conns": "_conn_lock",
        "_push_conns": "_conn_lock",
        "_decoders": "_codec_mutex",
        "_parked": "_codec_mutex",
        "_fwd_tails": "_codec_mutex",
    }

    def __init__(self, savime_addr: str, host: str = "127.0.0.1",
                 port: int = 0, mem_capacity: int = 1 << 30,
                 mem_dir: Optional[str] = None,
                 disk_dir: Optional[str] = None,
                 send_threads: int = 2,
                 straggler_timeout: Optional[float] = None,
                 auto_subtar: bool = True,
                 stripe_ttl: float = 300.0,
                 page_bytes: int = 0,
                 spill_dir: Optional[str] = None,
                 dedup: bool = False):
        self.savime_addr = savime_addr
        uid = f"{os.getpid()}-{secrets.token_hex(3)}"
        self.mem_dir = mem_dir or f"/dev/shm/staging-{uid}"
        self.disk_dir = disk_dir or f"/tmp/staging-{uid}"
        os.makedirs(self.mem_dir, exist_ok=True)
        os.makedirs(self.disk_dir, exist_ok=True)
        self.mem_capacity = mem_capacity
        self._mem_used = 0
        self._disk_used = 0
        self._alloc_lock = threading.Lock()
        # paged staging substrate (DESIGN.md §11): page_bytes > 0 replaces
        # flat per-dataset tmpfs regions with page tables over one arena
        # (LRU spill tier + optional content-addressed dedup); 0 keeps the
        # flat path byte-identical to the original
        self._store: Optional[PageStore] = None
        if page_bytes > 0:
            self._store = PageStore(
                capacity=mem_capacity, page_bytes=page_bytes,
                mem_dir=self.mem_dir,
                spill_dir=spill_dir or os.path.join(self.disk_dir, "spill"),
                dedup=dedup)
        # _datasets is written by connection threads and popped by send
        # threads — every mutation goes through _ds_lock
        self._ds_lock = threading.Lock()
        self._datasets: dict[str, _Dataset] = {}
        # (name, epoch) -> True for completed epoched ingests (bounded
        # FIFO): replayed writes whose ack was lost dedup against this
        self._acked: collections.OrderedDict = collections.OrderedDict()
        self._send_pool = FCFSPool(send_threads, "staging-send",
                                   straggler_timeout=straggler_timeout)
        self._savime_local = threading.local()
        self.auto_subtar = auto_subtar
        self.stripe_ttl = stripe_ttl
        self.stats = {"datasets": 0, "bytes_in": 0, "raw_bytes_in": 0,
                      "bytes_to_savime": 0,
                      "disk_fallbacks": 0, "registrations": 0,
                      "stripes": 0, "stripe_dups": 0, "stripe_aborts": 0,
                      "batches": 0, "batched_datasets": 0,
                      "codec_datasets": 0, "codec_parked": 0,
                      "bin_conns": 0, "credit_pushes": 0, "conns": 0,
                      "replay_dups": 0, "crc_errors": 0}
        # egress-codec decode state (DESIGN.md §13): one decoder instance
        # per codec name (chained codecs keep per-dataset-name history),
        # serialized by _codec_mutex; a chained dataset that arrives before
        # its predecessor parks keyed (name, base_seq) until the base lands
        self._decoders: dict[str, codec_mod.Codec] = {}
        self._codec_mutex = threading.Lock()
        self._parked: dict[tuple[str, int], _Dataset] = {}
        # chained datasets share a SAVIME name across links, so their
        # forwards must reach SAVIME in decode order even across the
        # send pool's threads: each queued forward for a name waits on
        # the previous one's handle (FIFO dequeue makes that safe)
        self._fwd_tails: dict[str, TaskHandle] = {}
        # bin1 data connections eligible for proactive credit pushes:
        # conn -> the send lock shared with its serve thread
        self._push_conns: dict[socket.socket, threading.Lock] = {}

        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(128)
        self.addr = f"{host}:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        # _threads is appended by the accept loop and walked by stop();
        # both sides hold _threads_lock (an unlocked prune-while-join
        # race used to drop serve threads from stop()'s view)
        self._threads: list[threading.Thread] = []
        self._threads_lock = threading.Lock()
        self._conns: set[socket.socket] = set()
        self._conn_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def start(self) -> "StagingServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="staging-accept", daemon=True)
        self._accept_thread.start()
        return self

    def stop(self, join_timeout: float = 2.0) -> None:
        self._stop.set()
        self._send_pool.stop()
        try:
            # shutdown (not just close) wakes a thread blocked in accept()
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._srv.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(join_timeout)
        deadline = time.monotonic() + join_timeout
        with self._threads_lock:
            threads = list(self._threads)
        for t in threads:
            t.join(max(deadline - time.monotonic(), 0.0))
        with self._threads_lock:
            self._threads = [t for t in self._threads if t.is_alive()]
        with self._ds_lock:
            datasets = list(self._datasets.values())
        for ds in datasets:
            ds.region.close(unlink=True)
        if self._store is not None:
            self._store.close()
            self._try_rmdir(self._store.spill_dir)
        self._try_rmdir(self.mem_dir)
        self._try_rmdir(self.disk_dir)

    @staticmethod
    def _try_rmdir(path: str) -> None:
        """Reap a directory this server created, but only when empty —
        live datasets (or a user-supplied shared dir) keep it."""
        try:
            os.rmdir(path)
        except OSError:
            pass

    def live_threads(self) -> int:
        with self._threads_lock:
            return sum(t.is_alive() for t in self._threads)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until the send queue is empty (staging→SAVIME finished)."""
        self._send_pool.sync(timeout)

    # ------------------------------------------------------------------
    def _savime(self) -> SavimeClient:
        cli = getattr(self._savime_local, "cli", None)
        if cli is None:  # one connection per send/serve thread
            cli = SavimeClient(self.savime_addr)
            self._savime_local.cli = cli
        return cli

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            if self._stop.is_set():
                # raced stop(): it already shut the conns it could see —
                # serving this one would leave a thread stop() never joins
                try:
                    conn.close()
                except OSError:
                    pass
                return
            with self._threads_lock:
                self._threads = [t for t in self._threads if t.is_alive()]
                t = threading.Thread(target=self._serve, args=(conn,),
                                     name="staging-conn", daemon=True)
                t.start()
                self._threads.append(t)

    def _serve(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._conn_lock:
            self._conns.add(conn)
        # replies and proactive credit pushes may interleave on this
        # socket from different threads — all sends go through this lock
        send_lock = threading.Lock()
        # conn-local protocol state: the reservation ids of the last
        # successful batch_open, consumed by the next batch_write
        conn_state: dict = {}
        # payloads for the generic ops are consumed before the next frame
        # is read, so their receive buffers are pooled, not per-frame
        pool = wire.BufferPool(max_per_bucket=2)

        def _reply(reply: dict, is_bin: bool) -> bool:
            try:
                with send_lock:
                    if is_bin:
                        wire.send_frame_bin(conn, dict(reply, op="ack"))
                    else:
                        wire.send_frame(conn, reply)
            except OSError:
                return False
            return True

        counted = False   # probe-only conns (ping/stats) stay uncounted
        try:
            with conn:
                while True:
                    try:
                        header = wire.recv_header(conn)
                        is_bin = bool(header.pop("_bin", False))
                        op = header.get("op")
                        if not counted and op not in ("ping", "stats"):
                            # a health prober that only ever pings must not
                            # inflate the data-connection total
                            self.stats["conns"] += 1
                            counted = True
                        if op in ("stripe", "batch_write"):
                            # these handlers receive their own payload —
                            # straight into the mmap'd region(s).
                            # _register_push_conn re-checks membership under
                            # _conn_lock (an unlocked pre-check here raced
                            # the pop in _serve's finally)
                            if is_bin:
                                self._register_push_conn(conn, send_lock)
                            try:
                                if op == "stripe":
                                    reply = self._op_stripe(conn, header)
                                else:
                                    reply = self._op_batch_write(
                                        conn, header, conn_state)
                            except (ConnectionError, OSError):
                                raise
                            except Exception as e:  # noqa: BLE001
                                # post-validation failure (e.g. region
                                # closed by stop() mid-transfer): report
                                # it, then drop the conn — the payload may
                                # not be fully consumed, so framing is gone
                                log.debug("ingest op %r failed: %s", op, e)
                                _reply({"ok": False, "error": str(e),
                                        "code": "ingest_failed"},
                                       is_bin)
                                return
                        elif op == "batch_open":
                            wire.drain_payload(conn, header)
                            # a prior batch_open whose batch_write never
                            # arrived is abandoned: release it or its
                            # reservations leak with no owner
                            self._abandon_batch(conn_state)
                            try:
                                reply = self._op_batch_open(header)
                                conn_state["batch"] = reply.pop("_ids")
                            except Exception as e:  # noqa: BLE001
                                log.debug("batch_open failed: %s", e)
                                reply = {"ok": False, "error": str(e),
                                         "code": "open_failed"}
                        else:
                            payload = wire.recv_payload(conn, header, pool)
                            try:
                                reply = self._handle(header, payload)
                            except Exception as e:  # noqa: BLE001
                                log.debug("op %r failed: %s",
                                          header.get("op"), e)
                                reply = {"ok": False, "error": str(e),
                                         "code": "error"}
                            finally:
                                # no generic op retains its payload past
                                # the handler — return the lease
                                if isinstance(payload, memoryview):
                                    pool.release(payload)
                            if op == "hello" and reply.get("ok"):
                                # remember the agreed caps on this conn:
                                # stripe CRC verification is gated on them
                                wire.set_negotiated_caps(
                                    conn, reply.get("caps") or ())
                    except (ConnectionError, OSError):
                        return
                    if not _reply(reply, is_bin):
                        return
        finally:
            # a connection that died between batch_open and batch_write
            # leaves reservations no client holds a handle to — release
            # them (the stripe TTL reaper only covers striped datasets)
            self._abandon_batch(conn_state)
            with self._conn_lock:
                self._conns.discard(conn)
                self._push_conns.pop(conn, None)

    def _abandon_batch(self, conn_state: dict) -> None:
        for fid in conn_state.pop("batch", None) or ():
            self._release_reservation(fid)

    def _register_push_conn(self, conn: socket.socket, send_lock) -> None:
        """Mark a bin1 data connection as eligible for proactive credit
        frames (only bin1 peers understand unsolicited ``credit`` ops)."""
        with self._conn_lock:
            if conn not in self._push_conns:
                self._push_conns[conn] = send_lock
                self.stats["bin_conns"] += 1

    # ------------------------------------------------------------------
    def _handle(self, h: dict, payload) -> dict:
        op = h.get("op")
        if op == "ping":
            return {"ok": True}
        if op == "hello":
            return wire.hello_reply(h, codecs=codec_mod.available(),
                                    caps=wire.SUPPORTED_CAPS)
        if op == "write_req":
            return self._op_write_req(h)
        if op == "reg_block":
            return self._op_reg_block(h)
        if op == "client_sync":
            return self._op_client_sync(h)
        if op == "stripe_open":
            return self._op_stripe_open(h)
        if op == "run_savime":
            res = self._savime().run(h["q"])
            if hasattr(res, "tolist"):
                res = res.tolist()
            return {"ok": True, "result": res}
        if op == "drain":
            self.drain(h.get("timeout"))
            return {"ok": True}
        if op == "stats":
            # snapshot under the owning locks: torn reads here made
            # monitoring report mutually inconsistent numbers
            with self._alloc_lock:
                mem_used = self._mem_used
                disk_used = self._disk_used
            with self._ds_lock:
                queued = len(self._datasets)
            out = {"ok": True, **self.stats, "mem_used": mem_used,
                   "disk_used": disk_used, "queued": queued,
                   "mem_capacity": self.mem_capacity,
                   "free_fraction": self.free_fraction()}
            if self._store is not None:
                pages = self._store.stats()
                out["pages"] = pages
                out["mem_used"] = mem_used + pages["mem_used"]
                out["disk_used"] = disk_used + pages["spill_used"]
            return out
        raise ValueError(f"unknown op {op!r}")

    def _dup_reply(self, h: dict) -> Optional[dict]:
        """Idempotent-replay check: a producer re-sending a journaled
        write whose ack was lost must not double-ingest. ``None`` means
        proceed; otherwise the positive ack to return as-is."""
        epoch = h.get("epoch")
        if not epoch:
            return None
        with self._ds_lock:
            if (h["name"], epoch) not in self._acked:
                return None
        self.stats["replay_dups"] += 1
        return {"ok": True, "dup": True, "file_id": "",
                "credits": self._credit_grant(int(h.get("credits", 4)))}

    def _apply_epoch(self, file_id: str, h: dict) -> None:
        epoch = h.get("epoch")
        if not epoch:
            return
        with self._ds_lock:
            ds = self._datasets.get(file_id)
            if ds is not None:
                ds.epoch = str(epoch)

    def _op_write_req(self, h: dict) -> dict:
        nbytes = int(h["size"])
        dup = self._dup_reply(h)
        if dup is not None:
            return dup
        cfields = self._parse_codec(h)   # validate before reserving
        if self._store is not None:
            rep = self._open_paged(h, nbytes)
            if rep is not None:
                self._apply_codec(rep["file_id"], cfields)
                self._apply_epoch(rep["file_id"], h)
                return rep
            # unsealed demand exceeds the store even after spilling
            # everything cold — the paper's disk tier takes the overflow
            in_memory = False
            with self._alloc_lock:
                self._disk_used += nbytes
            self.stats["disk_fallbacks"] += 1
        else:
            with self._alloc_lock:
                in_memory = self._mem_used + nbytes <= self.mem_capacity
                if in_memory:
                    self._mem_used += nbytes
                else:
                    self._disk_used += nbytes
            if not in_memory:
                self.stats["disk_fallbacks"] += 1  # paper: disk as fallback
        file_id = secrets.token_hex(8)
        base = self.mem_dir if in_memory else self.disk_dir
        path = os.path.join(base, file_id)
        try:
            region = MemoryRegion(path, nbytes, create=True)
        except BaseException:
            # mmap/ftruncate can fail after the capacity reservation was
            # taken; without the rollback the bytes leak until restart
            with self._alloc_lock:
                if in_memory:
                    self._mem_used -= nbytes
                else:
                    self._disk_used -= nbytes
            raise
        ds = _Dataset(file_id, h["name"], h.get("dtype", "uint8"), nbytes,
                      region, in_memory)
        if cfields is not None:
            ds.codec, ds.cmeta, ds.raw_size, ds.decode_at = cfields
        if h.get("epoch"):
            ds.epoch = str(h["epoch"])
        with self._ds_lock:
            self._datasets[file_id] = ds
        return {"ok": True, "file_id": file_id, "path": path,
                "in_memory": in_memory}

    def _parse_codec(self, h: dict) -> Optional[tuple]:
        """Validate and extract the codec fields riding an open header
        (``codec``/``cmeta``/``raw_size``/``decode_at``, DESIGN.md §13).
        Raises before any capacity is reserved so a bad codec name cannot
        leak a reservation; ``None`` for plain (uncoded) datasets."""
        name = h.get("codec")
        if not name or name == "none":
            return None
        cls = codec_mod.get(name)    # UnknownCodecError on bad names
        decode_at = h.get("decode_at") or "staging"
        if decode_at not in ("staging", "query"):
            raise ValueError(f"unknown decode_at {decode_at!r}")
        if cls.chained:
            # chain order only exists at ingest: deltas must decode in
            # sequence, so query-time laziness is forced off
            decode_at = "staging"
        return (name, dict(h.get("cmeta") or {}),
                int(h.get("raw_size") or 0), decode_at)

    def _apply_codec(self, file_id: str, cfields: Optional[tuple]) -> None:
        if cfields is None:
            return
        with self._ds_lock:
            ds = self._datasets.get(file_id)
        if ds is not None:
            ds.codec, ds.cmeta, ds.raw_size, ds.decode_at = cfields

    def _open_paged(self, h: dict, nbytes: int) -> Optional[dict]:
        """Reserve a page table for one dataset; ``None`` when unsealed
        demand exceeds the store (caller falls back to the disk tier).

        The reply carries the address translation for one-sided writers:
        ``path`` is the page *arena*, ``frames`` the arena byte offset of
        each page (``PagedRdmaWriter`` scatters through it); reg_block
        grants stay flat-shaped, so the bin1 wire format is untouched.
        """
        try:
            table = self._store.alloc(nbytes)
        except PageStoreFull:
            return None
        region = PagedMemoryRegion(self._store, table)
        file_id = secrets.token_hex(8)
        ds = _Dataset(file_id, h["name"], h.get("dtype", "uint8"), nbytes,
                      region, True)
        with self._ds_lock:
            self._datasets[file_id] = ds
        return {"ok": True, "file_id": file_id, "path": region.path,
                "in_memory": True, "page_bytes": self._store.page_bytes,
                "arena_bytes": self._store.arena_bytes,
                "frames": region.frame_offsets()}

    def _free_dataset(self, ds: _Dataset) -> None:
        """Release one dataset's storage and return its accounting — page
        tables back to the store (which owns frames and spill files), flat
        regions back to the mem/disk watermark."""
        ds.region.close(unlink=True)
        if ds.region.paged:
            return
        with self._alloc_lock:
            if ds.in_memory:
                self._mem_used -= ds.nbytes
            else:
                self._disk_used -= ds.nbytes

    def _release_reservation(self, file_id: str) -> None:
        """Undo one ``write_req`` reservation that never finished: close
        and unlink the region and return its capacity."""
        with self._ds_lock:
            ds = self._datasets.pop(file_id, None)
        if ds is None:
            return
        self._free_dataset(ds)

    # -- coalesced small-dataset ingest (DESIGN.md §10) -------------------
    def _op_batch_open(self, h: dict) -> dict:
        """Reserve regions for N datasets in one round-trip.

        All-or-nothing: if any reservation fails (capacity, tmpfs error),
        every region already opened for this batch is closed, unlinked
        and its capacity returned before the error is reported — a
        partial batch must not leak reservations that no client holds a
        handle to.
        """
        items = h.get("items")
        if not isinstance(items, list) or not items:
            raise ValueError("batch_open needs a non-empty items list")
        opened: list[dict] = []
        try:
            for it in items:
                opened.append(self._op_write_req(it))
        except BaseException as e:
            for rep in opened:
                self._release_reservation(rep["file_id"])
            raise RuntimeError(
                f"batch_open failed at item {len(opened)}/{len(items)} "
                f"({e}); {len(opened)} reservations rolled back") from e
        return {"ok": True, "items": opened,
                "_ids": [rep["file_id"] for rep in opened]}

    def _op_batch_write(self, conn: socket.socket, h: dict,
                        conn_state: dict) -> dict:
        """Land one jumbo multi-dataset payload into the regions reserved
        by the immediately preceding ``batch_open`` on this connection,
        then feed each sub-dataset into the finish/forward pipeline.

        Any validation failure must drain the declared payload before
        replying, or the connection's framing desynchronizes (the client
        pipelines batch_open + batch_write in one vectored send).
        """
        ids = conn_state.pop("batch", None)
        declared = int(h.get("nbytes") or 0)
        if ids is None:
            wire.drain_payload(conn, h)
            return {"ok": False, "code": "bad_request", "error":
                    "batch_write without a preceding successful batch_open"}
        with self._ds_lock:
            dss = [self._datasets.get(fid) for fid in ids]
        count = int(h.get("count", len(ids)))
        if any(ds is None for ds in dss) or count != len(ids) \
                or sum(ds.nbytes for ds in dss) != declared:
            wire.drain_payload(conn, h)
            for fid in ids:
                self._release_reservation(fid)
            return {"ok": False, "code": "bad_request", "error":
                    f"batch_write mismatch (count={count}, "
                    f"declared={declared} bytes)"}
        done = 0
        try:
            for ds in dss:
                # scatter across the region's segments (one contiguous
                # view for flat regions, per-page views when paged)
                for seg in ds.region.segments(0, ds.nbytes):
                    wire.recv_into(conn, seg)
                self._finish_dataset(ds)
                done += 1
        except BaseException:
            # connection died mid-payload: finished sub-datasets are
            # already forwarding; the rest must not leak their regions
            for ds in dss[done:]:
                self._release_reservation(ds.file_id)
            raise
        self.stats["batches"] += 1
        self.stats["batched_datasets"] += done
        return {"ok": True, "count": done,
                "credits": self._credit_grant(4)}

    def _op_reg_block(self, h: dict) -> dict:
        with self._ds_lock:
            ds = self._datasets[h["file_id"]]
            ds.last_stripe_at = time.monotonic()   # keep the reaper away
        grant = ds.region.register_block(int(h["offset"]), int(h["size"]))
        self.stats["registrations"] += 1
        return {"ok": True, **grant}

    def _op_client_sync(self, h: dict) -> dict:
        with self._ds_lock:
            ds = self._datasets[h["file_id"]]
        self._finish_dataset(ds)
        return {"ok": True}

    def _finish_dataset(self, ds: _Dataset) -> None:
        """Dataset fully received (block-path sync or last stripe): account
        it, decode it if an egress codec applies at ingest, and queue the
        staging→SAVIME forward."""
        ds.received_at = time.perf_counter()
        ds.finished = True    # universal: the reaper must skip forwards
        if ds.epoch:
            with self._ds_lock:
                first = (ds.name, ds.epoch) not in self._acked
                if first:
                    self._acked[(ds.name, ds.epoch)] = True
                    while len(self._acked) > _ACKED_CAP:
                        self._acked.popitem(last=False)
            if not first:
                # a replayed transfer raced the original's completion —
                # both finished. Keep the copy already forwarding; free
                # this one without double-counting it.
                self.stats["replay_dups"] += 1
                with self._ds_lock:
                    self._datasets.pop(ds.file_id, None)
                self._free_dataset(ds)
                return
        ds.region.deregister_all()   # paper: undo registration after sync
        if ds.region.paged:
            # fully received: pages become spillable / dedup-able
            ds.region.seal()
        self.stats["datasets"] += 1
        self.stats["bytes_in"] += ds.nbytes          # wire (coded) bytes
        self.stats["raw_bytes_in"] += ds.raw_size if ds.codec else ds.nbytes
        if ds.codec and ds.decode_at == "staging":
            self._decode_ingest(ds)   # forwards (or parks) from inside
            return
        self._send_pool.submit(self._send_to_savime, ds,
                               name=f"send-{ds.name}")

    # -- egress-codec decode (DESIGN.md §13) ------------------------------
    def _decoder(self, name: str) -> codec_mod.Codec:  # holds: self._codec_mutex
        dec = self._decoders.get(name)
        if dec is None:
            dec = self._decoders[name] = codec_mod.create(name)
        return dec

    def _region_bytes(self, ds: _Dataset):
        """One contiguous copy of the dataset's wire payload (the decoder
        keeps chain history across region swaps, so it needs its own
        buffer either way)."""
        if ds.region.paged:
            ds.region.pin()
            try:
                return ds.region.read(0, ds.nbytes)
            finally:
                ds.region.unpin()
        return bytes(ds.region.view()[:ds.nbytes])

    def _decode_ingest(self, ds: _Dataset) -> None:
        """Decode one finished dataset — and any parked chain successors
        it unblocks — then queue each for forwarding.

        Chained codecs (delta-rle) require decode in chain order, but
        io_threads/striping can reorder arrivals: a dataset whose base has
        not landed yet parks keyed ``(name, base_seq)`` and is revisited
        the moment its predecessor decodes. All decoder state and parking
        live under ``_codec_mutex``."""
        with self._codec_mutex:
            pending: Optional[_Dataset] = ds
            while pending is not None:
                try:
                    raw = self._decoder(pending.codec).decode(
                        self._region_bytes(pending), pending.cmeta,
                        key=pending.name)
                except codec_mod.CodecOrderError as e:
                    self._parked[(pending.name, e.base)] = pending
                    self.stats["codec_parked"] += 1
                    return
                except Exception as e:
                    # corrupt payload: the region must not leak while the
                    # error surfaces to the client
                    log.debug("codec %r decode of %r failed: %s",
                              pending.codec, pending.name, e)
                    with self._ds_lock:
                        self._datasets.pop(pending.file_id, None)
                    self._free_dataset(pending)
                    raise
                self._swap_region(pending, raw)
                self.stats["codec_datasets"] += 1
                self._submit_ordered(pending)
                seq = (pending.cmeta or {}).get("seq")
                pending = (self._parked.pop((pending.name, seq), None)
                           if seq is not None else None)

    def _submit_ordered(self, ds: _Dataset) -> None:  # holds: self._codec_mutex
        """Queue a decoded dataset's forward behind the previous forward
        queued for the same SAVIME name.

        Chained links decode in order under _codec_mutex, but the send
        pool has several workers: two same-name forwards could otherwise
        race and SAVIME's last-write-wins would keep the older link.  The
        wait cannot deadlock: a task only ever waits on one submitted
        *earlier*, and FIFO dequeue means the oldest unfinished task is
        never stuck behind a waiter."""
        prev = self._fwd_tails.get(ds.name)
        handle = self._send_pool.submit(self._send_after, ds, prev,
                                        name=f"send-{ds.name}")
        self._fwd_tails[ds.name] = handle
        if len(self._fwd_tails) > 64:
            self._fwd_tails = {n: h for n, h in self._fwd_tails.items()
                               if not h.done.is_set()}

    def _send_after(self, ds: _Dataset, prev: Optional[TaskHandle]) -> None:
        if prev is not None:
            # wait for completion, success *or* failure — ordering is the
            # only contract; poll so stop() (which abandons queued tasks,
            # leaving their handles forever pending) cannot wedge a worker
            while not prev.done.wait(0.05):
                if self._stop.is_set():
                    return
        self._send_to_savime(ds)

    def _swap_region(self, ds: _Dataset, raw) -> None:
        """Replace the dataset's wire-size storage with its decoded bytes:
        allocate raw-size storage through the normal tiers (paged store →
        flat tmpfs → disk), copy, and free the coded region together with
        its capacity accounting."""
        n = int(getattr(raw, "nbytes", None) or len(raw))
        ds.decoded = True
        old_region, old_mem, old_n = ds.region, ds.in_memory, ds.nbytes
        if n == 0 and old_n == 0:
            return                    # empty dataset: nothing to re-home
        rawv = codec_mod.as_bytes_array(raw)
        region, in_memory = self._alloc_plain(n)
        try:
            off = 0
            for seg in region.segments(0, n):
                ln = int(getattr(seg, "nbytes", None) or len(seg))
                seg[:] = rawv[off:off + ln]
                off += ln
            if region.paged:
                region.seal()
        except BaseException:
            region.close(unlink=True)
            if not region.paged:
                with self._alloc_lock:
                    if in_memory:
                        self._mem_used -= n
                    else:
                        self._disk_used -= n
            raise
        ds.region, ds.in_memory, ds.nbytes = region, in_memory, n
        old_region.close(unlink=True)
        if not old_region.paged:
            with self._alloc_lock:
                if old_mem:
                    self._mem_used -= old_n
                else:
                    self._disk_used -= old_n

    def _alloc_plain(self, nbytes: int):
        """Allocate dataset storage exactly like ``_op_write_req`` does,
        but for a server-internal (decoded) buffer with no client reply:
        paged store first, flat tmpfs under the watermark, disk overflow.
        Returns ``(region, in_memory)`` with the reservation taken."""
        if self._store is not None:
            try:
                table = self._store.alloc(nbytes)
                return PagedMemoryRegion(self._store, table), True
            except PageStoreFull:
                with self._alloc_lock:
                    self._disk_used += nbytes
                self.stats["disk_fallbacks"] += 1
                in_memory = False
        else:
            with self._alloc_lock:
                in_memory = self._mem_used + nbytes <= self.mem_capacity
                if in_memory:
                    self._mem_used += nbytes
                else:
                    self._disk_used += nbytes
            if not in_memory:
                self.stats["disk_fallbacks"] += 1
        file_id = secrets.token_hex(8)
        path = os.path.join(self.mem_dir if in_memory else self.disk_dir,
                            file_id)
        try:
            region = MemoryRegion(path, nbytes, create=True)
        except BaseException:
            with self._alloc_lock:
                if in_memory:
                    self._mem_used -= nbytes
                else:
                    self._disk_used -= nbytes
            raise
        return region, in_memory

    # -- striped ingest (DESIGN.md §9) -----------------------------------
    def _op_stripe_open(self, h: dict) -> dict:
        self._gc_stale_stripes()
        dup = self._dup_reply(h)
        if dup is not None:
            return dup               # replayed epoch: nothing to receive
        rep = self._op_write_req(h)
        n_stripes = int(h["n_stripes"])
        with self._ds_lock:
            ds = self._datasets[rep["file_id"]]
            ds.n_stripes = n_stripes
            ds.credits_wanted = max(1, int(h.get("credits", 4)))
            ds.last_stripe_at = time.monotonic()
        if n_stripes == 0:           # empty dataset: complete at open
            with self._ds_lock:
                ds.finished = True
            self._finish_dataset(ds)
        rep["credits"] = self._credit_grant(ds.credits_wanted)
        return rep

    def _op_stripe(self, conn: socket.socket, h: dict) -> dict:
        """Receive one stripe payload directly into the dataset's region.

        Any validation failure must still drain the payload bytes before
        replying, or the connection's framing desynchronizes.
        """
        nbytes = int(h.get("nbytes") or 0)
        try:
            with self._ds_lock:
                ds = self._datasets[h["file_id"]]
                dup = int(h["stripe_idx"]) in ds.stripes_seen
            idx = int(h["stripe_idx"])
            off = int(h["offset"])
            # one-sided stripes (sided=1) landed via a direct memory write;
            # the frame is control-only and declares its extent in "size"
            if h.get("sided"):
                if nbytes:
                    raise ValueError("sided stripe must not carry payload")
                span = int(h.get("size") or 0)
            else:
                span = nbytes
            if ds.n_stripes is None:
                raise ValueError("dataset was not opened with stripe_open")
            if h.get("enc") and not ds.codec:
                raise ValueError(
                    "enc stripe for a dataset opened without a codec")
            if off < 0 or off + span > ds.nbytes:
                raise ValueError(
                    f"stripe [{off},{off + span}) outside dataset "
                    f"[0,{ds.nbytes})")
        except (KeyError, ValueError, TypeError) as e:
            wire.drain_payload(conn, h)       # keep the stream framed
            return {"ok": False, "error": str(e), "code": "bad_request"}
        grant = self._credit_grant(ds.credits_wanted)
        if dup:
            # duplicate (retry / speculative re-send): ack idempotently,
            # do not touch the region — it may already be forwarding
            wire.drain_payload(conn, h)
            self.stats["stripe_dups"] += 1
            return {"ok": True, "stripe_idx": idx, "dup": True,
                    "done": False, "credits": grant}
        crc = None if h.get("sided") else h.get("crc")
        check = crc is not None and \
            wire.CAP_CRC in wire.negotiated_caps(conn)
        if nbytes:
            csum = 0
            for seg in ds.region.segments(off, nbytes):
                wire.recv_into(conn, seg)
                if check:
                    csum = zlib.crc32(seg, csum)
            if check and (csum & 0xFFFFFFFF) != int(crc):
                # payload fully consumed (framing intact) but mangled in
                # flight: leave the stripe out of stripes_seen so the
                # sender's re-send overwrites the garbage. The error text
                # is the contract — bin1 acks carry no code field.
                self.stats["crc_errors"] += 1
                return {"ok": False, "code": "corrupt",
                        "error": f"crc mismatch on stripe {idx} of "
                                 f"{ds.name!r}",
                        "stripe_idx": idx, "credits": grant}
        if span:
            # on-demand registration per stripe (paper: "the server
            # register each block as needed") — credit-granted rather than
            # request/reply, so it pipelines with the writes instead of
            # costing a serialized RTT + cold zero-fill pass per block
            ds.region.register_block(off, span)
            self.stats["registrations"] += 1
        done = False
        with self._ds_lock:
            ds.stripes_seen.add(idx)
            ds.last_stripe_at = time.monotonic()
            if len(ds.stripes_seen) >= ds.n_stripes and not ds.finished:
                ds.finished = done = True
        self.stats["stripes"] += 1
        if done:
            self._finish_dataset(ds)
        return {"ok": True, "stripe_idx": idx, "dup": False, "done": done,
                "credits": grant}

    def _gc_stale_stripes(self) -> None:
        """Reap datasets abandoned mid-transfer (client or channel died):
        without this their capacity reservation never releases, and since
        credit grants derive from ``_mem_used`` a few dead transfers would
        permanently throttle every healthy client. Covers block-path
        ``write_req`` reservations whose sync never came as well as
        striped ingests. Activity-based: a credit-stalled sender still
        trickles stripes (grants are never 0) and one-sided writers touch
        via reg_block, so only truly dead transfers age past the TTL."""
        now = time.monotonic()
        with self._ds_lock:
            stale = [ds for ds in self._datasets.values()
                     if not ds.finished
                     and now - ds.last_stripe_at > self.stripe_ttl]
            for ds in stale:
                self._datasets.pop(ds.file_id, None)
        for ds in stale:
            self._free_dataset(ds)
            self.stats["stripe_aborts"] += 1

    def _credit_grant(self, wanted: int) -> int:
        """Per-channel window grant: full when tmpfs is empty, shrinking
        toward 1 as it fills (a slow SAVIME hop keeps memory occupied, so
        producers stall on credits instead of overrunning the staging
        area). Never 0 — a zero grant with an empty pipeline would leave
        no ack to ever raise it again.

        Paged mode derives from *available pages* (free frames plus
        sealed evictable ones): a big cold backlog can always be spilled,
        so it no longer pins every producer's window to 1 the way the
        flat watermark did."""
        frac_free = self.free_fraction()
        return max(1, min(wanted, math.ceil(wanted * max(frac_free, 0.0))))

    def free_fraction(self) -> float:
        """The credit machinery's pressure signal, also exported through
        the ``stats`` op so a gateway can cap fleet-wide admission on the
        most-pressured backend."""
        if self._store is not None:
            return self._store.available_fraction()
        with self._alloc_lock:
            used = self._mem_used
        return 1.0 - used / self.mem_capacity if self.mem_capacity else 1.0

    # -- background forward (FCFS pool) ---------------------------------
    def _send_to_savime(self, ds: _Dataset) -> None:
        sent = ds.nbytes
        try:
            cli = self._savime()
            if ds.codec and not ds.decoded:
                # decode_at="query": the dataset was staged in wire form
                # (coded pages dedup and spill as-is); decode lazily on
                # the staging→SAVIME hop
                with self._codec_mutex:
                    raw = self._decoder(ds.codec).decode(
                        self._region_bytes(ds), ds.cmeta, key=ds.name)
                cli.load_dataset(ds.name, ds.dtype, raw)
                sent = int(getattr(raw, "nbytes", None) or len(raw))
            elif ds.region.paged:
                # gather page views (spilled pages stream from disk
                # without displacing hot frames); pin so the LRU cannot
                # evict a page out from under the send
                ds.region.pin()
                try:
                    cli.load_dataset_views(ds.name, ds.dtype,
                                           ds.region.page_views(),
                                           ds.nbytes)
                finally:
                    ds.region.unpin()
            else:
                cli.load_dataset_from_file(ds.name, ds.dtype, ds.region.fd,
                                           ds.nbytes)
        except OSError:
            if self._stop.is_set():
                return    # stop() already closed the regions mid-forward
            raise
        self.stats["bytes_to_savime"] += sent
        with self._ds_lock:
            self._datasets.pop(ds.file_id, None)
        self._free_dataset(ds)  # release staging memory (paper §3.2)
        if ds.in_memory:
            self._push_credits()

    def _push_credits(self) -> None:
        """Proactively raise windows on bin1 data connections after a
        forward released staging memory — a channel stalled at a grant of
        1 recovers immediately instead of waiting for its next ack (only
        bin1 peers understand unsolicited ``credit`` frames; JSON
        channels keep the ack-carried grants)."""
        with self._conn_lock:
            targets = list(self._push_conns.items())
        if not targets:
            return
        with self._ds_lock:
            wanted = max((d.credits_wanted for d in self._datasets.values()
                          if d.n_stripes is not None and not d.finished),
                         default=4)
        grant = self._credit_grant(wanted)
        for conn, send_lock in targets:
            try:
                with send_lock:
                    wire.send_frame_bin(conn,
                                        {"op": "credit", "credits": grant})
                self.stats["credit_pushes"] += 1
            except OSError:
                pass          # conn is dying; its serve thread cleans up
