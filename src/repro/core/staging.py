"""Staging server — the paper's §3 architecture, component 2 of 2.

Receives datasets from compute-node clients via emulated-RDMA one-sided
writes into mmap'd in-memory files (tmpfs, capacity-limited, disk
fallback), then forwards them to SAVIME in the background over TCP with
sendfile/splice, FCFS, from a pool of send threads. In-memory files are
unlinked after ingest to release memory (paper §3.2). Also proxies SAVIME
control commands for clients that cannot reach the analytical network.
"""
from __future__ import annotations

import os
import secrets
import socket
import threading
import time
from typing import Optional

from repro.core import wire
from repro.core.queues import FCFSPool
from repro.core.rdma import MemoryRegion
from repro.core.savime import SavimeClient


class _Dataset:
    def __init__(self, file_id: str, name: str, dtype: str, nbytes: int,
                 region: MemoryRegion, in_memory: bool):
        self.file_id = file_id
        self.name = name
        self.dtype = dtype
        self.nbytes = nbytes
        self.region = region
        self.in_memory = in_memory
        self.received_at: Optional[float] = None


class StagingServer:
    def __init__(self, savime_addr: str, host: str = "127.0.0.1",
                 port: int = 0, mem_capacity: int = 1 << 30,
                 mem_dir: Optional[str] = None,
                 disk_dir: Optional[str] = None,
                 send_threads: int = 2,
                 straggler_timeout: Optional[float] = None,
                 auto_subtar: bool = True):
        self.savime_addr = savime_addr
        uid = f"{os.getpid()}-{secrets.token_hex(3)}"
        self.mem_dir = mem_dir or f"/dev/shm/staging-{uid}"
        self.disk_dir = disk_dir or f"/tmp/staging-{uid}"
        os.makedirs(self.mem_dir, exist_ok=True)
        os.makedirs(self.disk_dir, exist_ok=True)
        self.mem_capacity = mem_capacity
        self._mem_used = 0
        self._alloc_lock = threading.Lock()
        # _datasets is written by connection threads and popped by send
        # threads — every mutation goes through _ds_lock
        self._ds_lock = threading.Lock()
        self._datasets: dict[str, _Dataset] = {}
        self._send_pool = FCFSPool(send_threads, "staging-send",
                                   straggler_timeout=straggler_timeout)
        self._savime_local = threading.local()
        self.auto_subtar = auto_subtar
        self.stats = {"datasets": 0, "bytes_in": 0, "bytes_to_savime": 0,
                      "disk_fallbacks": 0, "registrations": 0}

        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(128)
        self.addr = f"{host}:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._conns: set[socket.socket] = set()
        self._conn_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def start(self) -> "StagingServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="staging-accept", daemon=True)
        self._accept_thread.start()
        return self

    def stop(self, join_timeout: float = 2.0) -> None:
        self._stop.set()
        self._send_pool.stop()
        try:
            # shutdown (not just close) wakes a thread blocked in accept()
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._srv.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(join_timeout)
        deadline = time.monotonic() + join_timeout
        for t in self._threads:
            t.join(max(deadline - time.monotonic(), 0.0))
        self._threads = [t for t in self._threads if t.is_alive()]
        with self._ds_lock:
            datasets = list(self._datasets.values())
        for ds in datasets:
            ds.region.close(unlink=True)

    def live_threads(self) -> int:
        return sum(t.is_alive() for t in self._threads)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until the send queue is empty (staging→SAVIME finished)."""
        self._send_pool.sync(timeout)

    # ------------------------------------------------------------------
    def _savime(self) -> SavimeClient:
        cli = getattr(self._savime_local, "cli", None)
        if cli is None:  # one connection per send/serve thread
            cli = SavimeClient(self.savime_addr)
            self._savime_local.cli = cli
        return cli

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            self._threads = [t for t in self._threads if t.is_alive()]
            t = threading.Thread(target=self._serve, args=(conn,),
                                 name="staging-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _serve(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._conn_lock:
            self._conns.add(conn)
        try:
            with conn:
                while True:
                    try:
                        header, payload = wire.recv_frame(conn)
                    except (ConnectionError, OSError):
                        return
                    try:
                        reply = self._handle(header, payload)
                    except Exception as e:  # noqa: BLE001
                        reply = {"ok": False, "error": str(e)}
                    try:
                        wire.send_frame(conn, reply)
                    except OSError:
                        return
        finally:
            with self._conn_lock:
                self._conns.discard(conn)

    # ------------------------------------------------------------------
    def _handle(self, h: dict, payload) -> dict:
        op = h.get("op")
        if op == "ping":
            return {"ok": True}
        if op == "write_req":
            return self._op_write_req(h)
        if op == "reg_block":
            return self._op_reg_block(h)
        if op == "client_sync":
            return self._op_client_sync(h)
        if op == "run_savime":
            res = self._savime().run(h["q"])
            if hasattr(res, "tolist"):
                res = res.tolist()
            return {"ok": True, "result": res}
        if op == "drain":
            self.drain(h.get("timeout"))
            return {"ok": True}
        if op == "stats":
            return {"ok": True, **self.stats,
                    "mem_used": self._mem_used,
                    "queued": len(self._datasets)}
        raise ValueError(f"unknown op {op!r}")

    def _op_write_req(self, h: dict) -> dict:
        nbytes = int(h["size"])
        with self._alloc_lock:
            in_memory = self._mem_used + nbytes <= self.mem_capacity
            if in_memory:
                self._mem_used += nbytes
            else:
                self.stats["disk_fallbacks"] += 1  # paper: disk as fallback
        file_id = secrets.token_hex(8)
        base = self.mem_dir if in_memory else self.disk_dir
        path = os.path.join(base, file_id)
        try:
            region = MemoryRegion(path, nbytes, create=True)
        except BaseException:
            # mmap/ftruncate can fail after the capacity reservation was
            # taken; without the rollback the bytes leak until restart
            if in_memory:
                with self._alloc_lock:
                    self._mem_used -= nbytes
            raise
        ds = _Dataset(file_id, h["name"], h.get("dtype", "uint8"), nbytes,
                      region, in_memory)
        with self._ds_lock:
            self._datasets[file_id] = ds
        return {"ok": True, "file_id": file_id, "path": path,
                "in_memory": in_memory}

    def _op_reg_block(self, h: dict) -> dict:
        with self._ds_lock:
            ds = self._datasets[h["file_id"]]
        grant = ds.region.register_block(int(h["offset"]), int(h["size"]))
        self.stats["registrations"] += 1
        return {"ok": True, **grant}

    def _op_client_sync(self, h: dict) -> dict:
        with self._ds_lock:
            ds = self._datasets[h["file_id"]]
        ds.received_at = time.perf_counter()
        ds.region.deregister_all()   # paper: undo registration after sync
        self.stats["datasets"] += 1
        self.stats["bytes_in"] += ds.nbytes
        self._send_pool.submit(self._send_to_savime, ds,
                               name=f"send-{ds.name}")
        return {"ok": True}

    # -- background forward (FCFS pool) ---------------------------------
    def _send_to_savime(self, ds: _Dataset) -> None:
        try:
            cli = self._savime()
            cli.load_dataset_from_file(ds.name, ds.dtype, ds.region.fd,
                                       ds.nbytes)
        except OSError:
            if self._stop.is_set():
                return    # stop() already closed the regions mid-forward
            raise
        self.stats["bytes_to_savime"] += ds.nbytes
        ds.region.close(unlink=True)  # release tmpfs memory (paper §3.2)
        with self._ds_lock:
            self._datasets.pop(ds.file_id, None)
        if ds.in_memory:
            with self._alloc_lock:
                self._mem_used -= ds.nbytes
