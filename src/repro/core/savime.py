"""SAVIME — in-memory array DBMS for simulation data (stub-faithful build).

Implements the subset of SAVIME the paper exercises:
  * named byte *datasets* ingested over TCP (fast path: the staging server
    streams them with sendfile);
  * a TARS catalogue: ``create_tar`` / ``load_subtar`` attach datasets as
    subtar payloads;
  * analytical reads: ``select`` (dimension/range filter) and ``aggregate``
    — "SAVIME API already allows filtering stored data by dimensions and by
    range" (§6);
  * concurrent analytical readers (thread-per-connection + TAR RLocks).

The mini query language mirrors the paper's Listing 1 usage:
    create_tar(velocity, "x:0:200, y:0:500, z:0:500", "v:float64")
    load_subtar(velocity, D, "0,0,0", "201,501,501", v)
    select(velocity, v, "0,0,0", "10,10,10")
    aggregate(velocity, v, mean)
    drop_tar(velocity)
"""
from __future__ import annotations

import queue
import re
import select
import socket
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.core.tars import TAR, Attribute, Dimension
from repro.core import wire


class SavimeError(RuntimeError):
    pass


_ARG_RE = re.compile(r'"([^"]*)"|([^,()\s][^,()]*)')


def _parse_call(q: str) -> tuple[str, list[str]]:
    q = q.strip().rstrip(";")
    m = re.match(r"(\w+)\s*\((.*)\)\s*$", q, re.S)
    if not m:
        raise SavimeError(f"cannot parse query: {q!r}")
    fn, argstr = m.group(1), m.group(2)
    args = [a or b for a, b in _ARG_RE.findall(argstr)]
    return fn, [a.strip() for a in args]


class SavimeEngine:
    """In-process engine (the TCP server wraps this)."""

    # enforced by `python -m repro.lint` (DESIGN.md §14); _lock is an
    # RLock so query handlers can nest under run()
    _GUARDED_BY = {
        "tars": "_lock",
        "datasets": "_lock",
        "_listeners": "_lock",
        "stats": "_lock",
    }

    def __init__(self):
        self.tars: dict[str, TAR] = {}
        self.datasets: dict[str, np.ndarray] = {}
        self._lock = threading.RLock()
        self._listeners: list[Callable[[dict], None]] = []
        self.stats = {"bytes_ingested": 0, "datasets": 0, "queries": 0,
                      "subtars": 0}

    # -- subtar-arrival listeners (feed the subscribe/notify push path) ----
    def add_listener(self, fn: Callable[[dict], None]) -> None:
        with self._lock:
            self._listeners.append(fn)

    def remove_listener(self, fn: Callable[[dict], None]) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _notify(self, event: dict) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for fn in listeners:
            try:
                fn(event)
            except Exception:  # noqa: BLE001 — listeners must not break ingest
                pass

    # -- dataset ingestion (binary path) -----------------------------------
    def load_dataset(self, name: str, dtype: str, payload) -> None:
        arr = np.frombuffer(payload, dtype=np.dtype(dtype))
        with self._lock:
            self.datasets[name] = arr
            self.stats["bytes_ingested"] += arr.nbytes
            self.stats["datasets"] += 1

    # -- stat snapshots (the server must not read `stats` unlocked) --------
    def subtar_seq(self) -> int:
        with self._lock:
            return self.stats["subtars"]

    def stats_snapshot(self) -> dict:
        with self._lock:
            return dict(self.stats)

    # -- query language ------------------------------------------------------
    def run(self, q: str) -> Any:
        with self._lock:
            self.stats["queries"] += 1
        fn, args = _parse_call(q)
        handler = getattr(self, f"_q_{fn}", None)
        if handler is None:
            raise SavimeError(f"unknown operator {fn!r}")
        return handler(*args)

    def _q_create_tar(self, name: str, dims: str, attrs: str) -> str:
        dl = []
        for d in dims.split(","):
            parts = d.strip().split(":")
            dname, lo, hi = parts[0], int(parts[1]), int(parts[2])
            off = float(parts[3]) if len(parts) > 3 else 0.0
            stride = float(parts[4]) if len(parts) > 4 else 1.0
            dl.append(Dimension(dname, lo, hi, off, stride))
        al = [Attribute(*a.strip().split(":")) for a in attrs.split(",")]
        with self._lock:
            if name in self.tars:
                raise SavimeError(f"tar {name!r} exists")
            self.tars[name] = TAR(name, dl, al)
        return "ok"

    def _q_load_subtar(self, tar: str, dataset: str, origin: str,
                       shape: str, attr: str) -> str:
        t = self._tar(tar)
        with self._lock:
            if dataset not in self.datasets:
                raise SavimeError(f"dataset {dataset!r} not loaded")
            arr = self.datasets.pop(dataset)  # move: staging frees its copy too
        o = tuple(int(x) for x in origin.split(","))
        s = tuple(int(x) for x in shape.split(","))
        t.load_subtar(o, s, {attr: arr})
        with self._lock:
            self.stats["subtars"] += 1
            seq = self.stats["subtars"]
        self._notify({"tar": tar, "origin": list(o), "shape": list(s),
                      "attr": attr, "seq": seq})
        return "ok"

    def _q_select(self, tar: str, attr: str, lo: str = "", hi: str = ""):
        t = self._tar(tar)
        lo_t = tuple(int(x) for x in lo.split(",")) if lo else None
        hi_t = tuple(int(x) for x in hi.split(",")) if hi else None
        return t.select(attr, lo_t, hi_t)

    def _q_aggregate(self, tar: str, attr: str, op: str,
                     lo: str = "", hi: str = "") -> float:
        t = self._tar(tar)
        lo_t = tuple(int(x) for x in lo.split(",")) if lo else None
        hi_t = tuple(int(x) for x in hi.split(",")) if hi else None
        return t.aggregate(attr, op, lo_t, hi_t)

    def _q_data_box(self, tar: str):
        """Loaded bounding box ``[lo, hi]`` (inclusive), or None when the
        TAR holds no subtars — the scatter-gather router unions these to
        resolve unbounded queries to the same clip box a single server
        would use (DESIGN.md §12)."""
        box = self._tar(tar).data_box()
        if box is None:
            return None
        return [list(box[0]), list(box[1])]

    def _q_drop_tar(self, name: str) -> str:
        with self._lock:
            self.tars.pop(name, None)
        return "ok"

    def _q_list_tars(self) -> str:
        with self._lock:
            return ",".join(sorted(self.tars))

    def _tar(self, name: str) -> TAR:
        with self._lock:
            if name not in self.tars:
                raise SavimeError(f"no tar {name!r}")
            return self.tars[name]


class SavimeServer:
    """TCP front-end. Ops: query | load_dataset | subscribe | stats | ping.

    ``subscribe`` turns a connection into a push channel: the server acks
    ``{ok, seq}`` and then sends one ``{op: "notify", tar, origin, shape,
    attr, seq}`` frame per subtar loaded into the watched TAR (name match;
    ``""`` matches all, a trailing ``*`` matches by prefix) until the
    client closes the socket — the paper's query-while-running goal (§6)
    without analytical clients polling ``select``.
    """

    _GUARDED_BY = {
        "_threads": "_threads_lock",
        "_conns": "_conn_lock",
    }

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self.engine = SavimeEngine()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(64)
        self.addr = f"{host}:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        # appended by the accept loop, walked by stop()/live_threads() —
        # the same prune-while-join race StagingServer fixed in PR 7
        self._threads: list[threading.Thread] = []
        self._threads_lock = threading.Lock()
        self._conns: set[socket.socket] = set()
        self._conn_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None

    def start(self) -> "SavimeServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="savime-accept", daemon=True)
        self._accept_thread.start()
        return self

    def stop(self, join_timeout: float = 2.0) -> None:
        self._stop.set()
        try:
            # shutdown (not just close) wakes a thread blocked in accept()
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._srv.close()
        except OSError:
            pass
        # unblock connection threads parked in recv, then join them
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(join_timeout)
        deadline = time.monotonic() + join_timeout
        with self._threads_lock:
            threads = list(self._threads)
        for t in threads:
            t.join(max(deadline - time.monotonic(), 0.0))
        with self._threads_lock:
            self._threads = [t for t in self._threads if t.is_alive()]

    def live_threads(self) -> int:
        with self._threads_lock:
            return sum(t.is_alive() for t in self._threads)

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            # prune finished connection threads so a long-running server
            # stays bounded by *live* connections, not total ever accepted
            with self._threads_lock:
                self._threads = [t for t in self._threads if t.is_alive()]
                t = threading.Thread(target=self._serve, args=(conn,),
                                     name="savime-conn", daemon=True)
                t.start()
                self._threads.append(t)

    def _serve(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._conn_lock:
            self._conns.add(conn)
        try:
            with conn:
                while True:
                    try:
                        header, payload = wire.recv_frame(conn)
                    except (ConnectionError, OSError):
                        return
                    if header.get("op") == "subscribe":
                        self._serve_subscription(conn, header)
                        return
                    try:
                        reply, data = self._handle(header, payload)
                    except Exception as e:  # noqa: BLE001 — report to client
                        reply, data = {"ok": False, "error": str(e),
                                       "code": "error"}, None
                    try:
                        wire.send_frame(conn, reply, data)
                    except OSError:
                        return
        finally:
            with self._conn_lock:
                self._conns.discard(conn)

    def _serve_subscription(self, conn: socket.socket, header) -> None:
        """Push-mode connection: forward matching subtar events until the
        subscriber (or the server) goes away."""
        pattern = header.get("tar", "")
        # bounded: a stalled subscriber must not grow server memory with
        # ingest; drop-oldest keeps the most recent events for the reader
        events: queue.Queue = queue.Queue(maxsize=1024)

        def listener(ev: dict) -> None:
            t = ev["tar"]
            if not (not pattern or t == pattern or
                    (pattern.endswith("*") and t.startswith(pattern[:-1]))):
                return
            while True:
                try:
                    events.put_nowait(ev)
                    return
                except queue.Full:
                    try:
                        events.get_nowait()
                    except queue.Empty:
                        pass

        self.engine.add_listener(listener)
        try:
            # a reader that stops draining must eventually free this
            # thread: a stalled send times out and ends the subscription
            conn.settimeout(30.0)
            wire.send_frame(conn, {"ok": True, "tar": pattern,
                                   "seq": self.engine.subtar_seq()})
            while not self._stop.is_set():
                try:
                    ev = events.get(timeout=0.25)
                except queue.Empty:
                    # no event to push — check for subscriber EOF, or an
                    # idle disconnected watcher leaks this thread and its
                    # engine listener until server stop
                    r, _, _ = select.select([conn], [], [], 0)
                    if r and not conn.recv(1, socket.MSG_PEEK):
                        return
                    continue
                wire.send_frame(conn, {"op": "notify", "ok": True, **ev})
        except OSError:
            pass
        finally:
            self.engine.remove_listener(listener)

    def _handle(self, header, payload):
        op = header.get("op")
        if op == "ping":
            return {"ok": True}, None
        if op == "load_dataset":
            self.engine.load_dataset(header["name"], header["dtype"], payload)
            return {"ok": True}, None
        if op == "query":
            res = self.engine.run(header["q"])
            if isinstance(res, np.ndarray):
                # range-filtered results may be strided views; memoryview
                # cast("B") requires C-contiguity
                res = np.ascontiguousarray(res)
                return {"ok": True, "dtype": str(res.dtype),
                        "shape": list(res.shape)}, memoryview(res).cast("B")
            return {"ok": True, "result": res}, None
        if op == "stats":
            return {"ok": True, **self.engine.stats_snapshot()}, None
        raise SavimeError(f"unknown op {op!r}")


class SavimeClient:
    """Thin client used by staging + analytical apps (and tests)."""

    def __init__(self, addr: str):
        self.addr = addr
        self._sock = wire.connect(addr)
        self._lock = threading.Lock()

    def run(self, q):
        """Run one operator. ``q`` may be a typed statement from
        :mod:`repro.analysis.query` (preferred) or raw mini-language text
        (deprecated as a user API — kept as wire plumbing; DESIGN.md §8)."""
        if hasattr(q, "compile"):
            q = q.compile()
        # _lock deliberately serialises whole request/reply round-trips on
        # this one socket — that's its job (same for every ignore below)
        with self._lock:  # lint: ignore[io-under-lock]
            header, payload = wire.request(self._sock, {"op": "query", "q": q})
        if not header.get("ok"):
            raise SavimeError(header.get("error", "?"))
        if "dtype" in header:
            return np.frombuffer(payload, header["dtype"]).reshape(header["shape"])
        return header.get("result")

    def load_dataset(self, name: str, dtype: str, payload) -> None:
        with self._lock:  # lint: ignore[io-under-lock]
            header, _ = wire.request(
                self._sock, {"op": "load_dataset", "name": name,
                             "dtype": dtype}, payload)
        if not header.get("ok"):
            raise SavimeError(header.get("error", "?"))

    def load_dataset_from_file(self, name: str, dtype: str, fd: int,
                               count: int) -> None:
        """Zero-copy ingest path: sendfile(2)/splice from a (tmpfs) file
        straight into the SAVIME socket — the paper's staging→SAVIME hop."""
        with self._lock:  # lint: ignore[io-under-lock]
            wire.send_frame_from_file(
                self._sock, {"op": "load_dataset", "name": name,
                             "dtype": dtype}, fd, count)
            header, _ = wire.recv_frame(self._sock)
        if not header.get("ok"):
            raise SavimeError(header.get("error", "?"))

    def load_dataset_views(self, name: str, dtype: str, views,
                           count: int) -> None:
        """Scatter-gather ingest for paged staging (DESIGN.md §11): one
        vectored send over the dataset's page views — arena slices for
        resident pages, file bytes for spilled ones — with no user-space
        concatenation."""
        total = sum(getattr(v, "nbytes", None) or len(v) for v in views)
        if total != count:
            raise SavimeError(
                f"page views cover {total} bytes, dataset is {count}")
        with self._lock:  # lint: ignore[io-under-lock]
            wire.sendmsg_all(self._sock, wire.encode_frame(
                {"op": "load_dataset", "name": name, "dtype": dtype},
                list(views)))
            header, _ = wire.recv_frame(self._sock)
        if not header.get("ok"):
            raise SavimeError(header.get("error", "?"))

    def stats(self) -> dict:
        with self._lock:  # lint: ignore[io-under-lock]
            header, _ = wire.request(self._sock, {"op": "stats"})
        return header

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
