"""libstaging — the paper's client library (§3.2: server / communicator /
dataset), Python/NumPy edition of the C++ API in Listing 1:

    st = StagingClient("127.0.0.1:3221", io_threads=1, block_size=256 << 20)
    st.run_savime("create_tar(...);")
    ds = Dataset("D", "float64", st)
    ds.write(v)            # non-blocking: enqueue + return
    st.sync()              # block until all writes reached staging
    st.run_savime("load_subtar(...);")

Since the transport API redesign both ``StagingClient`` and ``Dataset``
are thin facades over :class:`repro.transport.TransferSession` with the
``rdma_staged`` transport — pinning, backpressure and per-dataset futures
come from the session (see DESIGN.md §7).  ``Communicator`` remains the
low-level engine room the staged transport drives directly.
"""
from __future__ import annotations

import time
from typing import Optional, Union

import numpy as np

from repro.core import wire
from repro.core.blocks import plan_blocks
from repro.core.queues import FCFSPool, TaskHandle
from repro.core.rdma import writer_for_reply

Buf = Union[np.ndarray, bytes, bytearray, memoryview]


class Communicator:
    """Manages the task queue + I/O thread pool (not user-facing).

    With ``n_channels > 1`` each dataset is striped across a shared
    :class:`~repro.transport.channels.ChannelGroup` (concurrent
    connections + credit-based flow control) instead of the single
    per-thread connection; the FCFS queue/sync semantics are unchanged —
    only the per-dataset data plane widens.

    Two small-regime levers (DESIGN.md §10), both off by default:
    ``wire_format="bin1"`` negotiates the struct-packed fast path per
    connection (per-block ``reg_block``/ack frames skip JSON and ride
    single ``sendmsg`` calls); ``coalesce_bytes > 0`` routes datasets
    below the threshold through a :class:`~repro.transport.coalesce.
    Coalescer` that packs them into one ``batch_open`` + ``batch_write``
    round-trip instead of 2+ control RTTs each.
    """

    def __init__(self, addr: str, io_threads: int, block_size: int,
                 straggler_timeout: Optional[float] = None,
                 n_channels: int = 1, stripe_bytes: Optional[int] = None,
                 credits: int = 4, wire_format: str = wire.WIRE_JSON,
                 coalesce_bytes: int = 0, linger_ms: float = 2.0):
        if wire_format not in wire.SUPPORTED_WIRE:
            raise ValueError(f"unknown wire_format {wire_format!r}; "
                             f"supported: {', '.join(wire.SUPPORTED_WIRE)}")
        self.addr = addr
        self.block_size = block_size
        self.wire_format = wire_format
        self._pool = None
        self._socks = wire.ConnCache()   # one conn (≈ RC QP) per I/O thread
        self._channels = None
        self._coalescer = None
        if coalesce_bytes > 0:
            # imported lazily: repro.transport imports this module
            from repro.transport.coalesce import Coalescer
            self._coalescer = Coalescer(self._flush_batch, coalesce_bytes,
                                        linger_ms=linger_ms)
        if n_channels > 1:
            # striped mode bypasses the I/O pool entirely — don't start
            # worker threads that would only ever idle
            from repro.transport.channels import ChannelGroup
            self._channels = ChannelGroup(
                addr, n_channels=n_channels,
                stripe_bytes=stripe_bytes or block_size,
                credits=credits, wire_format=wire_format).open()
        else:
            self._pool = FCFSPool(io_threads, "libstaging-io",
                                  straggler_timeout=straggler_timeout)

    def _connect(self, addr: str):
        sock = wire.connect(addr)
        if self.wire_format == wire.WIRE_BIN1:
            # per-connection handshake; an old server leaves us on JSON
            wire.negotiate(sock)
        return sock

    def _conn(self):
        return self._socks.get(self.addr, factory=self._connect)

    def _request(self, header: dict, payload=None) -> dict:
        h, _ = wire.request(self._conn(), header, payload)
        if not h.get("ok"):
            raise RuntimeError(f"staging error: {h.get('error')}")
        return h

    # -- the transfer task (runs on an I/O thread) -----------------------
    def _send(self, name: str, dtype: str, buf: np.ndarray) -> int:
        nbytes = buf.nbytes
        # NB: "nbytes" is reserved by the wire framing; use "size"
        h = self._request({"op": "write_req", "name": name, "dtype": dtype,
                           "size": nbytes})
        conn = self._conn()
        use_bin = wire.negotiated(conn) == wire.WIRE_BIN1
        writer = writer_for_reply(h, nbytes)
        try:
            flat = buf.reshape(-1).view(np.uint8)
            for off, size in plan_blocks(nbytes, self.block_size):
                # ask for the remote block (server registers on demand)...
                hdr = {"op": "reg_block", "file_id": h["file_id"],
                       "offset": off, "size": size}
                if use_bin:     # fast path: packed header, one sendmsg
                    wire.send_frame_bin(conn, hdr)
                    grant, _ = wire.recv_frame(conn)
                    if not grant.get("ok"):
                        raise RuntimeError(
                            f"staging error: {grant.get('error')}")
                else:
                    grant = self._request(hdr)
                # ...then one-sided RDMA write, no server CPU involved
                writer.write(grant["offset"], flat[off:off + size],
                             grant["rkey"])
            # two-sided sync message: no more remote ops on this MR
            self._request({"op": "client_sync", "file_id": h["file_id"]})
        finally:
            writer.close()
        return nbytes

    # -- the coalesced batch flush (runs on the coalescer worker) --------
    def _flush_batch(self, items) -> None:
        """One round-trip for N small datasets: pipelined ``batch_open``
        (reservations) + ``batch_write`` (jumbo payload), pushed in a
        single vectored ``sendmsg`` — nothing is concatenated in user
        space, the payload iovec list is the item buffers themselves."""
        sock = self._conn()       # coalescer worker gets its own cached conn
        open_hdr = {"op": "batch_open",
                    "items": [{"name": it.name, "dtype": it.dtype,
                               "size": it.nbytes} for it in items]}
        write_hdr = {"op": "batch_write", "count": len(items)}
        payload = [it.buf for it in items if it.nbytes]
        wire.send_frames_vectored(
            sock, [(open_hdr, None), (write_hdr, payload)],
            fmt=wire.negotiated(sock))
        oh, _ = wire.recv_frame(sock)
        wh, _ = wire.recv_frame(sock)
        if not oh.get("ok"):
            raise RuntimeError(f"batch_open failed: {oh.get('error')}")
        if not wh.get("ok"):
            raise RuntimeError(f"batch_write failed: {wh.get('error')}")

    def submit(self, name: str, dtype: str, buf: np.ndarray) -> TaskHandle:
        if self._coalescer is not None and \
                buf.nbytes < self._coalescer.coalesce_bytes:
            flat = buf.reshape(-1).view(np.uint8)
            return self._coalescer.add(name, dtype, flat, buf.nbytes)
        if self._channels is not None:
            # striped mode bypasses the I/O pool entirely: stripes are
            # enqueued onto the channels right away and datasets pipeline
            # back-to-back (no per-dataset drain between transfers); the
            # ack-driven completion feeds the same TaskHandle contract
            h = TaskHandle(self._send, (name, dtype, buf),
                           name=f"write-{name}")
            h.started_at = time.perf_counter()
            h.attempts = 1
            tr = self._channels.submit_dataset(name, dtype, buf)
            tr.add_done_callback(
                lambda t, h=h: h.complete(result=t.nbytes)
                if t.error is None else h.complete(error=t.error))
            return h
        return self._pool.submit(self._send, name, dtype, buf,
                                 name=f"write-{name}")

    def sync(self, timeout: Optional[float] = None) -> None:
        if self._coalescer is not None:
            self._coalescer.sync(timeout)
        if self._channels is not None:
            self._channels.sync(timeout)
        else:
            self._pool.sync(timeout)

    def stop(self) -> None:
        if self._coalescer is not None:
            self._coalescer.close()      # flushes buffered small datasets
        if self._pool is not None:
            self._pool.stop()            # joins in-flight transfers first
        self._socks.close_all()          # per-thread QPs die with the pool
        if self._channels is not None:
            self._channels.close()       # drains in-flight stripes first

    def channel_stats(self) -> list[dict]:
        return self._channels.channel_stats() if self._channels else []


class StagingClient:
    """The paper's ``staging::server`` handle (now a TransferSession facade)."""

    def __init__(self, addr: str, io_threads: int = 1,
                 block_size: int = 64 << 20,
                 straggler_timeout: Optional[float] = None,
                 max_inflight_bytes: Optional[int] = None,
                 n_channels: int = 1, stripe_bytes: Optional[int] = None,
                 credits: int = 4, wire_format: str = wire.WIRE_JSON,
                 coalesce_bytes: int = 0, linger_ms: float = 2.0):
        # imported lazily: repro.transport's engine modules import this
        # module for Communicator
        from repro.transport import TransferSession, TransportConfig
        self.session = TransferSession("rdma_staged", TransportConfig(
            staging_addr=addr, io_threads=io_threads, block_size=block_size,
            straggler_timeout=straggler_timeout,
            max_inflight_bytes=max_inflight_bytes,
            n_channels=n_channels, stripe_bytes=stripe_bytes,
            credits=credits, wire_format=wire_format,
            coalesce_bytes=coalesce_bytes, linger_ms=linger_ms)).open()

    @property
    def comm(self) -> Communicator:
        return self.session.transport.comm

    def run_savime(self, q: str):
        """Proxy a SAVIME operator through staging (compute nodes cannot
        reach the analytical network directly — paper §3.1)."""
        return self.session.run_savime(q)

    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until all queued writes are fully received by staging."""
        self.session.sync(timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until staging finished forwarding to SAVIME (benchmarks)."""
        self.session.drain(timeout)

    def stats(self) -> dict:
        return self.session.server_stats()

    def close(self) -> None:
        self.session.close()


class Dataset:
    """The paper's ``staging::dataset``."""

    def __init__(self, name: str, dtype: str, server: StagingClient):
        self.name = name
        self.dtype = dtype
        self.server = server
        self._handles: list = []

    def write(self, buf: Buf, nbytes: Optional[int] = None):
        """Non-blocking; buffer pinned (by the session) until completion.
        Returns a :class:`repro.transport.DatasetFuture`."""
        fut = self.server.session.write(self.name, buf, dtype=self.dtype,
                                        nbytes=nbytes)
        self._handles.append(fut)
        return fut
