"""libstaging — the paper's client library (§3.2: server / communicator /
dataset), Python/NumPy edition of the C++ API in Listing 1:

    st = StagingClient("127.0.0.1:3221", io_threads=1, block_size=256 << 20)
    st.run_savime("create_tar(...);")
    ds = Dataset("D", "float64", st)
    ds.write(v)            # non-blocking: enqueue + return
    st.sync()              # block until all writes reached staging
    st.run_savime("load_subtar(...);")

Since the transport API redesign both ``StagingClient`` and ``Dataset``
are thin facades over :class:`repro.transport.TransferSession` with the
``rdma_staged`` transport — pinning, backpressure and per-dataset futures
come from the session (see DESIGN.md §7).  ``Communicator`` remains the
low-level engine room the staged transport drives directly.
"""
from __future__ import annotations

import time
from typing import Optional, Union

import numpy as np

from repro.core import wire
from repro.core.blocks import plan_blocks
from repro.core.queues import FCFSPool, TaskHandle
from repro.core.rdma import RdmaWriter

Buf = Union[np.ndarray, bytes, bytearray, memoryview]


class Communicator:
    """Manages the task queue + I/O thread pool (not user-facing).

    With ``n_channels > 1`` each dataset is striped across a shared
    :class:`~repro.transport.channels.ChannelGroup` (concurrent
    connections + credit-based flow control) instead of the single
    per-thread connection; the FCFS queue/sync semantics are unchanged —
    only the per-dataset data plane widens.
    """

    def __init__(self, addr: str, io_threads: int, block_size: int,
                 straggler_timeout: Optional[float] = None,
                 n_channels: int = 1, stripe_bytes: Optional[int] = None,
                 credits: int = 4):
        self.addr = addr
        self.block_size = block_size
        self._pool = None
        self._socks = wire.ConnCache()   # one conn (≈ RC QP) per I/O thread
        self._channels = None
        if n_channels > 1:
            # striped mode bypasses the I/O pool entirely — don't start
            # worker threads that would only ever idle
            # (imported lazily: repro.transport imports this module)
            from repro.transport.channels import ChannelGroup
            self._channels = ChannelGroup(
                addr, n_channels=n_channels,
                stripe_bytes=stripe_bytes or block_size,
                credits=credits).open()
        else:
            self._pool = FCFSPool(io_threads, "libstaging-io",
                                  straggler_timeout=straggler_timeout)

    def _conn(self):
        return self._socks.get(self.addr)

    def _request(self, header: dict, payload=None) -> dict:
        h, _ = wire.request(self._conn(), header, payload)
        if not h.get("ok"):
            raise RuntimeError(f"staging error: {h.get('error')}")
        return h

    # -- the transfer task (runs on an I/O thread) -----------------------
    def _send(self, name: str, dtype: str, buf: np.ndarray) -> int:
        nbytes = buf.nbytes
        # NB: "nbytes" is reserved by the wire framing; use "size"
        h = self._request({"op": "write_req", "name": name, "dtype": dtype,
                           "size": nbytes})
        writer = RdmaWriter(h["path"], nbytes)
        try:
            flat = buf.reshape(-1).view(np.uint8)
            for off, size in plan_blocks(nbytes, self.block_size):
                # ask for the remote block (server registers on demand)...
                grant = self._request({"op": "reg_block",
                                       "file_id": h["file_id"],
                                       "offset": off, "size": size})
                # ...then one-sided RDMA write, no server CPU involved
                writer.write(grant["offset"], flat[off:off + size],
                             grant["rkey"])
            # two-sided sync message: no more remote ops on this MR
            self._request({"op": "client_sync", "file_id": h["file_id"]})
        finally:
            writer.close()
        return nbytes

    def submit(self, name: str, dtype: str, buf: np.ndarray) -> TaskHandle:
        if self._channels is not None:
            # striped mode bypasses the I/O pool entirely: stripes are
            # enqueued onto the channels right away and datasets pipeline
            # back-to-back (no per-dataset drain between transfers); the
            # ack-driven completion feeds the same TaskHandle contract
            h = TaskHandle(self._send, (name, dtype, buf),
                           name=f"write-{name}")
            h.started_at = time.perf_counter()
            h.attempts = 1
            tr = self._channels.submit_dataset(name, dtype, buf)
            tr.add_done_callback(
                lambda t, h=h: h.complete(result=t.nbytes)
                if t.error is None else h.complete(error=t.error))
            return h
        return self._pool.submit(self._send, name, dtype, buf,
                                 name=f"write-{name}")

    def sync(self, timeout: Optional[float] = None) -> None:
        if self._channels is not None:
            self._channels.sync(timeout)
        else:
            self._pool.sync(timeout)

    def stop(self) -> None:
        if self._pool is not None:
            self._pool.stop()            # joins in-flight transfers first
        self._socks.close_all()          # per-thread QPs die with the pool
        if self._channels is not None:
            self._channels.close()       # drains in-flight stripes first

    def channel_stats(self) -> list[dict]:
        return self._channels.channel_stats() if self._channels else []


class StagingClient:
    """The paper's ``staging::server`` handle (now a TransferSession facade)."""

    def __init__(self, addr: str, io_threads: int = 1,
                 block_size: int = 64 << 20,
                 straggler_timeout: Optional[float] = None,
                 max_inflight_bytes: Optional[int] = None,
                 n_channels: int = 1, stripe_bytes: Optional[int] = None,
                 credits: int = 4):
        # imported lazily: repro.transport's engine modules import this
        # module for Communicator
        from repro.transport import TransferSession, TransportConfig
        self.session = TransferSession("rdma_staged", TransportConfig(
            staging_addr=addr, io_threads=io_threads, block_size=block_size,
            straggler_timeout=straggler_timeout,
            max_inflight_bytes=max_inflight_bytes,
            n_channels=n_channels, stripe_bytes=stripe_bytes,
            credits=credits)).open()

    @property
    def comm(self) -> Communicator:
        return self.session.transport.comm

    def run_savime(self, q: str):
        """Proxy a SAVIME operator through staging (compute nodes cannot
        reach the analytical network directly — paper §3.1)."""
        return self.session.run_savime(q)

    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until all queued writes are fully received by staging."""
        self.session.sync(timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until staging finished forwarding to SAVIME (benchmarks)."""
        self.session.drain(timeout)

    def stats(self) -> dict:
        return self.session.server_stats()

    def close(self) -> None:
        self.session.close()


class Dataset:
    """The paper's ``staging::dataset``."""

    def __init__(self, name: str, dtype: str, server: StagingClient):
        self.name = name
        self.dtype = dtype
        self.server = server
        self._handles: list = []

    def write(self, buf: Buf, nbytes: Optional[int] = None):
        """Non-blocking; buffer pinned (by the session) until completion.
        Returns a :class:`repro.transport.DatasetFuture`."""
        fut = self.server.session.write(self.name, buf, dtype=self.dtype,
                                        nbytes=nbytes)
        self._handles.append(fut)
        return fut
