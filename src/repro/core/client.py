"""libstaging — the paper's client library (§3.2: server / communicator /
dataset), Python/NumPy edition of the C++ API in Listing 1:

    st = StagingClient("127.0.0.1:3221", io_threads=1, block_size=256 << 20)
    st.run_savime("create_tar(...);")
    ds = Dataset("D", "float64", st)
    ds.write(v)            # non-blocking: enqueue + return
    st.sync()              # block until all writes reached staging
    st.run_savime("load_subtar(...);")

Since the transport API redesign both ``StagingClient`` and ``Dataset``
are thin facades over :class:`repro.transport.TransferSession` with the
``rdma_staged`` transport — pinning, backpressure and per-dataset futures
come from the session (see DESIGN.md §7).  ``Communicator`` remains the
low-level engine room the staged transport drives directly.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Union

import numpy as np

from repro.core import wire
from repro.core.blocks import plan_blocks
from repro.core.queues import FCFSPool, TaskHandle
from repro.core.rdma import writer_for_reply
from repro.core.retry import RetryPolicy

Buf = Union[np.ndarray, bytes, bytearray, memoryview]


class Communicator:
    """Manages the task queue + I/O thread pool (not user-facing).

    With ``n_channels > 1`` each dataset is striped across a shared
    :class:`~repro.transport.channels.ChannelGroup` (concurrent
    connections + credit-based flow control) instead of the single
    per-thread connection; the FCFS queue/sync semantics are unchanged —
    only the per-dataset data plane widens.

    Two small-regime levers (DESIGN.md §10), both off by default:
    ``wire_format="bin1"`` negotiates the struct-packed fast path per
    connection (per-block ``reg_block``/ack frames skip JSON and ride
    single ``sendmsg`` calls); ``coalesce_bytes > 0`` routes datasets
    below the threshold through a :class:`~repro.transport.coalesce.
    Coalescer` that packs them into one ``batch_open`` + ``batch_write``
    round-trip instead of 2+ control RTTs each.
    """

    def __init__(self, addr: str, io_threads: int, block_size: int,
                 straggler_timeout: Optional[float] = None,
                 n_channels: int = 1, stripe_bytes: Optional[int] = None,
                 credits: int = 4, wire_format: str = wire.WIRE_JSON,
                 coalesce_bytes: int = 0, linger_ms: float = 2.0,
                 gateway: bool = False, tenant: Optional[str] = None,
                 codec: str = "none", decode_at: str = "staging",
                 retry: int = 3, deadline_s: Optional[float] = None):
        if wire_format not in wire.SUPPORTED_WIRE:
            raise ValueError(f"unknown wire_format {wire_format!r}; "
                             f"supported: {', '.join(wire.SUPPORTED_WIRE)}")
        if decode_at not in ("staging", "query"):
            raise ValueError(f"unknown decode_at {decode_at!r}; "
                             "supported: staging, query")
        self.addr = addr
        self.block_size = block_size
        self.wire_format = wire_format
        # shared transfer retry policy (DESIGN.md §15): exponential
        # backoff + full jitter, optional per-write deadline budget
        self._retry = RetryPolicy(retries=retry, deadline_s=deadline_s)
        # egress reduction codec (DESIGN.md §13): encode happens centrally
        # in submit() so the block, coalesced and striped paths all ship
        # the same reduced bytes. The codec only activates once the peer
        # advertised it in the hello handshake (_codec_active); against an
        # old server we silently fall back to raw bytes.
        self._codec = None
        self._decode_at = decode_at
        if codec != "none":
            from repro import codec as codec_mod
            self._codec = codec_mod.create(codec)   # raises on unknown name
        self._codec_lock = threading.Lock()          # chain/order + counters
        self._codec_ok: Optional[bool] = None
        self._codec_counts = {"raw_bytes": 0, "wire_bytes": 0,
                              "encode_s": 0.0, "datasets": 0, "fallbacks": 0}
        self._pool = None
        self._socks = wire.ConnCache()   # one conn (≈ RC QP) per I/O thread
        self._channels = None
        self._coalescer = None
        self._gateway = None
        if gateway:
            # redirect protocol (DESIGN.md §12): one control RTT per
            # dataset resolves placement + tenancy; data goes straight
            # to the admitted backend, never through the gateway
            from repro.gateway.client import GatewayClient
            self._gateway = GatewayClient(addr, tenant=tenant)
        if coalesce_bytes > 0:
            # imported lazily: repro.transport imports this module
            from repro.transport.coalesce import Coalescer
            self._coalescer = Coalescer(self._flush_batch, coalesce_bytes,
                                        linger_ms=linger_ms)
        self._channel_opts = {"n_channels": n_channels,
                              "stripe_bytes": stripe_bytes or block_size,
                              "credits": credits, "wire_format": wire_format,
                              "retry": self._retry}
        self._groups: dict[str, object] = {}   # backend addr -> ChannelGroup
        self._groups_lock = threading.Lock()
        if n_channels > 1:
            # striped mode bypasses the I/O pool entirely — don't start
            # worker threads that would only ever idle. Behind a gateway
            # the groups open lazily per admitted backend instead.
            if not gateway:
                self._channels = self._group_for(addr)
        else:
            self._pool = FCFSPool(io_threads, "libstaging-io",
                                  straggler_timeout=straggler_timeout)

    def _connect(self, addr: str):
        sock = wire.connect(addr)
        codecs = (self._codec.name,) if self._codec is not None else ()
        if self.wire_format == wire.WIRE_BIN1:
            # per-connection handshake; an old server leaves us on JSON
            wire.negotiate(sock, codecs=codecs, caps=wire.SUPPORTED_CAPS)
        elif codecs:
            # codec negotiation without a wire upgrade: offer JSON only
            wire.negotiate(sock, formats=(wire.WIRE_JSON,), codecs=codecs,
                           caps=wire.SUPPORTED_CAPS)
        return sock

    def _conn(self, addr: Optional[str] = None):
        return self._socks.get(addr or self.addr, factory=self._connect)

    def _group_for(self, addr: str):
        """Get-or-open the striped ChannelGroup bound to ``addr`` (one
        per backend when a gateway spreads datasets across a pool)."""
        with self._groups_lock:
            grp = self._groups.get(addr)
            if grp is None:
                from repro.transport.channels import ChannelGroup
                grp = ChannelGroup(addr, **self._channel_opts).open()
                self._groups[addr] = grp
            return grp

    def _request(self, header: dict, payload=None,
                 addr: Optional[str] = None) -> dict:
        h, _ = wire.request(self._conn(addr), header, payload)
        if not h.get("ok"):
            from repro.gateway.tenancy import error_from_reply
            raise error_from_reply(h, "staging error")
        return h

    # -- egress codec stage (DESIGN.md §13) ------------------------------
    def _codec_active(self) -> bool:
        """True once the peer has accepted our codec in a hello handshake.

        Probed lazily on the main address (the gateway answers for its
        whole pool); a peer that never advertised the codec leaves the
        sender on raw bytes — recorded as a fallback, not an error."""
        if self._codec is None:
            return False
        if self._codec_ok is None:
            with self._codec_lock:
                if self._codec_ok is None:
                    try:
                        sock = self._conn(self.addr)
                        ok = self._codec.name in wire.negotiated_codecs(sock)
                    except (OSError, RuntimeError):
                        ok = False
                    if not ok:
                        self._codec_counts["fallbacks"] += 1
                    self._codec_ok = ok
        return self._codec_ok

    def _encode(self, name: str, dtype: str, buf: np.ndarray):
        """Encode one dataset; returns (wire_buf, codec header fields).

        Serialized under the codec lock: chained codecs (delta-rle) must
        observe submissions in order even when I/O threads race."""
        t0 = time.perf_counter()
        with self._codec_lock:
            payload, meta = self._codec.encode(buf, dtype=dtype, key=name)
            enc = payload if isinstance(payload, np.ndarray) else \
                np.frombuffer(memoryview(payload).cast("B"), np.uint8)
            c = self._codec_counts
            c["raw_bytes"] += buf.nbytes
            c["wire_bytes"] += enc.nbytes
            c["encode_s"] += time.perf_counter() - t0
            c["datasets"] += 1
        cinfo = {"codec": self._codec.name, "cmeta": meta,
                 "raw_size": int(meta.get("raw_size", buf.nbytes)),
                 "decode_at": self._decode_at}
        return enc, cinfo

    def codec_stats(self) -> dict:
        if self._codec is None:
            return {}
        with self._codec_lock:
            return dict(self._codec_counts, name=self._codec.name)

    # -- the transfer task (runs on an I/O thread) -----------------------
    def _send(self, name: str, dtype: str, buf: np.ndarray,
              addr: Optional[str] = None, cinfo: Optional[dict] = None,
              epoch: Optional[str] = None) -> int:
        """Block-path transfer with connection-level retry: a broken conn
        is dropped from the cache, the write restarts from ``write_req``
        after a jittered backoff (the epoch makes the restart idempotent —
        a server that already finished this epoch just acks ``dup``)."""
        for attempt in self._retry.attempts(f"write {name!r}"):
            tgt = addr
            try:
                if tgt is None and self._gateway is not None:
                    # re-admit on every attempt: after a backend fail-out
                    # the gateway routes the retry onto the rebuilt ring
                    tgt = self._gateway.admit(name, buf.nbytes, epoch=epoch)
                return self._send_once(name, dtype, buf, tgt, cinfo, epoch)
            except (ConnectionError, TimeoutError, OSError) as e:
                self._socks.invalidate(tgt or self.addr)
                attempt.backoff(e)   # raises RetryExhausted when spent

    def _send_once(self, name: str, dtype: str, buf: np.ndarray,
                   addr: Optional[str], cinfo: Optional[dict],
                   epoch: Optional[str]) -> int:
        nbytes = buf.nbytes
        # NB: "nbytes" is reserved by the wire framing; use "size"
        req = dict({"op": "write_req", "name": name,
                    "dtype": dtype, "size": nbytes}, **(cinfo or {}))
        if epoch is not None:
            req["epoch"] = epoch
        h = self._request(req, addr=addr)
        if h.get("dup"):
            return nbytes     # server already holds this epoch in full
        conn = self._conn(addr)
        use_bin = wire.negotiated(conn) == wire.WIRE_BIN1
        writer = writer_for_reply(h, nbytes)
        try:
            flat = buf.reshape(-1).view(np.uint8)
            for off, size in plan_blocks(nbytes, self.block_size):
                # ask for the remote block (server registers on demand)...
                hdr = {"op": "reg_block", "file_id": h["file_id"],
                       "offset": off, "size": size}
                if use_bin:     # fast path: packed header, one sendmsg
                    wire.send_frame_bin(conn, hdr)
                    grant, _ = wire.recv_frame(conn)
                    if not grant.get("ok"):
                        raise RuntimeError(
                            f"staging error: {grant.get('error')}")
                else:
                    grant = self._request(hdr, addr=addr)
                # ...then one-sided RDMA write, no server CPU involved
                writer.write(grant["offset"], flat[off:off + size],
                             grant["rkey"])
            # two-sided sync message: no more remote ops on this MR
            self._request({"op": "client_sync", "file_id": h["file_id"]},
                          addr=addr)
        finally:
            writer.close()
        return nbytes

    # -- the coalesced batch flush (runs on the coalescer worker) --------
    def _flush_one_batch(self, sock, items) -> None:
        """Pipelined ``batch_open`` + ``batch_write`` against one server,
        pushed in a single vectored ``sendmsg`` — nothing is concatenated
        in user space, the payload iovec list is the item buffers."""
        open_hdr = {"op": "batch_open",
                    "items": [dict({"name": it.name, "dtype": it.dtype,
                                    "size": it.nbytes}, **(it.extra or {}))
                              for it in items]}
        write_hdr = {"op": "batch_write", "count": len(items)}
        payload = [it.buf for it in items if it.nbytes]
        wire.send_frames_vectored(
            sock, [(open_hdr, None), (write_hdr, payload)],
            fmt=wire.negotiated(sock))
        oh, _ = wire.recv_frame(sock)
        wh, _ = wire.recv_frame(sock)
        if not oh.get("ok"):
            raise RuntimeError(f"batch_open failed: {oh.get('error')}")
        if not wh.get("ok"):
            raise RuntimeError(f"batch_write failed: {wh.get('error')}")

    def _flush_batch(self, items) -> None:
        """One round-trip for N small datasets (two behind a gateway:
        ``admit_batch`` resolves tenancy + placement for the whole batch
        first, then one vectored flush per admitted backend)."""
        if self._gateway is None:
            self._flush_one_batch(self._conn(), items)
            return
        # all-or-nothing admission: a quota rejection fails every item's
        # future before any backend sees a byte
        addrs = self._gateway.admit_batch([(it.name, it.nbytes)
                                           for it in items])
        by_addr: dict[str, list] = {}
        for addr, it in zip(addrs, items):
            by_addr.setdefault(addr, []).append(it)
        for addr, group in by_addr.items():
            self._flush_one_batch(self._conn(addr), group)

    def submit(self, name: str, dtype: str, buf: np.ndarray,
               epoch: Optional[str] = None,
               replay: bool = False) -> TaskHandle:
        cinfo = None
        if self._codec_active():
            if replay:
                # a replayed write cannot assume the server's decode chain
                # saw the original: break the chain so this encode is
                # self-contained (base=None), whatever landed before
                with self._codec_lock:
                    self._codec.reset(name)
            # one central encode feeds all three egress paths; downstream
            # decisions (coalescing threshold, striping plan) see the
            # *wire* size — that is the point of reducing first
            buf, cinfo = self._encode(name, dtype, buf)
        if not replay and self._coalescer is not None and \
                buf.nbytes < self._coalescer.coalesce_bytes:
            # replays skip the coalescer: recovery wants the write on the
            # wire now, with its epoch checked individually, not parked
            # behind a linger window in a batch that could fail as a unit
            extra = cinfo if epoch is None else dict(cinfo or {},
                                                     epoch=epoch)
            flat = buf.reshape(-1).view(np.uint8)
            return self._coalescer.add(name, dtype, flat, buf.nbytes,
                                       extra=extra)
        if self._channel_opts["n_channels"] > 1:
            # striped mode bypasses the I/O pool entirely: stripes are
            # enqueued onto the channels right away and datasets pipeline
            # back-to-back (no per-dataset drain between transfers); the
            # ack-driven completion feeds the same TaskHandle contract
            h = TaskHandle(self._send, (name, dtype, buf),
                           name=f"write-{name}")
            h.started_at = time.perf_counter()
            h.attempts = 1
            if self._gateway is not None:
                try:
                    group = self._group_for(
                        self._gateway.admit(name, buf.nbytes, epoch=epoch))
                except Exception as e:  # noqa: BLE001 — typed quota/auth
                    h.complete(error=e)
                    return h
            else:
                group = self._channels
            try:
                tr = group.submit_dataset(name, dtype, buf,
                                          codec_info=cinfo, epoch=epoch)
            except (ConnectionError, OSError) as e:
                h.complete(error=e)      # RetryExhausted after reopens
                return h
            tr.add_done_callback(
                lambda t, h=h: h.complete(result=t.nbytes)
                if t.error is None else h.complete(error=t.error))
            return h
        return self._pool.submit(self._send, name, dtype, buf, None, cinfo,
                                 epoch, name=f"write-{name}")

    def _all_groups(self) -> list:
        with self._groups_lock:
            return list(self._groups.values())

    def sync(self, timeout: Optional[float] = None) -> None:
        if self._coalescer is not None:
            self._coalescer.sync(timeout)
        for grp in self._all_groups():
            grp.sync(timeout)
        if self._pool is not None:
            self._pool.sync(timeout)

    def stop(self) -> None:
        if self._coalescer is not None:
            self._coalescer.close()      # flushes buffered small datasets
        if self._pool is not None:
            self._pool.stop()            # joins in-flight transfers first
        self._socks.close_all()          # per-thread QPs die with the pool
        for grp in self._all_groups():
            grp.close()                  # drains in-flight stripes first
        if self._gateway is not None:
            self._gateway.close()

    def channel_stats(self) -> list[dict]:
        out: list[dict] = []
        for grp in self._all_groups():
            out.extend(grp.channel_stats())
        return out


class StagingClient:
    """The paper's ``staging::server`` handle (now a TransferSession facade)."""

    def __init__(self, addr: str, io_threads: int = 1,
                 block_size: int = 64 << 20,
                 straggler_timeout: Optional[float] = None,
                 max_inflight_bytes: Optional[int] = None,
                 n_channels: int = 1, stripe_bytes: Optional[int] = None,
                 credits: int = 4, wire_format: str = wire.WIRE_JSON,
                 coalesce_bytes: int = 0, linger_ms: float = 2.0,
                 codec: str = "none", decode_at: str = "staging"):
        # imported lazily: repro.transport's engine modules import this
        # module for Communicator
        from repro.transport import TransferSession, TransportConfig
        self.session = TransferSession("rdma_staged", TransportConfig(
            staging_addr=addr, io_threads=io_threads, block_size=block_size,
            straggler_timeout=straggler_timeout,
            max_inflight_bytes=max_inflight_bytes,
            n_channels=n_channels, stripe_bytes=stripe_bytes,
            credits=credits, wire_format=wire_format,
            coalesce_bytes=coalesce_bytes, linger_ms=linger_ms,
            codec=codec, decode_at=decode_at)).open()

    @property
    def comm(self) -> Communicator:
        return self.session.transport.comm

    def run_savime(self, q: str):
        """Proxy a SAVIME operator through staging (compute nodes cannot
        reach the analytical network directly — paper §3.1)."""
        return self.session.run_savime(q)

    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until all queued writes are fully received by staging."""
        self.session.sync(timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until staging finished forwarding to SAVIME (benchmarks)."""
        self.session.drain(timeout)

    def stats(self) -> dict:
        return self.session.server_stats()

    def close(self) -> None:
        self.session.close()


class Dataset:
    """The paper's ``staging::dataset``."""

    def __init__(self, name: str, dtype: str, server: StagingClient):
        self.name = name
        self.dtype = dtype
        self.server = server
        self._handles: list = []

    def write(self, buf: Buf, nbytes: Optional[int] = None):
        """Non-blocking; buffer pinned (by the session) until completion.
        Returns a :class:`repro.transport.DatasetFuture`."""
        fut = self.server.session.write(self.name, buf, dtype=self.dtype,
                                        nbytes=nbytes)
        self._handles.append(fut)
        return fut
