"""libstaging — the paper's client library (§3.2: server / communicator /
dataset), Python/NumPy edition of the C++ API in Listing 1:

    st = StagingClient("127.0.0.1:3221", io_threads=1, block_size=256 << 20)
    st.run_savime("create_tar(...);")
    ds = Dataset("D", "float64", st)
    ds.write(v)            # non-blocking: enqueue + return
    st.sync()              # block until all writes reached staging
    st.run_savime("load_subtar(...);")

`write` pushes a task to the communicator's local queue; a pool of I/O
threads consumes tasks (producer-consumer). The buffer must not be mutated
until sync() returns (it is pinned by reference until sent).
"""
from __future__ import annotations

import threading
from typing import Optional, Union

import numpy as np

from repro.core import wire
from repro.core.blocks import plan_blocks
from repro.core.queues import FCFSPool, TaskHandle
from repro.core.rdma import RdmaWriter

Buf = Union[np.ndarray, bytes, bytearray, memoryview]


class Communicator:
    """Manages the task queue + I/O thread pool (not user-facing)."""

    def __init__(self, addr: str, io_threads: int, block_size: int,
                 straggler_timeout: Optional[float] = None):
        self.addr = addr
        self.block_size = block_size
        self._pool = FCFSPool(io_threads, "libstaging-io",
                              straggler_timeout=straggler_timeout)
        self._local = threading.local()

    def _conn(self):
        sock = getattr(self._local, "sock", None)
        if sock is None:  # one control connection (≈ RC QP) per I/O thread
            sock = wire.connect(self.addr)
            self._local.sock = sock
        return sock

    def _request(self, header: dict, payload=None) -> dict:
        h, _ = wire.request(self._conn(), header, payload)
        if not h.get("ok"):
            raise RuntimeError(f"staging error: {h.get('error')}")
        return h

    # -- the transfer task (runs on an I/O thread) -----------------------
    def _send(self, name: str, dtype: str, buf: np.ndarray) -> int:
        nbytes = buf.nbytes
        # NB: "nbytes" is reserved by the wire framing; use "size"
        h = self._request({"op": "write_req", "name": name, "dtype": dtype,
                           "size": nbytes})
        writer = RdmaWriter(h["path"], nbytes)
        try:
            flat = buf.reshape(-1).view(np.uint8)
            for off, size in plan_blocks(nbytes, self.block_size):
                # ask for the remote block (server registers on demand)...
                grant = self._request({"op": "reg_block",
                                       "file_id": h["file_id"],
                                       "offset": off, "size": size})
                # ...then one-sided RDMA write, no server CPU involved
                writer.write(grant["offset"], flat[off:off + size],
                             grant["rkey"])
            # two-sided sync message: no more remote ops on this MR
            self._request({"op": "client_sync", "file_id": h["file_id"]})
        finally:
            writer.close()
        return nbytes

    def submit(self, name: str, dtype: str, buf: np.ndarray) -> TaskHandle:
        return self._pool.submit(self._send, name, dtype, buf,
                                 name=f"write-{name}")

    def sync(self, timeout: Optional[float] = None) -> None:
        self._pool.sync(timeout)

    def stop(self) -> None:
        self._pool.stop()


class StagingClient:
    """The paper's ``staging::server`` handle."""

    def __init__(self, addr: str, io_threads: int = 1,
                 block_size: int = 64 << 20,
                 straggler_timeout: Optional[float] = None):
        self.comm = Communicator(addr, io_threads, block_size,
                                 straggler_timeout)
        self._ctrl = wire.connect(addr)
        self._ctrl_lock = threading.Lock()

    def run_savime(self, q: str):
        """Proxy a SAVIME operator through staging (compute nodes cannot
        reach the analytical network directly — paper §3.1)."""
        with self._ctrl_lock:
            h, _ = wire.request(self._ctrl, {"op": "run_savime", "q": q})
        if not h.get("ok"):
            raise RuntimeError(f"savime error: {h.get('error')}")
        return h.get("result")

    def sync(self, timeout: Optional[float] = None) -> None:
        """Block until all queued writes are fully received by staging."""
        self.comm.sync(timeout)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until staging finished forwarding to SAVIME (benchmarks)."""
        with self._ctrl_lock:
            h, _ = wire.request(self._ctrl, {"op": "drain",
                                             "timeout": timeout})
        if not h.get("ok"):
            raise RuntimeError(h.get("error"))

    def stats(self) -> dict:
        with self._ctrl_lock:
            h, _ = wire.request(self._ctrl, {"op": "stats"})
        return h

    def close(self) -> None:
        self.comm.stop()
        try:
            self._ctrl.close()
        except OSError:
            pass


class Dataset:
    """The paper's ``staging::dataset``."""

    def __init__(self, name: str, dtype: str, server: StagingClient):
        self.name = name
        self.dtype = dtype
        self.server = server
        self._handles: list[TaskHandle] = []

    def write(self, buf: Buf, nbytes: Optional[int] = None) -> TaskHandle:
        """Non-blocking; buffer pinned (by reference) until sync()."""
        arr = np.frombuffer(buf, dtype=np.uint8) if not isinstance(buf, np.ndarray) \
            else buf
        if nbytes is not None:
            arr = arr.reshape(-1).view(np.uint8)[:nbytes]
        h = self.server.comm.submit(self.name, self.dtype, arr)
        self._handles.append(h)
        return h
