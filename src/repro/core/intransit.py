"""In-transit analysis sink for JAX jobs — the paper's technique as a
first-class training/serving feature.

The training/serving loop produces *quantities of interest* (simulation
fields, diagnostics tensors, activation samples, checkpoint shards). The
sink ships them through the full paper pipeline without blocking the step:

    device arrays --(device_get)--> host --libstaging(async, RDMA-emulated,
    block knob)--> staging tmpfs --(sendfile, FCFS pool)--> SAVIME TARS

DDL is automatic: each staged array gets a TAR whose dimensions mirror its
shape (+ a leading `step` dimension), and a ``load_subtar`` is issued once
the dataset lands in SAVIME — so analytical clients can query any range of
any step while the job keeps running (the paper's §6 goal).

Data reduction (paper §6 future work, implemented): optional int8 block
quantization before egress — 4x/2x wire-volume reduction; scales are staged
as a companion attribute so analysis can dequantize exactly.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

import numpy as np

from repro.analysis.query import CreateTar, LoadSubtar
from repro.core.tars import Attribute, Dimension
from repro.transport import TransferSession, TransportConfig

MAX_STEPS = 1_000_000  # upper bound of the `step` dimension in DDL


@dataclasses.dataclass(frozen=True)
class InTransitConfig:
    block_size: int = 16 << 20
    io_threads: int = 2
    quantize: str = "none"        # none | int8
    quant_block: int = 4096       # elements per quantization block
    tar_prefix: str = "run"
    straggler_timeout: Optional[float] = None
    transport: str = "rdma_staged"   # any registered transport name
    max_inflight_bytes: Optional[int] = None  # egress backpressure bound
    n_channels: int = 1              # striped egress connections (1 = off)
    stripe_bytes: Optional[int] = None  # stripe size (None = block_size)
    credits: int = 4                 # per-channel credit window request
    wire_format: str = "json"        # "json" (legacy) | "bin1" fast path
    coalesce_bytes: int = 0          # coalesce datasets below this (0 = off)
    linger_ms: float = 2.0           # coalescing flush window
    page_bytes: int = 0              # paged staging page size (0 = flat)
    spill_dir: Optional[str] = None  # cold-page spill tier (paged mode)
    dedup: bool = False              # content-addressed page dedup
    gateway: bool = False            # addr is a staging gateway (pool mode)
    tenant: Optional[str] = None     # tenant token for gateway auth
    codec: str = "none"              # egress reduction codec (DESIGN.md §13)
    decode_at: str = "staging"       # "staging" (ingest) | "query" (lazy)


def quantize_int8_np(x: np.ndarray, block: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-block symmetric int8 quantization (numpy oracle; the Pallas
    kernel in repro/kernels/quantize is the device-side twin)."""
    flat = x.reshape(-1).astype(np.float32)
    pad = (-flat.size) % block
    if pad:
        flat = np.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = np.abs(blocks).max(axis=1) / 127.0
    scale = np.where(scale == 0, 1.0, scale)
    q = np.clip(np.rint(blocks / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1), scale.astype(np.float32)


def dequantize_int8_np(q: np.ndarray, scale: np.ndarray, shape, block: int):
    blocks = q.reshape(-1, block).astype(np.float32) * scale[:, None]
    return blocks.reshape(-1)[: int(np.prod(shape))].reshape(shape)


class InTransitSink:
    """Asynchronous egress of named arrays into SAVIME via a
    :class:`~repro.transport.TransferSession`.

    ``addr`` is the staging server for the default ``rdma_staged``
    transport, or the SAVIME address for the copy-emulation transports
    (``cfg.transport`` names any registered engine).
    """

    def __init__(self, addr: str, cfg: InTransitConfig = InTransitConfig()):
        self.cfg = cfg
        staged = cfg.transport == "rdma_staged"
        gateway = staged and cfg.gateway
        self.session = TransferSession(cfg.transport, TransportConfig(
            staging_addr=addr if staged and not gateway else None,
            savime_addr=None if staged else addr,
            gateway_addr=addr if gateway else None, tenant=cfg.tenant,
            io_threads=cfg.io_threads, block_size=cfg.block_size,
            straggler_timeout=cfg.straggler_timeout,
            max_inflight_bytes=cfg.max_inflight_bytes,
            n_channels=cfg.n_channels, stripe_bytes=cfg.stripe_bytes,
            credits=cfg.credits, wire_format=cfg.wire_format,
            coalesce_bytes=cfg.coalesce_bytes,
            linger_ms=cfg.linger_ms, page_bytes=cfg.page_bytes,
            spill_dir=cfg.spill_dir, dedup=cfg.dedup,
            codec=cfg.codec, decode_at=cfg.decode_at)).open()
        self._tars: set[str] = set()
        self._pending: list[LoadSubtar] = []  # typed DDL to run at flush
        self._lock = threading.Lock()
        self.staged_bytes = 0
        self.staged_arrays = 0

    @property
    def client(self):
        """Back-compat alias: the session speaks the old StagingClient
        surface (sync / drain / run_savime / close)."""
        return self.session

    # ------------------------------------------------------------------
    def _ensure_tar(self, tar: str, shape: tuple[int, ...], dtype: str,
                    quantized: bool) -> None:
        if tar in self._tars:
            return
        step = Dimension("step", 0, MAX_STEPS)
        if quantized:  # quantized payloads are flat (block-padded) streams
            n = int(np.prod(shape))
            qlen = n + ((-n) % self.cfg.quant_block)
            dims = (step, Dimension("i", 0, qlen - 1))
            attrs = (Attribute("v", "int8"),)
        else:
            dims = (step,) + tuple(Dimension(f"d{i}", 0, n - 1)
                                   for i, n in enumerate(shape))
            attrs = (Attribute("v", dtype),)
        self.session.run_savime(CreateTar(tar, dims, attrs))
        if quantized:
            self.session.run_savime(CreateTar(
                f"{tar}__scale",
                (step, Dimension("b", 0, MAX_STEPS)),
                (Attribute("s", "float32"),)))
        self._tars.add(tar)

    def stage_array(self, name: str, arr: Any, step: int = 0) -> None:
        """Non-blocking: device->host copy + enqueue. `arr` is a jax or
        numpy array; the write itself happens on libstaging I/O threads."""
        x = np.asarray(arr)                   # device_get for jax arrays
        tar = f"{self.cfg.tar_prefix}_{name}"
        quantized = self.cfg.quantize == "int8" and x.dtype.kind == "f"
        self._ensure_tar(tar, x.shape, str(x.dtype), quantized)
        ds_name = f"{tar}__{step}"
        if quantized:
            q, scale = quantize_int8_np(x, self.cfg.quant_block)
            self.session.write(ds_name, q, dtype="int8")
            self.session.write(ds_name + "s", scale, dtype="float32")
            with self._lock:
                self._pending.append(LoadSubtar(
                    tar, ds_name, (step, 0), (1, q.size), "v"))
                self._pending.append(LoadSubtar(
                    f"{tar}__scale", ds_name + "s",
                    (step, 0), (1, scale.size), "s"))
            self.staged_bytes += q.nbytes + scale.nbytes
        else:
            self.session.write(ds_name, np.ascontiguousarray(x),
                               dtype=str(x.dtype))
            with self._lock:
                self._pending.append(LoadSubtar(
                    tar, ds_name, (step,) + (0,) * x.ndim,
                    (1,) + x.shape, "v"))
            self.staged_bytes += x.nbytes
        self.staged_arrays += 1

    def stage_tree(self, prefix: str, tree: Any, step: int = 0) -> None:
        import jax
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in flat:
            key = prefix + "".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path
            ).replace("/", "_").replace(".", "_").replace(":", "_")
            self.stage_array(key, leaf, step)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until staged data is queryable in SAVIME (sync + drain +
        pending load_subtar DDL). The hot loop never calls this; analysis
        clients / checkpoint barriers do."""
        self.session.sync(timeout)
        self.session.drain(timeout)
        with self._lock:
            pending, self._pending = self._pending, []
        seen = set()
        for q in pending:
            # replay-after-restore stages the same step twice: the dataset
            # name is the idempotency token — run its DDL once
            if q in seen:
                continue
            seen.add(q)
            self.session.run_savime(q)

    def close(self) -> None:
        try:
            self.flush()
        finally:
            self.session.close()
