"""Block planner — the paper's central knob (RDMA block size, Fig 3/4).

Also provides the analytic transfer-cost model used for §Perf napkin math
and property tests: elapsed(nbytes, block) should fall monotonically with
block size (paper claim C1) because the per-block costs (registration RTT +
on-demand memory registration) amortize.

On TPU the same knob becomes the Pallas BlockSpec tile of the egress pack
kernel — `vmem_tile` aligns a block to (sublane, lane) = (8·dtype, 128)
multiples so the MXU/VPU see hardware-aligned shapes.
"""
from __future__ import annotations

import dataclasses
import math


def plan_blocks(nbytes: int, block_size: int) -> list[tuple[int, int]]:
    """[(offset, size)] covering nbytes; last block may be short."""
    if nbytes < 0 or block_size <= 0:
        raise ValueError((nbytes, block_size))
    if nbytes == 0:
        return []
    return [(off, min(block_size, nbytes - off))
            for off in range(0, nbytes, block_size)]


def vmem_tile(block_elems: int, dtype_bytes: int, lane: int = 128,
              sublane_bytes: int = 32) -> tuple[int, int]:
    """(rows, 128) tile whose footprint ≲ block_elems elements, rows a
    multiple of the dtype's sublane packing (32 bytes / dtype size)."""
    sublane = max(sublane_bytes // dtype_bytes, 1)
    rows = max(block_elems // lane, sublane)
    rows -= rows % sublane
    return (max(rows, sublane), lane)


@dataclasses.dataclass(frozen=True)
class TransferCostModel:
    """elapsed = n_blocks·(rtt + reg_fixed) + nbytes·(1/bw + reg_per_byte)

    rtt:          control round-trip per block grant (s)
    reg_fixed:    fixed on-demand registration cost per block (s)
    reg_per_byte: page-pinning cost per byte (s/B)
    bw:           link bandwidth (B/s)
    """
    rtt: float = 50e-6
    reg_fixed: float = 20e-6
    reg_per_byte: float = 1 / (30e9)
    bw: float = 12.5e9          # ~100 Gb/s Infiniband-ish

    def predict(self, nbytes: int, block_size: int) -> float:
        n_blocks = max(1, math.ceil(nbytes / block_size))
        return (n_blocks * (self.rtt + self.reg_fixed)
                + nbytes * (1.0 / self.bw + self.reg_per_byte))

    def best_block(self, nbytes: int, candidates: list[int]) -> int:
        return min(candidates, key=lambda b: self.predict(nbytes, b))
