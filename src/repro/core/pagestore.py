"""Paged staging store — page-table allocator, LRU spill tier, dedup.

The staging area used to reserve one flat tmpfs region per dataset, so a
single slow SAVIME hop (or one jumbo dataset) pushed the global memory
watermark up and squeezed ``_credit_grant`` for every producer at once.
This module rebuilds that substrate the way a kv-cache page table builds
device memory (DESIGN.md §11):

  * **page-table allocator** — one tmpfs *arena* file carved into
    fixed-size page frames (default 64 KiB).  A dataset is a
    :class:`PageTable`: an ordered list of physical pages, possibly
    non-contiguous in the arena.  Clients still reach frames with
    one-sided mmap writes — the arena is the registered memory region,
    the page table is the address translation.
  * **LRU spill tier** — *sealed* (fully received) pages are evictable:
    when the free list runs dry, the coldest unpinned sealed pages are
    written to per-page files under ``spill_dir`` and their frames
    reused.  ``read`` pulls spilled pages back on access; the forward
    path gathers them straight from disk (a streaming read) without
    displacing hot pages.  Unsealed pages (mid-ingest, possibly being
    written one-sided by a client) and pinned pages (mid-forward) never
    move.
  * **content-addressed dedup** — at seal time each page's content is
    hashed (BLAKE2b-128 over the used bytes); a page whose digest is
    already resident drops its frame and refcounts the existing physical
    page.  Checkpoint streams and iterative outputs that repeat most of
    their bytes cost one copy; a shared page is freed only when its last
    referencing dataset releases it.

Credit grants derive from *available pages* — free frames plus sealed
evictable ones — so small datasets keep flowing while a big cold one
spills, instead of every producer stalling on one global watermark.
"""
from __future__ import annotations

import collections
import hashlib
import mmap
import os
import secrets
import threading
from typing import Optional

import numpy as np

DEFAULT_PAGE_BYTES = 64 << 10


class PageStoreFull(MemoryError):
    """No frame can be freed (every resident page is unsealed or pinned).
    Callers fall back to the flat disk tier."""


class _PhysPage:
    """One physical page: an arena frame, or a spill file when cold."""

    __slots__ = ("frame", "spill_path", "used", "refs", "pins", "digest",
                 "sealed")

    def __init__(self, frame: int, used: int):
        self.frame: Optional[int] = frame   # arena frame idx; None = spilled
        self.spill_path: Optional[str] = None
        self.used = used                    # bytes of this page in use
        self.refs = 1                       # page tables referencing it
        self.pins = 0                       # readers forbidding eviction
        self.digest: Optional[tuple] = None  # dedup key once sealed
        self.sealed = False

    @property
    def resident(self) -> bool:
        return self.frame is not None


class PageTable:
    """Per-dataset page list (ordered; pages may be shared via dedup)."""

    __slots__ = ("table_id", "nbytes", "pages", "sealed", "freed")

    def __init__(self, table_id: str, nbytes: int, pages: list):
        self.table_id = table_id
        self.nbytes = nbytes
        self.pages: list[_PhysPage] = pages
        self.sealed = False
        self.freed = False

    @property
    def n_pages(self) -> int:
        return len(self.pages)


class PageStore:
    """Fixed-frame arena + page tables + spill tier + dedup index.

    Thread-safe: one lock guards the free list, LRU, dedup index and
    counters.  Views handed out by :meth:`segments` outlive the lock —
    that is safe because only *sealed unpinned* pages can be evicted, and
    segments are only used while a page is unsealed (ingest) or pinned
    (forward).
    """

    _GUARDED_BY = {
        "_free": "_lock",
        "_lru": "_lock",
        "_n_evictable": "_lock",
        "_by_digest": "_lock",
        "_spill_files": "_lock",
        "_seq": "_lock",
        "_closed": "_lock",
        "counters": "_lock",
        "_mm": "_lock",
        "_view": "_lock",
    }

    def __init__(self, capacity: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 mem_dir: str = "/dev/shm", spill_dir: str = "/tmp",
                 dedup: bool = False):
        if page_bytes < 1:
            raise ValueError(f"page_bytes must be >= 1, got {page_bytes}")
        self.page_bytes = page_bytes
        self.n_frames = max(1, capacity // page_bytes)
        self.dedup = dedup
        os.makedirs(mem_dir, exist_ok=True)
        os.makedirs(spill_dir, exist_ok=True)
        self.spill_dir = spill_dir
        self.arena_bytes = self.n_frames * page_bytes
        self.arena_path = os.path.join(
            mem_dir, f"arena-{os.getpid()}-{secrets.token_hex(3)}")
        self._fd = os.open(self.arena_path, os.O_RDWR | os.O_CREAT, 0o600)
        os.ftruncate(self._fd, self.arena_bytes)
        self._mm = mmap.mmap(self._fd, self.arena_bytes)
        self._view = np.frombuffer(self._mm, dtype=np.uint8)
        self._lock = threading.Lock()
        self._free: list[int] = list(range(self.n_frames - 1, -1, -1))
        # LRU of sealed+resident pages, oldest first; pinned entries stay
        # in the dict but are skipped by eviction (and not counted
        # evictable)
        self._lru: "collections.OrderedDict[_PhysPage, None]" = \
            collections.OrderedDict()
        self._n_evictable = 0
        self._by_digest: dict[tuple, _PhysPage] = {}
        self._spill_files: dict[str, int] = {}   # path -> live bytes
        self._seq = 0
        self._closed = False
        self.counters = {
            "page_bytes": page_bytes, "pages_total": self.n_frames,
            "spill_outs": 0, "spill_ins": 0,
            "spill_bytes_out": 0, "spill_bytes_in": 0,
            "dedup_hits": 0, "dedup_saved_bytes": 0,
            "peak_mem_used": 0,
        }

    # -- allocation ------------------------------------------------------
    def alloc(self, nbytes: int) -> PageTable:
        """Allocate frames for ``nbytes`` (spilling cold pages to make
        room).  Raises :class:`PageStoreFull` when the demand cannot be
        met even after spilling everything evictable."""
        n = -(-nbytes // self.page_bytes) if nbytes else 0
        with self._lock:
            if n > self.n_frames:
                raise PageStoreFull(
                    f"{n} pages wanted, store holds {self.n_frames}")
            self._reclaim(n)
            pages = []
            for i in range(n):
                used = self.page_bytes if i < n - 1 \
                    else nbytes - (n - 1) * self.page_bytes
                pages.append(_PhysPage(self._free.pop(), used))
            self._seq += 1
            table = PageTable(f"t{self._seq}", nbytes, pages)
            self.counters["peak_mem_used"] = max(
                self.counters["peak_mem_used"],
                (self.n_frames - len(self._free)) * self.page_bytes)
        return table

    def _reclaim(self, n: int) -> None:  # holds: self._lock
        """Evict cold sealed pages until >= n frames are free (locked)."""
        while len(self._free) < n:
            victim = next((p for p in self._lru if p.pins == 0), None)
            if victim is None:
                raise PageStoreFull(
                    f"need {n} pages, {len(self._free)} free and nothing "
                    "evictable (all resident pages unsealed or pinned)")
            self._evict(victim)

    def _evict(self, phys: _PhysPage) -> None:  # holds: self._lock
        path = os.path.join(
            self.spill_dir, f"page-{os.getpid()}-{id(phys):x}")
        base = phys.frame * self.page_bytes
        with open(path, "wb") as f:
            f.write(self._mm[base:base + phys.used])
        phys.spill_path = path
        self._spill_files[path] = phys.used
        self._free.append(phys.frame)
        phys.frame = None
        self._lru_remove(phys)
        self.counters["spill_outs"] += 1
        self.counters["spill_bytes_out"] += phys.used

    def _promote(self, phys: _PhysPage) -> None:  # holds: self._lock
        """Pull one spilled page back into a frame (locked)."""
        self._reclaim(1)
        frame = self._free.pop()
        base = frame * self.page_bytes
        with open(phys.spill_path, "rb") as f:
            data = f.read(phys.used)
        self._mm[base:base + phys.used] = data
        os.unlink(phys.spill_path)
        self._spill_files.pop(phys.spill_path, None)
        phys.spill_path = None
        phys.frame = frame
        self._lru_insert(phys)
        self.counters["spill_ins"] += 1
        self.counters["spill_bytes_in"] += phys.used
        self.counters["peak_mem_used"] = max(
            self.counters["peak_mem_used"],
            (self.n_frames - len(self._free)) * self.page_bytes)

    # -- LRU bookkeeping (locked) ---------------------------------------
    def _lru_insert(self, phys: _PhysPage) -> None:  # holds: self._lock
        if phys not in self._lru:
            self._lru[phys] = None
            if phys.pins == 0:
                self._n_evictable += 1

    def _lru_remove(self, phys: _PhysPage) -> None:  # holds: self._lock
        if phys in self._lru:
            del self._lru[phys]
            if phys.pins == 0:
                self._n_evictable = max(0, self._n_evictable - 1)

    def _touch(self, phys: _PhysPage) -> None:  # holds: self._lock
        if phys in self._lru:
            self._lru.move_to_end(phys)

    # -- lifecycle of a table -------------------------------------------
    def seal(self, table: PageTable) -> None:
        """Dataset fully received: its pages become evictable, and (with
        dedup on) content-identical pages collapse onto one copy."""
        with self._lock:
            if table.sealed or table.freed:
                return
            table.sealed = True
            for i, phys in enumerate(table.pages):
                if phys.sealed:        # already-shared page (intra-table)
                    continue
                phys.sealed = True
                if self.dedup:
                    base = phys.frame * self.page_bytes
                    dg = hashlib.blake2b(
                        self._mm[base:base + phys.used],
                        digest_size=16).digest()
                    key = (dg, phys.used)
                    existing = self._by_digest.get(key)
                    if existing is not None and existing is not phys \
                            and existing.refs > 0:
                        existing.refs += 1
                        self._free.append(phys.frame)
                        phys.frame = None
                        phys.refs = 0
                        table.pages[i] = existing
                        self._touch(existing)
                        self.counters["dedup_hits"] += 1
                        self.counters["dedup_saved_bytes"] += phys.used
                        continue
                    phys.digest = key
                    self._by_digest[key] = phys
                self._lru_insert(phys)

    def free(self, table: PageTable) -> None:
        """Release one table's reference on every page; frames and spill
        files of pages nobody references anymore are reclaimed."""
        with self._lock:
            if table.freed:
                return
            table.freed = True
            for phys in table.pages:
                phys.refs -= 1
                if phys.refs > 0:
                    continue
                if phys.resident:
                    self._free.append(phys.frame)
                    phys.frame = None
                elif phys.spill_path:
                    try:
                        os.unlink(phys.spill_path)
                    except OSError:
                        pass
                    self._spill_files.pop(phys.spill_path, None)
                    phys.spill_path = None
                self._lru_remove(phys)
                if phys.digest is not None:
                    self._by_digest.pop(phys.digest, None)
            table.pages = []

    def pin(self, table: PageTable) -> None:
        """Forbid eviction of this table's pages (forward in progress)."""
        with self._lock:
            for phys in table.pages:
                phys.pins += 1
                if phys.pins == 1 and phys in self._lru:
                    self._n_evictable = max(0, self._n_evictable - 1)

    def unpin(self, table: PageTable) -> None:
        with self._lock:
            for phys in table.pages:
                phys.pins -= 1
                if phys.pins == 0 and phys in self._lru:
                    self._n_evictable += 1

    # -- data access -----------------------------------------------------
    def _span(self, table: PageTable, offset: int, size: int):  # holds: self._lock
        """Yield (phys, in-page offset, length) covering [offset, offset+size)."""
        if offset < 0 or offset + size > table.nbytes:
            raise ValueError(f"range [{offset},{offset + size}) outside "
                             f"table [0,{table.nbytes})")
        while size > 0:
            idx, in_off = divmod(offset, self.page_bytes)
            phys = table.pages[idx]
            n = min(phys.used - in_off, size)
            yield phys, in_off, n
            offset += n
            size -= n

    def segments(self, table: PageTable, offset: int = 0,
                 size: Optional[int] = None) -> list[np.ndarray]:
        """Writable views over the resident pages covering a byte range
        (the gather/scatter targets for ingest ``recv_into``).  Only
        valid for ranges whose pages are resident — i.e. unsealed
        (mid-ingest) or pinned pages."""
        if size is None:
            size = table.nbytes - offset
        out = []
        with self._lock:
            for phys, in_off, n in self._span(table, offset, size):
                if not phys.resident:
                    raise PageStoreFull(
                        "segments() over a spilled page — pin or read() "
                        "to pull it back first")
                base = phys.frame * self.page_bytes + in_off
                out.append(self._view[base:base + n])
        return out

    def page_views(self, table: PageTable) -> list:
        """Per-page gather list for the forward path: arena views for
        resident pages, file *bytes* for spilled ones (streamed from
        disk without displacing hot pages).  Pin the table first."""
        out = []
        with self._lock:
            for phys in table.pages:
                if phys.resident:
                    base = phys.frame * self.page_bytes
                    out.append(self._view[base:base + phys.used])
                else:
                    with open(phys.spill_path, "rb") as f:
                        out.append(f.read(phys.used))
        return out

    def read(self, table: PageTable, offset: int = 0,
             size: Optional[int] = None) -> bytearray:
        """Gather a byte range, pulling spilled pages back on access
        (LRU promote).  Falls back to a direct disk read when nothing
        can be evicted to make room."""
        if size is None:
            size = table.nbytes - offset
        out = bytearray(size)
        pos = 0
        with self._lock:
            for phys, in_off, n in self._span(table, offset, size):
                if not phys.resident:
                    try:
                        self._promote(phys)
                    except PageStoreFull:
                        with open(phys.spill_path, "rb") as f:
                            f.seek(in_off)
                            out[pos:pos + n] = f.read(n)
                        pos += n
                        continue
                self._touch(phys)
                base = phys.frame * self.page_bytes + in_off
                out[pos:pos + n] = self._mm[base:base + n]
                pos += n
        return out

    def write(self, table: PageTable, offset: int, data) -> int:
        """Scatter bytes into a table (server-local producers, tests)."""
        src = np.frombuffer(data, dtype=np.uint8) \
            if not isinstance(data, np.ndarray) \
            else data.reshape(-1).view(np.uint8)
        pos = 0
        for seg in self.segments(table, offset, src.size):
            n = len(seg)
            np.copyto(seg, src[pos:pos + n])
            pos += n
        return src.size

    def frame_offsets(self, table: PageTable) -> list[int]:
        """Arena byte offset of each page (the translation table shipped
        to one-sided writers).  Valid while the table is unsealed: those
        pages are pinned by construction (never evicted)."""
        with self._lock:
            offs = []
            for phys in table.pages:
                if not phys.resident:
                    raise PageStoreFull("frame_offsets of a spilled page")
                offs.append(phys.frame * self.page_bytes)
            return offs

    # -- introspection ---------------------------------------------------
    def available_pages(self) -> int:
        """Frames free now plus frames reclaimable by spilling — what
        credit grants derive from (a big sealed backlog does not starve
        small producers: it can always be spilled)."""
        with self._lock:
            return len(self._free) + self._n_evictable

    def available_fraction(self) -> float:
        return self.available_pages() / self.n_frames

    def stats(self) -> dict:
        with self._lock:
            mem_used = (self.n_frames - len(self._free)) * self.page_bytes
            return dict(self.counters,
                        pages_free=len(self._free),
                        pages_evictable=self._n_evictable,
                        pages_spilled=len(self._spill_files),
                        spill_used=sum(self._spill_files.values()),
                        mem_used=mem_used,
                        dedup_pages=len(self._by_digest))

    def close(self) -> None:
        """Release the arena and every live spill file."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            spills = list(self._spill_files)
            self._spill_files.clear()
            self._view = None
            try:
                self._mm.close()
            except BufferError:
                pass    # an exported view dies with its last holder
            os.close(self._fd)
            try:
                os.unlink(self.arena_path)
            except OSError:
                pass
        for path in spills:
            try:
                os.unlink(path)
            except OSError:
                pass
