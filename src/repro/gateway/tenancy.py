"""Tenancy: token auth, byte/dataset quotas, usage accounting.

A multi-tenant gateway must answer three questions before any byte is
admitted: *who is this* (token -> :class:`Tenant`), *may they write
this* (:meth:`TenantRegistry.charge` against byte/dataset quotas), and
*what have they used* (:meth:`TenantRegistry.snapshot`).  The registry
is the single synchronized authority for all three; the gateway calls it
on every admission path (``admit``/``admit_batch`` for redirect-capable
clients, proxied ``write_req``/``stripe_open``/``batch_open`` for
legacy ones).

Quota rejections are *typed* on the wire: the error reply carries a
``code`` field (``quota_exceeded`` / ``auth_failed``) that clients map
back to :class:`QuotaExceededError` / :class:`AuthError`, so a tenant
over budget gets a catchable, actionable exception instead of a generic
``RuntimeError`` — and other tenants' traffic is untouched.

Usage counts *admitted ingress* (cumulative bytes/datasets accepted),
not live staging occupancy: occupancy is the credit machinery's job
(see ``server.py``); quotas are the billing-shaped budget knob.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Optional, Union

DEFAULT_TENANT = "default"

CODE_QUOTA = "quota_exceeded"
CODE_AUTH = "auth_failed"


class QuotaExceededError(RuntimeError):
    """Typed rejection: the write would take the tenant over quota."""

    code = CODE_QUOTA

    def __init__(self, message: str, tenant: str = ""):
        super().__init__(message)
        self.tenant = tenant


class AuthError(RuntimeError):
    """Typed rejection: unknown/missing token on an authenticated pool."""

    code = CODE_AUTH


def error_reply(exc: BaseException) -> dict:
    """Wire form of a (possibly typed) rejection.  Untyped exceptions
    still get a ``code`` ("error") so every error reply is taggable."""
    code = getattr(exc, "code", None) or "error"
    return {"ok": False, "error": str(exc), "code": code}


def error_from_reply(h: dict, prefix: str = "staging error") -> Exception:
    """Client side: rebuild the typed exception from an error reply."""
    msg = f"{prefix}: {h.get('error')}"
    code = h.get("code")
    if code == CODE_QUOTA:
        return QuotaExceededError(msg, tenant=h.get("tenant", ""))
    if code == CODE_AUTH:
        return AuthError(msg)
    return RuntimeError(msg)


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One tenant: identity, credential, budget (None = unlimited)."""

    name: str
    token: Optional[str] = None
    quota_bytes: Optional[int] = None
    quota_datasets: Optional[int] = None


class TenantRegistry:
    """Synchronized auth + quota + usage authority for one gateway.

    ``require_auth=False`` (the default) keeps single-tenant deployments
    zero-config: requests without a token run as the ``default`` tenant
    (optionally budgeted via ``default_quota_bytes``). With
    ``require_auth=True`` a missing/unknown token is an
    :class:`AuthError` — the hardened multi-tenant posture.
    """

    _GUARDED_BY = {
        "_tenants": "_lock",
        "_by_token": "_lock",
        "_usage": "_lock",
    }

    def __init__(self, tenants: Iterable[Tenant] = (), *,
                 default_quota_bytes: Optional[int] = None,
                 require_auth: bool = False):
        self.require_auth = require_auth
        self._lock = threading.Lock()
        self._tenants: dict[str, Tenant] = {}
        self._by_token: dict[str, Tenant] = {}
        self._usage: dict[str, dict] = {}
        if not require_auth:
            self.register(Tenant(DEFAULT_TENANT,
                                 quota_bytes=default_quota_bytes))
        for t in tenants:
            self.register(t)

    def register(self, tenant: Tenant) -> Tenant:
        with self._lock:
            self._tenants[tenant.name] = tenant
            if tenant.token:
                self._by_token[tenant.token] = tenant
            self._usage.setdefault(tenant.name,
                                   {"bytes": 0, "datasets": 0, "rejects": 0})
        return tenant

    def authenticate(self, token: Optional[str]) -> Tenant:
        """Token -> tenant. Bare tenant *names* are also accepted when
        the tenant has no token (convenience for trusted pools)."""
        with self._lock:
            if token:
                t = self._by_token.get(token)
                if t is None:
                    t = self._tenants.get(token)
                    if t is not None and t.token:
                        t = None      # named tenant requires its token
                if t is None:
                    raise AuthError(f"unknown tenant token {token!r}")
                return t
            if self.require_auth:
                raise AuthError("this gateway requires a tenant token")
            return self._tenants[DEFAULT_TENANT]

    def charge(self, tenant: Union[Tenant, str], nbytes: int,
               datasets: int = 1) -> None:
        """Admit ``datasets`` totalling ``nbytes`` against the tenant's
        budget — all-or-nothing, so a multi-item batch never lands half
        inside quota."""
        name = tenant.name if isinstance(tenant, Tenant) else tenant
        with self._lock:
            t = self._tenants.get(name)
            if t is None:
                raise AuthError(f"unknown tenant {name!r}")
            u = self._usage[name]
            if t.quota_bytes is not None and \
                    u["bytes"] + nbytes > t.quota_bytes:
                u["rejects"] += 1
                raise QuotaExceededError(
                    f"tenant {name!r} byte quota exceeded: "
                    f"{u['bytes']} + {nbytes} > {t.quota_bytes}",
                    tenant=name)
            if t.quota_datasets is not None and \
                    u["datasets"] + datasets > t.quota_datasets:
                u["rejects"] += 1
                raise QuotaExceededError(
                    f"tenant {name!r} dataset quota exceeded: "
                    f"{u['datasets']} + {datasets} > {t.quota_datasets}",
                    tenant=name)
            u["bytes"] += nbytes
            u["datasets"] += datasets

    def usage(self, name: str) -> dict:
        with self._lock:
            return dict(self._usage[name])

    def snapshot(self) -> dict:
        """Per-tenant usage + budget, JSON-safe (the gateway ``stats``
        surface and the launcher's accounting printout)."""
        with self._lock:
            out = {}
            for name, t in self._tenants.items():
                u = self._usage[name]
                out[name] = {**u, "quota_bytes": t.quota_bytes,
                             "quota_datasets": t.quota_datasets}
            return out
