"""GatewayServer — one address fronting a pool of StagingServers.

The gateway owns four jobs (DESIGN.md §12):

  * **placement** — every dataset maps to one backend through the
    consistent-hash ring (:mod:`repro.gateway.ring`); membership changes
    (a backend failing health probes, or rejoining) rebuild the ring and
    bump its epoch.
  * **admission** — every ingress path authenticates + charges the
    tenant registry first (:mod:`repro.gateway.tenancy`); quota/auth
    failures are *typed* error replies, and per-backend admitted
    byte/dataset counters feed the accounting-parity check
    (gateway totals == Σ backend ``bytes_in``).
  * **redirect vs proxy** — gateway-aware clients call ``admit`` /
    ``admit_batch`` and ship data straight to the returned backend
    (one control RTT, the one-sided RDMA plane untouched); legacy
    clients speak the unmodified staging wire protocol (JSON *and*
    bin1 — ``hello`` negotiation is answered in kind) and the gateway
    resolves placement per ``write_req``/``stripe_open``/``batch_open``
    and relays the data ops. Even proxied block writes stay one-sided:
    the relayed reservation reply carries the backend's region path, so
    a client sharing the emulated-RDMA fabric mmaps the backend region
    directly and only control frames cross the gateway.
  * **fleet-wide backpressure** — health probes sample each backend's
    ``free_fraction`` (its ``_credit_grant`` pressure signal); every
    credit grant relayed to a client is capped by the *worst* live
    backend's fraction, so one staging server drowning throttles the
    whole pool's producers through the existing credit machinery.

The analytical side is symmetric: ``run_savime`` parses the operator
and routes it through :func:`repro.gateway.router.route_query` — DDL
fans out, ``load_subtar`` follows the dataset's recorded placement,
reads scatter-gather-merge — so an ``AnalysisSession(via=...)`` riding
a gateway-backed transport sees one coherent engine.
"""
from __future__ import annotations

import collections
import math
import socket
import threading
import time
from typing import Iterable, Optional

from repro.core import wire
from repro.core.savime import SavimeClient, _parse_call
from repro.gateway.ring import HashRing, RingNode
from repro.gateway.router import route_query
from repro.gateway.tenancy import (AuthError, QuotaExceededError, Tenant,
                                   TenantRegistry, error_reply)

# wanted-credit guess when a relayed ack has no stripe_open context
DEFAULT_WANTED = 8

# (name, epoch) admit-log bound: replay identities older than the last
# this-many admits can no longer dedup (matches the staging server's cap)
_ADMIT_LOG_CAP = 4096


class Backend:
    """Gateway-side view of one staging backend."""

    def __init__(self, node: RingNode):
        self.node = node
        self.alive = True
        self.fails = 0
        self.free_fraction = 1.0       # last probed pressure signal
        self.last_stats: dict = {}     # last probed server stats snapshot
        self.admitted_bytes = 0        # accounting-parity counters
        self.admitted_datasets = 0

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def addr(self) -> str:
        return self.node.addr

    @property
    def savime_addr(self) -> str:
        return self.node.savime_addr


class GatewayServer:
    """TCP front-end multiplexing the staging wire protocol over a pool."""

    # ``stats`` is deliberately unguarded: plain int-counter bumps under
    # the GIL, read only by the stats op (monitoring tolerates torn reads).
    _GUARDED_BY = {
        "ring": "_lock",
        "_file_map": "_lock",
        "_ds_map": "_lock",
        "_admit_log": "_lock",
        "_threads": "_threads_lock",
        "_conns": "_conn_lock",
    }

    def __init__(self, nodes: Iterable[RingNode], host: str = "127.0.0.1",
                 port: int = 0, *, tenants: Iterable[Tenant] = (),
                 default_quota_bytes: Optional[int] = None,
                 require_auth: bool = False, vnodes: int = 64,
                 health_interval: float = 0.25, probe_fails: int = 2):
        nodes = list(nodes)
        if not nodes:
            raise ValueError("gateway needs at least one backend node")
        self.backends: dict[str, Backend] = {
            n.name: Backend(n) for n in nodes}
        if len(self.backends) != len(nodes):
            raise ValueError("duplicate backend names")
        self.vnodes = vnodes
        self.tenants = TenantRegistry(
            tenants, default_quota_bytes=default_quota_bytes,
            require_auth=require_auth)
        self.health_interval = health_interval
        self.probe_fails = max(1, probe_fails)
        # _lock guards: ring swaps, backend liveness/accounting, the
        # dataset/file placement maps
        self._lock = threading.Lock()
        self.ring = HashRing([b.node for b in self.backends.values()],
                             vnodes)
        self._file_map: dict[str, tuple[str, int]] = {}  # fid -> (backend, wanted)
        self._ds_map: dict[str, str] = {}                # dataset -> backend
        # (name, epoch) -> (backend, size): replay identities already
        # admitted, so a client retry is not double-charged (DESIGN.md §15)
        self._admit_log: collections.OrderedDict = collections.OrderedDict()
        self.stats = {"conns": 0, "admits": 0, "rejects": 0,
                      "redirected_bytes": 0, "proxied_ops": 0,
                      "proxied_bytes": 0, "queries": 0, "readmits": 0,
                      "remaps": 0, "rejoins": 0, "ring_fetches": 0}
        self._savime_local = threading.local()
        self._probe_socks: dict[str, socket.socket] = {}

        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(128)
        self.addr = f"{host}:{self._srv.getsockname()[1]}"
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._threads_lock = threading.Lock()
        self._conns: set[socket.socket] = set()
        self._conn_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None
        self._health_thread: Optional[threading.Thread] = None

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "GatewayServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="gateway-accept", daemon=True)
        self._accept_thread.start()
        self._health_thread = threading.Thread(
            target=self._health_loop, name="gateway-health", daemon=True)
        self._health_thread.start()
        return self

    def stop(self, join_timeout: float = 2.0) -> None:
        self._stop.set()
        try:
            self._srv.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._srv.close()
        except OSError:
            pass
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(join_timeout)
        if self._health_thread is not None:
            self._health_thread.join(join_timeout + self.health_interval)
        deadline = time.monotonic() + join_timeout
        with self._threads_lock:
            threads = list(self._threads)
        for t in threads:
            t.join(max(deadline - time.monotonic(), 0.0))
        with self._threads_lock:
            self._threads = [t for t in self._threads if t.is_alive()]
        for s in self._probe_socks.values():
            try:
                s.close()
            except OSError:
                pass
        self._probe_socks.clear()

    def live_threads(self) -> int:
        with self._threads_lock:
            return sum(t.is_alive() for t in self._threads)

    # -- ring / placement -----------------------------------------------
    def _rebuild_ring(self) -> None:  # holds: self._lock
        """Swap in a ring over the currently-live backends (caller holds
        ``_lock``)."""
        live = [b.node for b in self.backends.values() if b.alive]
        self.ring = HashRing(live, self.vnodes)

    @property
    def epoch(self) -> str:
        with self._lock:
            return self.ring.epoch

    def _place(self, name: str) -> Backend:
        with self._lock:
            if not len(self.ring):
                raise RuntimeError("no live staging backends")
            return self.backends[self.ring.place(name).name]

    # -- health / fleet pressure ----------------------------------------
    def _health_loop(self) -> None:
        while not self._stop.wait(self.health_interval):
            for b in list(self.backends.values()):
                if self._stop.is_set():
                    return
                self._probe(b)

    def _probe(self, b: Backend) -> None:
        try:
            sock = self._probe_socks.get(b.name)
            if sock is None:
                sock = wire.connect(b.addr, timeout=2.0)
                sock.settimeout(2.0)
                self._probe_socks[b.name] = sock
            h, _ = wire.request(sock, {"op": "ping"})
            if not h.get("ok"):
                raise ConnectionError("ping rejected")
            s, _ = wire.request(sock, {"op": "stats"})
        except (OSError, ConnectionError, ValueError):
            old = self._probe_socks.pop(b.name, None)
            if old is not None:
                try:
                    old.close()
                except OSError:
                    pass
            with self._lock:
                b.fails += 1
                if b.fails >= self.probe_fails and b.alive:
                    b.alive = False
                    self._rebuild_ring()
                    self.stats["remaps"] += 1
            return
        frac = s.get("free_fraction")
        if frac is None:       # older backend: derive from the watermark
            cap = s.get("mem_capacity") or 0
            frac = 1.0 - s.get("mem_used", 0) / cap if cap else 1.0
        with self._lock:
            b.fails = 0
            b.free_fraction = max(0.0, min(1.0, float(frac)))
            b.last_stats = {k: v for k, v in s.items() if k != "ok"}
            if not b.alive:
                b.alive = True
                self._rebuild_ring()
                self.stats["rejoins"] += 1

    def fleet_free_fraction(self) -> float:
        """The *worst* live backend's free fraction — cluster-wide
        admission follows the most-pressured server, so the pool never
        runs hotter than its sickest member."""
        with self._lock:
            fracs = [b.free_fraction for b in self.backends.values()
                     if b.alive]
        return min(fracs) if fracs else 1.0

    def _fleet_credits(self, wanted: int, backend_grant) -> int:
        """Gateway-issued grant: the backend's own grant, additionally
        capped by fleet pressure (same shape as ``_credit_grant``:
        never 0, so a stalled window can always recover)."""
        wanted = max(1, int(wanted))
        cap = max(1, math.ceil(wanted * self.fleet_free_fraction()))
        return max(1, min(int(backend_grant), cap))

    # -- accept / serve --------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            with self._threads_lock:
                self._threads = [t for t in self._threads if t.is_alive()]
                t = threading.Thread(target=self._serve, args=(conn,),
                                     name="gateway-conn", daemon=True)
                t.start()
                self._threads.append(t)

    def _serve(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._conn_lock:
            self._conns.add(conn)
        self.stats["conns"] += 1
        # per-connection state: the authenticated tenant, one relay
        # connection per backend (JSON — the gateway never negotiates
        # bin1 with backends, so unsolicited pushes cannot desync the
        # request/reply relay), and the pending proxied batch
        state: dict = {"tenant": None, "bconns": {}, "gwbatch": None}
        pool = wire.BufferPool(max_per_bucket=2)

        def _reply(reply: dict, is_bin: bool) -> bool:
            try:
                if is_bin:
                    wire.send_frame_bin(conn, dict(reply, op="ack"))
                else:
                    wire.send_frame(conn, reply)
            except OSError:
                return False
            return True

        try:
            with conn:
                while True:
                    try:
                        h = wire.recv_header(conn)
                        is_bin = bool(h.pop("_bin", False))
                        op = h.get("op")
                        if op in ("stripe", "batch_write"):
                            # payload ops: the relay consumes the payload
                            # itself (fully buffered before forwarding, so
                            # a backend failure never desyncs the client's
                            # framing)
                            try:
                                if op == "stripe":
                                    reply = self._op_stripe_relay(
                                        conn, state, h)
                                else:
                                    reply = self._op_batch_write_relay(
                                        conn, state, h)
                            except (ConnectionError, OSError):
                                raise
                            except Exception as e:  # noqa: BLE001
                                reply = error_reply(e)
                        else:
                            payload = wire.recv_payload(conn, h, pool)
                            try:
                                reply = self._handle(state, h)
                            except (AuthError, QuotaExceededError) as e:
                                self.stats["rejects"] += 1
                                reply = error_reply(e)
                            except Exception as e:  # noqa: BLE001
                                reply = error_reply(e)
                            finally:
                                if isinstance(payload, memoryview):
                                    pool.release(payload)
                    except (ConnectionError, OSError):
                        return
                    if not _reply(reply, is_bin):
                        return
        finally:
            for bsock in state["bconns"].values():
                try:
                    bsock.close()
                except OSError:
                    pass
            with self._conn_lock:
                self._conns.discard(conn)

    # -- backend relay plumbing -----------------------------------------
    def _backend_conn(self, state: dict, bname: str) -> socket.socket:
        sock = state["bconns"].get(bname)
        if sock is None:
            sock = wire.connect(self.backends[bname].addr, timeout=10.0)
            state["bconns"][bname] = sock
        return sock

    def _forward(self, state: dict, bname: str, header: dict,
                 payload=None) -> dict:
        """One relayed request/reply; a dead backend becomes a clean
        error reply (and the cached relay conn is dropped so a rejoined
        backend gets a fresh one)."""
        try:
            sock = self._backend_conn(state, bname)
        except OSError as e:
            return {"ok": False, "code": "backend_unreachable",
                    "error": f"backend {bname!r} unreachable: {e}"}
        try:
            if isinstance(payload, (list, tuple)):
                wire.sendmsg_all(sock, wire.encode_frame(header, payload))
                rep, _ = wire.recv_frame(sock)
            else:
                rep, _ = wire.request(sock, header, payload)
            return rep
        except (OSError, ConnectionError) as e:
            state["bconns"].pop(bname, None)
            try:
                sock.close()
            except OSError:
                pass
            return {"ok": False, "code": "backend_unreachable",
                    "error": f"backend {bname!r} unreachable: {e}"}

    # -- op dispatch ------------------------------------------------------
    def _handle(self, state: dict, h: dict) -> dict:
        op = h.get("op")
        if op == "ping":
            return {"ok": True, "gateway": True}
        if op == "hello":
            if h.get("tenant"):
                state["tenant"] = self.tenants.authenticate(h["tenant"])
            # advertise the codec registry on the backends' behalf — every
            # pool member runs the same build, and redirected writers only
            # hello against the gateway (DESIGN.md §13)
            from repro import codec as codec_mod
            return dict(wire.hello_reply(h, codecs=codec_mod.available()),
                        gateway=True, epoch=self.epoch)
        if op == "ring":
            self.stats["ring_fetches"] += 1
            with self._lock:
                ring = self.ring
            return {"ok": True, "ring": ring.encode(), "epoch": ring.epoch}
        if op == "admit":
            return self._op_admit(state, h)
        if op == "admit_batch":
            return self._op_admit_batch(state, h)
        if op in ("write_req", "stripe_open"):
            return self._op_proxy_open(state, h)
        if op == "batch_open":
            return self._op_batch_open_relay(state, h)
        if op in ("reg_block", "client_sync"):
            return self._op_file_relay(state, h)
        if op == "run_savime":
            return self._op_run_savime(h)
        if op == "drain":
            return self._op_drain(state, h)
        if op == "stats":
            return self._op_stats()
        raise ValueError(f"unknown op {op!r}")

    # -- tenancy ----------------------------------------------------------
    def _auth(self, state: dict, h: dict) -> Tenant:
        token = h.get("tenant")
        if token:
            return self.tenants.authenticate(token)
        if state["tenant"] is not None:
            return state["tenant"]
        return self.tenants.authenticate(None)

    def _record_admit(self, b: Backend, name: str, size: int) -> None:
        """Caller already charged the tenant; update placement records +
        parity counters (holds ``_lock``)."""
        with self._lock:
            b.admitted_bytes += size
            b.admitted_datasets += 1
            self._ds_map[name] = b.name

    # -- redirect protocol ------------------------------------------------
    def _op_admit(self, state: dict, h: dict) -> dict:
        tenant = self._auth(state, h)
        size = int(h.get("size", 0))
        name = h["name"]
        epoch = h.get("epoch")
        b = self._place(name)
        if epoch is not None:
            rep = self._readmit(name, str(epoch), size, b)
            if rep is not None:
                return rep
        self.tenants.charge(tenant, size)
        self._record_admit(b, name, size)
        if epoch is not None:
            with self._lock:
                self._admit_log[(name, str(epoch))] = (b.name, size)
                while len(self._admit_log) > _ADMIT_LOG_CAP:
                    self._admit_log.popitem(last=False)
        self.stats["admits"] += 1
        self.stats["redirected_bytes"] += size
        return {"ok": True, "addr": b.addr, "backend": b.name,
                "epoch": self.epoch}

    def _readmit(self, name: str, epoch: str, size: int,
                 b: Backend) -> Optional[dict]:
        """Handle an admit whose (name, epoch) was already admitted — a
        journal replay after a reconnect or a backend fail-out. The
        tenant was charged the first time, so only the parity accounting
        moves: the original backend's counters are reversed and the new
        placement charged (a no-op when placement is unchanged — the
        backend itself dedups the replayed write)."""
        with self._lock:
            prev = self._admit_log.get((name, epoch))
            if prev is None:
                return None
            old_name, old_size = prev
            old_b = self.backends.get(old_name)
            if old_name != b.name:
                if old_b is not None:
                    old_b.admitted_bytes -= old_size
                    old_b.admitted_datasets -= 1
                b.admitted_bytes += size
                b.admitted_datasets += 1
            self._ds_map[name] = b.name
            self._admit_log[(name, epoch)] = (b.name, size)
        self.stats["readmits"] += 1
        return {"ok": True, "addr": b.addr, "backend": b.name,
                "dup": True, "epoch": self.epoch}

    def _op_admit_batch(self, state: dict, h: dict) -> dict:
        tenant = self._auth(state, h)
        items = h.get("items")
        if not isinstance(items, list) or not items:
            raise ValueError("admit_batch needs a non-empty items list")
        placed = [self._place(it["name"]) for it in items]
        total = sum(int(it.get("size", 0)) for it in items)
        # all-or-nothing: the whole batch fits the budget or none lands
        self.tenants.charge(tenant, total, datasets=len(items))
        for b, it in zip(placed, items):
            self._record_admit(b, it["name"], int(it.get("size", 0)))
        self.stats["admits"] += len(items)
        self.stats["redirected_bytes"] += total
        return {"ok": True, "addrs": [b.addr for b in placed],
                "backends": [b.name for b in placed], "epoch": self.epoch}

    # -- proxy protocol ---------------------------------------------------
    def _op_proxy_open(self, state: dict, h: dict) -> dict:
        """Relayed ``write_req`` / ``stripe_open``: place, charge,
        forward, remember the file_id→backend binding for the data ops
        that follow (possibly on other connections — stripes ride the
        channel sockets, not the control socket that opened them)."""
        tenant = self._auth(state, h)
        size = int(h.get("size", 0))
        b = self._place(h["name"])
        self.tenants.charge(tenant, size)
        fwd = {k: v for k, v in h.items() if k != "tenant"}
        rep = self._forward(state, b.name, fwd)
        self.stats["proxied_ops"] += 1
        if not rep.get("ok"):
            return rep
        self._record_admit(b, h["name"], size)
        wanted = max(1, int(h.get("credits", 4)))
        with self._lock:
            self._file_map[rep["file_id"]] = (b.name, wanted)
        if "credits" in rep:
            rep["credits"] = self._fleet_credits(wanted, rep["credits"])
        return rep

    def _op_file_relay(self, state: dict, h: dict) -> dict:
        """Relay an op addressed by ``file_id`` (reg_block/client_sync)."""
        with self._lock:
            ent = self._file_map.get(h.get("file_id"))
        if ent is None:
            return {"ok": False, "code": "bad_request",
                    "error": f"unknown file_id {h.get('file_id')!r}"}
        bname, _wanted = ent
        rep = self._forward(state, bname, h)
        self.stats["proxied_ops"] += 1
        if rep.get("ok") and h.get("op") == "client_sync":
            with self._lock:
                self._file_map.pop(h["file_id"], None)
        return rep

    def _op_stripe_relay(self, conn: socket.socket, state: dict,
                         h: dict) -> dict:
        """Relay one stripe. The payload (if any — one-sided stripes are
        control-only) is buffered, so client framing survives any backend
        failure; the ack's credit grant is re-capped fleet-wide."""
        nbytes = int(h.get("nbytes") or 0)
        with self._lock:
            ent = self._file_map.get(h.get("file_id"))
        if ent is None:
            wire.drain_payload(conn, h)
            return {"ok": False, "code": "bad_request",
                    "error": f"unknown file_id {h.get('file_id')!r}"}
        bname, wanted = ent
        payload = None
        if nbytes:
            payload = bytearray(nbytes)
            wire.recv_into(conn, memoryview(payload))
        rep = self._forward(state, bname, h, payload)
        self.stats["proxied_ops"] += 1
        self.stats["proxied_bytes"] += nbytes
        if "credits" in rep:
            rep["credits"] = self._fleet_credits(wanted, rep["credits"])
        if rep.get("ok") and rep.get("done"):
            with self._lock:
                self._file_map.pop(h.get("file_id"), None)
        return rep

    def _op_batch_open_relay(self, state: dict, h: dict) -> dict:
        """Partition a coalesced batch by placement and open one
        sub-batch per backend; the client's view stays a single batch."""
        tenant = self._auth(state, h)
        items = h.get("items")
        if not isinstance(items, list) or not items:
            raise ValueError("batch_open needs a non-empty items list")
        placed = [self._place(it["name"]) for it in items]
        total = sum(int(it.get("size", 0)) for it in items)
        self.tenants.charge(tenant, total, datasets=len(items))
        groups: dict[str, list[int]] = {}
        for i, b in enumerate(placed):
            groups.setdefault(b.name, []).append(i)
        replies: list = [None] * len(items)
        for bname, idxs in groups.items():
            rep = self._forward(state, bname, {
                "op": "batch_open", "items": [items[i] for i in idxs]})
            self.stats["proxied_ops"] += 1
            if not rep.get("ok"):
                # backends that already opened roll their reservations
                # back when this relay conn next batch_opens (or closes)
                # — the staging server's own abandoned-batch handling
                state["gwbatch"] = None
                return rep
            for i, item_rep in zip(idxs, rep.get("items", ())):
                replies[i] = item_rep
        for b, it in zip(placed, items):
            self._record_admit(b, it["name"], int(it.get("size", 0)))
        state["gwbatch"] = (items, sorted(groups.items()))
        return {"ok": True, "items": replies}

    def _op_batch_write_relay(self, conn: socket.socket, state: dict,
                              h: dict) -> dict:
        """Scatter one jumbo batch payload into per-backend sub-batches.

        Item payloads arrive in client batch order; they are buffered
        per item and re-vectored into one ``batch_write`` per backend,
        in the exact order that backend's ``batch_open`` declared."""
        binfo = state.get("gwbatch")
        state["gwbatch"] = None
        declared = int(h.get("nbytes") or 0)
        if binfo is None:
            wire.drain_payload(conn, h)
            return {"ok": False, "code": "bad_request", "error":
                    "batch_write without a preceding successful batch_open"}
        items, groups = binfo
        sizes = [int(it.get("size", 0)) for it in items]
        if int(h.get("count", -1)) != len(items) or sum(sizes) != declared:
            wire.drain_payload(conn, h)
            return {"ok": False, "code": "bad_request", "error":
                    f"batch_write mismatch (count={h.get('count')}, "
                    f"declared={declared} bytes)"}
        bufs: list[bytearray] = []
        for n in sizes:
            buf = bytearray(n)
            if n:
                wire.recv_into(conn, memoryview(buf))
            bufs.append(buf)
        self.stats["proxied_bytes"] += declared
        count = 0
        credits: Optional[int] = None
        for bname, idxs in groups:
            payload = [memoryview(bufs[i]) for i in idxs if sizes[i]]
            rep = self._forward(state, bname,
                                {"op": "batch_write", "count": len(idxs)},
                                payload)
            self.stats["proxied_ops"] += 1
            if not rep.get("ok"):
                return rep
            count += int(rep.get("count", len(idxs)))
            grant = self._fleet_credits(4, rep.get("credits", 4))
            credits = grant if credits is None else min(credits, grant)
        return {"ok": True, "count": count,
                "credits": credits if credits is not None else 1}

    # -- analytical routing ----------------------------------------------
    def _savime_clients(self) -> tuple[list[SavimeClient], list[str]]:
        """One analytical connection per backend, per gateway thread.

        Deliberately *not* filtered by staging liveness: a dead staging
        server's SAVIME usually survives it, and the subtars it already
        ingested must stay queryable (no lost acked datasets)."""
        cache = getattr(self._savime_local, "clis", None)
        if cache is None:
            cache = self._savime_local.clis = {}
        clis, names = [], []
        for b in self.backends.values():
            if not b.savime_addr:
                continue
            cli = cache.get(b.name)
            if cli is None:
                try:
                    cli = SavimeClient(b.savime_addr)
                except OSError:
                    continue
                cache[b.name] = cli
            clis.append(cli)
            names.append(b.name)
        return clis, names

    def _op_run_savime(self, h: dict) -> dict:
        q = h["q"]
        clis, names = self._savime_clients()
        fn, args = _parse_call(q)
        dataset = args[1] if fn == "load_subtar" and len(args) > 1 else None

        def place(ds: str) -> Optional[int]:
            with self._lock:
                bname = self._ds_map.get(ds)
            if bname is None:
                try:
                    bname = self._place(ds).name
                except RuntimeError:
                    return None
            return names.index(bname) if bname in names else None

        res = route_query(clis, q, place=place)
        if dataset is not None:
            with self._lock:
                self._ds_map.pop(dataset, None)   # consumed (move semantics)
        if hasattr(res, "tolist"):
            res = res.tolist()
        self.stats["queries"] += 1
        return {"ok": True, "result": res}

    # -- control ops ------------------------------------------------------
    def _op_drain(self, state: dict, h: dict) -> dict:
        """Fan the drain barrier to every live backend."""
        with self._lock:
            live = [b.name for b in self.backends.values() if b.alive]
        for bname in live:
            rep = self._forward(state, bname,
                                {"op": "drain", "timeout": h.get("timeout")})
            if not rep.get("ok"):
                return rep
        return {"ok": True, "backends": len(live)}

    def _op_stats(self) -> dict:
        """GatewayStats: fleet view + tenancy snapshot + parity totals."""
        with self._lock:
            ring = self.ring
            backends = {
                b.name: {"addr": b.addr, "savime_addr": b.savime_addr,
                         "weight": b.node.weight, "alive": b.alive,
                         "free_fraction": b.free_fraction,
                         "admitted_bytes": b.admitted_bytes,
                         "admitted_datasets": b.admitted_datasets,
                         "server": dict(b.last_stats)}
                for b in self.backends.values()}
            totals = {
                "admitted_bytes": sum(b.admitted_bytes
                                      for b in self.backends.values()),
                "admitted_datasets": sum(b.admitted_datasets
                                         for b in self.backends.values())}
        return {"ok": True, "gateway": True, "epoch": ring.epoch,
                "n_backends": len(backends),
                "live_backends": sum(1 for d in backends.values()
                                     if d["alive"]),
                "fleet_free_fraction": self.fleet_free_fraction(),
                "backends": backends, "totals": totals,
                "tenants": self.tenants.snapshot(), **self.stats}
