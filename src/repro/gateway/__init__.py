"""Multi-tenant staging gateway (DESIGN.md §12).

One address fronting a pool of staging servers: consistent-hash
placement (:mod:`~repro.gateway.ring`), tenancy + admission
(:mod:`~repro.gateway.tenancy`), redirect/proxy wire front
(:mod:`~repro.gateway.server`), scatter-gather analytical routing
(:mod:`~repro.gateway.router`), and the :class:`StagingPool` harness.
"""
from repro.gateway.client import GatewayClient
from repro.gateway.pool import StagingPool
from repro.gateway.ring import DEFAULT_VNODES, HashRing, RingNode
from repro.gateway.router import (MultiSubscription, RouterSession,
                                  gather_aggregate, gather_select,
                                  merge_histograms, route_query)
from repro.gateway.server import Backend, GatewayServer
from repro.gateway.tenancy import (AuthError, QuotaExceededError, Tenant,
                                   TenantRegistry, error_from_reply,
                                   error_reply)

__all__ = [
    "AuthError", "Backend", "DEFAULT_VNODES", "GatewayClient",
    "GatewayServer", "HashRing", "MultiSubscription", "QuotaExceededError",
    "RingNode", "RouterSession", "StagingPool", "Tenant", "TenantRegistry",
    "error_from_reply", "error_reply", "gather_aggregate", "gather_select",
    "merge_histograms", "route_query",
]
