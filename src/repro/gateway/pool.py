"""StagingPool — N (SAVIME, staging) pairs behind one GatewayServer.

The deployment unit for multi-tenant in-transit analysis: each backend
is a full vertical slice (its own SAVIME engine fed by its own staging
server), and the gateway is the single address producers and analysts
talk to. Placement is per dataset, so one logical TAR's subtars spread
across the pool and the gateway's scatter-gather router
(:mod:`repro.gateway.router`) reassembles query answers.

Used by the launchers (``--pool N``), the gateway tests, and
``benchmarks/fig11_gateway.py``; owns startup/shutdown ordering
(backends up before the gateway accepts, gateway down before backends).
"""
from __future__ import annotations

import contextlib
from typing import Iterable, Optional, Sequence

from repro.core.savime import SavimeServer
from repro.core.staging import StagingServer
from repro.gateway.ring import RingNode
from repro.gateway.server import GatewayServer
from repro.gateway.tenancy import Tenant


class StagingPool:
    """Start/stop harness for a gateway-fronted staging fleet."""

    def __init__(self, n_backends: int = 2, *,
                 mem_capacity: int = 1 << 30,
                 weights: Optional[Sequence[float]] = None,
                 tenants: Iterable[Tenant] = (),
                 default_quota_bytes: Optional[int] = None,
                 require_auth: bool = False,
                 vnodes: int = 64,
                 health_interval: float = 0.25,
                 staging_kwargs: Optional[dict] = None):
        if n_backends < 1:
            raise ValueError("pool needs at least one backend")
        if weights is not None and len(weights) != n_backends:
            raise ValueError("weights must match n_backends")
        self.savimes: list[SavimeServer] = []
        self.stagings: list[StagingServer] = []
        self.gateway: Optional[GatewayServer] = None
        self._n = n_backends
        self._weights = weights
        self._mem_capacity = mem_capacity
        self._tenants = tuple(tenants)
        self._default_quota_bytes = default_quota_bytes
        self._require_auth = require_auth
        self._vnodes = vnodes
        self._health_interval = health_interval
        self._staging_kwargs = dict(staging_kwargs or {})

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "StagingPool":
        try:
            for i in range(self._n):
                sv = SavimeServer().start()
                st = StagingServer(sv.addr, mem_capacity=self._mem_capacity,
                                   **self._staging_kwargs).start()
                self.savimes.append(sv)
                self.stagings.append(st)
            nodes = [RingNode(name=f"backend{i}", addr=st.addr,
                              savime_addr=sv.addr,
                              weight=(self._weights[i]
                                      if self._weights else 1.0))
                     for i, (sv, st) in enumerate(zip(self.savimes,
                                                      self.stagings))]
            self.gateway = GatewayServer(
                nodes, tenants=self._tenants,
                default_quota_bytes=self._default_quota_bytes,
                require_auth=self._require_auth, vnodes=self._vnodes,
                health_interval=self._health_interval).start()
        except BaseException:
            self.stop()
            raise
        return self

    def stop(self) -> None:
        if self.gateway is not None:
            self.gateway.stop()
            self.gateway = None
        for st in self.stagings:
            st.stop()
        for sv in self.savimes:
            sv.stop()
        self.stagings.clear()
        self.savimes.clear()

    def __enter__(self) -> "StagingPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- convenience ----------------------------------------------------
    @property
    def addr(self) -> str:
        """The gateway address — the only address clients need."""
        if self.gateway is None:
            raise RuntimeError("pool is not running")
        return self.gateway.addr

    @property
    def savime_addrs(self) -> list[str]:
        return [sv.addr for sv in self.savimes]

    def backend_stats(self) -> dict:
        """In-process view of per-backend staging counters (the
        accounting-parity side the gateway's ``totals`` must match)."""
        return {f"backend{i}": dict(st.stats)
                for i, st in enumerate(self.stagings)}

    def kill_backend(self, i: int) -> None:
        """Hard-stop one staging server (its SAVIME stays up — already
        acked datasets must remain queryable); health probes will fail
        it out of the ring."""
        self.stagings[i].stop()

    # -- fault harness ---------------------------------------------------
    @contextlib.contextmanager
    def with_faults(self, plan):
        """Run a :class:`~repro.faults.FaultPlan` against this pool.

            with pool.with_faults(FaultPlan.parse(spec)) as harness:
                ... drive traffic; harness.injector.fired /
                    harness.scheduler.killed tell what happened ...

        Wire rules apply only to client connections targeting the pool
        (gateway + backend staging addrs); kill rules resolve
        ``staging:i`` / ``savime:i`` / ``gateway`` targets to this
        pool's processes. Install/uninstall is scoped to the block.
        """
        from repro.faults.inject import injected
        from repro.faults.sched import FaultScheduler
        if self.gateway is None:
            raise RuntimeError("pool is not running")
        scope = [self.addr] + [st.addr for st in self.stagings] \
            + [sv.addr for sv in self.savimes]
        targets = {"gateway": self.gateway.stop}
        for i, st in enumerate(self.stagings):
            targets[f"staging:{i}"] = st.stop
        for i, sv in enumerate(self.savimes):
            targets[f"savime:{i}"] = sv.stop
        with injected(plan, scope=scope) as inj:
            sched = FaultScheduler(plan, targets).start()
            harness = _FaultHarness(inj, sched)
            try:
                yield harness
            finally:
                sched.stop()


class _FaultHarness:
    """What ``with_faults`` yields: both halves of the running harness."""

    def __init__(self, injector, scheduler):
        self.injector = injector
        self.scheduler = scheduler
