"""GatewayClient — the redirect-capable client's view of the gateway.

A gateway-aware producer keeps exactly one control connection to the
gateway and sends every *data* byte straight to the backend the gateway
admits it to — the redirect protocol (DESIGN.md §12). Per dataset that
costs one ``admit`` round-trip (auth + quota + placement) and preserves
the one-sided RDMA data plane end-to-end: the backend's ``write_req`` /
``stripe_open`` replies still carry a locally-mappable region path, so
payload bytes never traverse the gateway.

The client also caches the placement ring locally. Placement is pure
(BLAKE2b; see :mod:`repro.gateway.ring`), so the cached ring predicts
the gateway's decisions for free — the Coalescer uses it to pre-group
small datasets — while the authoritative answer remains the gateway's
``admit`` reply. Every admit carries the current ring ``epoch``; an
epoch mismatch (a backend joined/left) refreshes the cache.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

from repro.core import wire
from repro.core.retry import RetryPolicy
from repro.gateway.ring import HashRing, RingNode
from repro.gateway.tenancy import error_from_reply


class GatewayClient:
    """One locked control connection + a cached placement ring."""

    def __init__(self, addr: str, tenant: Optional[str] = None, *,
                 retry: Optional[RetryPolicy] = None):
        self.addr = addr
        self.tenant = tenant
        self._lock = threading.Lock()
        self._retry = retry or RetryPolicy()
        self._sock = wire.connect(addr)
        self.ring: Optional[HashRing] = None
        self.epoch: Optional[str] = None
        self.refresh()

    # -- control-plane RTTs ---------------------------------------------
    def _request(self, header: dict) -> dict:
        if self.tenant and "tenant" not in header:
            header = dict(header, tenant=self.tenant)
        for attempt in self._retry.attempts(f"gateway {header.get('op')}"):
            try:
                # the lock serializes request/reply pairs on the one
                # control conn — blocking under it is the point
                with self._lock:  # lint: ignore[io-under-lock]
                    h, _ = wire.request(self._sock, header)
                break
            except (ConnectionError, TimeoutError, OSError) as e:
                attempt.backoff(e)          # jittered sleep, outside _lock
                try:
                    self._reconnect()
                except (ConnectionError, OSError):
                    pass    # still down: the next attempt backs off again
        if not h.get("ok"):
            raise error_from_reply(h, f"gateway {header.get('op')} failed")
        return h

    def _reconnect(self) -> None:
        # the dial under the lock *is* the serialisation: concurrent
        # _request retries must not race a half-swapped control conn
        with self._lock:  # lint: ignore[io-under-lock]
            old, self._sock = self._sock, wire.connect(self.addr)
        try:
            old.close()
        except OSError:
            pass

    def refresh(self) -> HashRing:
        """Re-fetch the authoritative ring (join/leave happened)."""
        h = self._request({"op": "ring"})
        ring = HashRing.decode(h["ring"])
        self.ring, self.epoch = ring, ring.epoch
        return ring

    def _adopt_epoch(self, h: dict) -> None:
        epoch = h.get("epoch")
        if epoch and epoch != self.epoch:
            try:
                self.refresh()
            except (OSError, RuntimeError):
                pass     # stale cache only costs extra refreshes, not data

    def admit(self, name: str, size: int,
              epoch: Optional[str] = None) -> str:
        """Admit one dataset (auth + quota + placement); returns the
        backend address the data plane must target. ``epoch`` is the
        producer's replay identity: a re-admit of the same (name, epoch)
        is not re-charged against the tenant, and the gateway moves the
        parity accounting if placement changed (backend fail-out)."""
        req = {"op": "admit", "name": name, "size": int(size)}
        if epoch is not None:
            req["epoch"] = str(epoch)
        h = self._request(req)
        self._adopt_epoch(h)
        return h["addr"]

    def admit_batch(self, items: Sequence[tuple[str, int]]) -> list[str]:
        """Admit N datasets in one RTT (the Coalescer's flush path);
        all-or-nothing against quota. Returns one backend address per
        item, in order."""
        h = self._request({"op": "admit_batch",
                           "items": [{"name": n, "size": int(s)}
                                     for n, s in items]})
        self._adopt_epoch(h)
        return list(h["addrs"])

    # -- local (RTT-free) placement -------------------------------------
    def place(self, name: str) -> RingNode:
        """Predicted owner from the cached ring (grouping hint only —
        ``admit`` is the authority)."""
        if self.ring is None or not len(self.ring):
            raise RuntimeError("gateway ring cache is empty")
        return self.ring.place(name)

    def stats(self) -> dict:
        return self._request({"op": "stats"})

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
