"""Consistent-hash placement ring — the gateway's pure placement core.

Datasets map to staging backends through a classic virtual-node hash
ring (Karger et al.; the shape every staging fabric from DataSpaces to
memcached pools converges on): each backend contributes ``round(vnodes
* weight)`` points hashed onto a 64-bit circle, and a dataset lands on
the first point clockwise of its own hash. Properties the gateway (and
the property tests) rely on:

  * **deterministic across processes** — hashes are BLAKE2b over the
    node/key text, never Python's seeded ``hash()``; two gateways (or a
    gateway and a client-side cache) built from the same node set place
    every key identically;
  * **minimal disruption** — adding or removing one of N equal nodes
    remaps ~K/N of K keys; everything else stays put (contrast a modulo
    scheme, which remaps nearly everything);
  * **capacity weights** — a node with ``weight=2.0`` owns ~2x the
    arc, so heterogeneous staging servers fill proportionally.

The ring is immutable: membership changes build a new ring
(:meth:`with_node` / :meth:`without_node`).  Every distinct node set has
a deterministic :attr:`epoch` digest carried on the wire, so a client
caching placements can detect staleness with an equality check instead
of re-fetching the whole ring per admit.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import json
from typing import Iterable, Optional

DEFAULT_VNODES = 64


def _h64(text: str) -> int:
    """64-bit position on the ring — BLAKE2b so placement is identical in
    every process (``hash()`` is salted per interpreter)."""
    digest = hashlib.blake2b(text.encode("utf-8"), digest_size=8).digest()
    return int.from_bytes(digest, "big")


@dataclasses.dataclass(frozen=True)
class RingNode:
    """One staging backend: data-plane address, its analytical endpoint,
    and a relative capacity weight."""

    name: str
    addr: str                   # StagingServer host:port (data + control)
    savime_addr: str = ""       # SAVIME behind this backend (query fan-out)
    weight: float = 1.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class HashRing:
    """Immutable consistent-hash ring over :class:`RingNode`s."""

    def __init__(self, nodes: Iterable[RingNode],
                 vnodes: int = DEFAULT_VNODES):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        by_name: dict[str, RingNode] = {}
        for n in nodes:
            if n.name in by_name:
                raise ValueError(f"duplicate ring node {n.name!r}")
            if n.weight <= 0:
                raise ValueError(
                    f"node {n.name!r} weight must be > 0, got {n.weight}")
            by_name[n.name] = n
        # canonical order: ring identity (and the epoch digest) must not
        # depend on the order the caller listed the nodes in
        self.nodes: tuple[RingNode, ...] = tuple(
            by_name[k] for k in sorted(by_name))
        self.vnodes = vnodes
        points: list[tuple[int, str, RingNode]] = []
        for node in self.nodes:
            replicas = max(1, round(vnodes * node.weight))
            for r in range(replicas):
                # node name ties (hash collisions) break by name so the
                # ring order is still total and deterministic
                points.append((_h64(f"{node.name}#{r}"), node.name, node))
        points.sort(key=lambda p: (p[0], p[1]))
        self._hashes = [p[0] for p in points]
        self._owners = [p[2] for p in points]

    # -- placement ------------------------------------------------------
    def place(self, key: str) -> RingNode:
        """The backend owning ``key`` (first vnode clockwise)."""
        if not self._hashes:
            raise RuntimeError("cannot place on an empty ring")
        i = bisect.bisect_right(self._hashes, _h64(key))
        return self._owners[i % len(self._owners)]

    def node(self, name: str) -> Optional[RingNode]:
        for n in self.nodes:
            if n.name == name:
                return n
        return None

    def __len__(self) -> int:
        return len(self.nodes)

    def __contains__(self, name: str) -> bool:
        return self.node(name) is not None

    # -- membership (pure: build a new ring) ----------------------------
    def with_node(self, node: RingNode) -> "HashRing":
        return HashRing([n for n in self.nodes if n.name != node.name]
                        + [node], self.vnodes)

    def without_node(self, name: str) -> "HashRing":
        return HashRing([n for n in self.nodes if n.name != name],
                        self.vnodes)

    # -- wire encoding / staleness detection ----------------------------
    @property
    def epoch(self) -> str:
        """Deterministic digest of the membership (node set + weights +
        vnodes). Two rings place identically iff their epochs match, so
        clients cache placements and compare epochs instead of rings."""
        canon = json.dumps(
            [self.vnodes, [[n.name, n.addr, n.savime_addr, n.weight]
                           for n in self.nodes]],
            separators=(",", ":"))
        return hashlib.blake2b(canon.encode("utf-8"),
                               digest_size=8).hexdigest()

    def encode(self) -> dict:
        """JSON-safe wire form (the gateway's ``ring`` op reply)."""
        return {"vnodes": self.vnodes, "epoch": self.epoch,
                "nodes": [n.as_dict() for n in self.nodes]}

    @classmethod
    def decode(cls, d: dict) -> "HashRing":
        ring = cls([RingNode(**n) for n in d.get("nodes", ())],
                   vnodes=int(d.get("vnodes", DEFAULT_VNODES)))
        epoch = d.get("epoch")
        if epoch and ring.epoch != epoch:
            raise ValueError(
                f"ring epoch mismatch after decode: {ring.epoch} != {epoch}")
        return ring
