"""Scatter-gather query router — one analytical answer over N backends.

A tar sharded across the pool (each dataset/subtar loaded into exactly
one backend's SAVIME by ring placement) must still answer a single
:class:`~repro.analysis.AnalysisSession`-shaped query. This module holds
the pure merge functions plus :class:`RouterSession`, the
AnalysisSession-compatible front the gateway's ``run_savime`` op and
analytical clients both ride.

Merge strategy, chosen for *byte-identical* parity with the N=1 run
(the acceptance bar — "recombines exactly" must mean bit-equal floats,
not merely close):

  * ``select`` — each backend materializes the *same* query box (its
    missing cells are zero-filled, exactly as a single server zero-fills
    them); the box-shaped parts are summed elementwise.  Subtars are
    placed disjointly (each dataset lives on one backend), so every cell
    is non-zero in at most one part and the sum *is* the overlay — no
    float reordering anywhere.
  * ``sum`` / ``mean`` / ``std`` / ``count`` — computed by applying the
    single-server reduction (``float(np_op(...))``) to the merged select,
    not by recombining per-backend scalars: ``sum(A) + sum(B)`` changes
    the pairwise-summation tree and can drift in the last bit, while
    ``np.sum(A + B)`` reduces the identical array a single server would.
  * ``min`` / ``max`` — scalar merge of per-backend aggregates over the
    *resolved* query box (never each backend's own data box: the
    single-server answer includes the zero-filled gaps, so every backend
    must see the same box). Float min/max is exact, order-free.
  * unbounded queries — resolved against the union of per-backend data
    boxes (the new ``data_box`` engine op), which equals the single
    server's clip box.
  * histograms — analyzer summaries with identical edges merge by
    summing counts (:func:`merge_histograms`).
  * ``watch()`` — :class:`MultiSubscription` selects across one push
    connection per backend and yields events as they arrive.
"""
from __future__ import annotations

import select as _select
import time
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

from repro.core import wire
from repro.core.savime import SavimeClient, SavimeError, _parse_call
from repro.analysis.query import Aggregate, Select, Statement
from repro.analysis.session import (AnalysisStats, QueryResult,
                                    SubscriptionClosed, Subscription,
                                    SubtarEvent)

Box = tuple[tuple[int, ...], tuple[int, ...]]


# ---------------------------------------------------------------------------
# pure merge functions
# ---------------------------------------------------------------------------


def backend_data_box(cli: SavimeClient, tar: str) -> Optional[Box]:
    """One backend's loaded bounding box for ``tar`` (None = no data,
    including "tar unknown here" — a backend that never saw the DDL)."""
    try:
        box = cli.run(f"data_box({tar})")
    except SavimeError:
        return None
    if not box:
        return None
    return tuple(box[0]), tuple(box[1])


def union_box(boxes: Sequence[Optional[Box]]) -> Optional[Box]:
    """Bounding box of the per-backend boxes — equals the data box a
    single server holding every subtar would clip unbounded queries to."""
    boxes = [b for b in boxes if b]
    if not boxes:
        return None
    nd = len(boxes[0][0])
    lo = tuple(min(b[0][i] for b in boxes) for i in range(nd))
    hi = tuple(max(b[1][i] for b in boxes) for i in range(nd))
    return lo, hi


def _first_answer(clis: Sequence[SavimeClient], q: str):
    """Run ``q`` on backends in order until one answers. In a sharded
    pool "no tar here" is membership, not failure — only surface an
    error when *no* backend can answer, preferring a substantive error
    (e.g. min over an empty tar) over a membership miss."""
    errs: list[SavimeError] = []
    for cli in clis:
        try:
            return cli.run(q)
        except SavimeError as e:
            errs.append(e)
    substantive = [e for e in errs if not str(e).startswith("no tar")]
    raise (substantive[0] if substantive else errs[-1])


def gather_select(clis: Sequence[SavimeClient], tar: str, attr: str,
                  lo=None, hi=None) -> np.ndarray:
    """Merged ``select`` over every backend (overlay-by-sum; see module
    docstring for why this is byte-identical to the N=1 run)."""
    if lo is None:
        box = union_box([backend_data_box(c, tar) for c in clis])
        if box is None:
            # no subtar anywhere: delegate so the typed empty result
            # (dtype + 0-size shape) matches the single server exactly
            return np.asarray(_first_answer(clis, Select(tar, attr).compile()))
        lo, hi = box
    lo, hi = tuple(lo), tuple(hi)
    q = Select(tar, attr, lo, hi).compile()
    shape = tuple(h - l + 1 for l, h in zip(lo, hi))
    merged: Optional[np.ndarray] = None
    typed_empty: Optional[np.ndarray] = None
    for cli in clis:
        try:
            part = np.asarray(cli.run(q))
        except SavimeError:
            continue            # this backend never saw the tar's DDL

        if part.shape != shape:
            typed_empty = part  # empty-tar backends answer 0-size typed
            continue
        merged = part.copy() if merged is None else merged + part
    if merged is not None:
        return merged
    if typed_empty is not None:
        return typed_empty
    return np.asarray(_first_answer(clis, q))   # surface the right error


def gather_aggregate(clis: Sequence[SavimeClient], tar: str, attr: str,
                     op: str, lo=None, hi=None) -> float:
    """Merged ``aggregate`` (exactness per the module docstring)."""
    if lo is None:
        box = union_box([backend_data_box(c, tar) for c in clis])
        if box is None:
            # empty everywhere: raise/return whatever one server would
            return float(_first_answer(clis,
                                       Aggregate(tar, attr, op).compile()))
        lo, hi = box
    lo, hi = tuple(lo), tuple(hi)
    if op in ("sum", "mean", "std", "count"):
        merged = gather_select(clis, tar, attr, lo, hi)
        np_op = {"sum": np.sum, "mean": np.mean, "std": np.std,
                 "count": np.size}[op]
        return float(np_op(merged))
    if op not in ("min", "max"):
        raise SavimeError(f"unknown aggregate op {op!r}")
    q = Aggregate(tar, attr, op, lo, hi).compile()
    parts: list[float] = []
    for cli in clis:
        try:
            parts.append(float(cli.run(q)))
        except SavimeError:
            continue        # backend holds no data for this tar
    if not parts:
        return float(_first_answer(clis, q))   # surface the right error
    return float(max(parts) if op == "max" else min(parts))


def merge_histograms(summaries) -> dict:
    """Merge ``histogram`` analyzer payloads computed per backend: counts
    add bin-wise when every summary shares the same edges (fix the range
    up front — ``Histogram(bins, lo, hi)`` — so they do)."""
    payloads = [getattr(s, "payload", s) for s in summaries]
    if not payloads:
        return {"counts": [], "edges": [], "total": 0}
    edges = payloads[0]["edges"]
    for p in payloads[1:]:
        if p["edges"] != edges:
            raise ValueError(
                "cannot merge histograms with different edges; construct "
                "them with an explicit (lo, hi) range")
    counts = np.sum([p["counts"] for p in payloads], axis=0)
    return {"counts": counts.tolist(), "edges": list(edges),
            "total": int(counts.sum())}


def route_query(clis: Sequence[SavimeClient], q: str,
                place: Optional[Callable[[str], Optional[int]]] = None):
    """Route one compiled mini-language query across ``clis``.

    DDL (``create_tar``/``drop_tar``) fans to every backend so any of
    them can host any subtar; ``load_subtar`` runs where its dataset was
    ingested (``place(dataset) -> client index`` hint first, then the
    rest — the dataset lives on exactly one backend); reads merge via
    the gather functions above.
    """
    if not clis:
        raise RuntimeError("no live backends to route to")
    fn, args = _parse_call(q)
    if fn in ("create_tar", "drop_tar"):
        res = None
        for cli in clis:
            res = cli.run(q)
        return res
    if fn == "load_subtar":
        dataset = args[1] if len(args) > 1 else ""
        order = list(range(len(clis)))
        if place is not None:
            i = place(dataset)
            if i is not None and 0 <= i < len(clis):
                order.remove(i)
                order.insert(0, i)
        last: Optional[SavimeError] = None
        for i in order:
            try:
                return clis[i].run(q)
            except SavimeError as e:
                last = e
        raise last if last is not None else SavimeError("no backends")

    def _box(i: int):
        if len(args) > i and args[i]:
            return tuple(int(x) for x in args[i].split(","))
        return None

    if fn == "select":
        return gather_select(clis, args[0], args[1], _box(2), _box(3))
    if fn == "aggregate":
        return gather_aggregate(clis, args[0], args[1], args[2],
                                _box(3), _box(4))
    if fn == "data_box":
        box = union_box([backend_data_box(c, args[0]) for c in clis])
        return None if box is None else [list(box[0]), list(box[1])]
    # membership-independent ops (list_tars, ...) answer from one backend
    return clis[0].run(q)


# ---------------------------------------------------------------------------
# multiplexed subscriptions
# ---------------------------------------------------------------------------


class MultiSubscription:
    """``watch()`` over a sharded tar: one push connection per backend,
    events interleaved in arrival order. Iteration semantics mirror
    :class:`~repro.analysis.session.Subscription` (ends after
    ``max_events`` events or a ``timeout`` wait with nothing arriving)."""

    def __init__(self, addrs: Sequence[str], tar: str = "", *,
                 timeout: Optional[float] = None,
                 max_events: Optional[int] = None):
        self.tar = tar
        self.timeout = timeout
        self.max_events = max_events
        self.n_events = 0
        self.subs: list[Subscription] = []
        try:
            for a in addrs:
                self.subs.append(Subscription(a, tar))
        except BaseException:
            self.close()
            raise

    def poll(self, timeout: Optional[float] = None) -> Optional[SubtarEvent]:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            live = {s._sock: s for s in self.subs if not s._closed}
            if not live:
                return None
            remaining = None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)
            ready, _, _ = _select.select(list(live), [], [], remaining)
            for sock in ready:
                try:
                    ev = live[sock].poll(0)
                except SubscriptionClosed:
                    continue        # one backend gone; survivors keep going
                if ev is not None:
                    self.n_events += 1
                    return ev
            if deadline is not None and time.monotonic() >= deadline:
                return None
            if not ready and remaining is None:
                return None      # select woke with nothing: all gone

    def __iter__(self) -> Iterator[SubtarEvent]:
        return self

    def __next__(self) -> SubtarEvent:
        if self.max_events is not None and self.n_events >= self.max_events:
            raise StopIteration
        ev = self.poll(self.timeout)
        if ev is None:
            raise StopIteration
        return ev

    def close(self) -> None:
        for s in self.subs:
            s.close()

    def __enter__(self) -> "MultiSubscription":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# session front
# ---------------------------------------------------------------------------


class RouterSession:
    """AnalysisSession-compatible scatter-gather front.

    Point it at the pool directly (``savime_addrs=[...]``) or at a
    gateway (``gateway_addr=...`` — the backend list and the placement
    hint come from the gateway's ring). Surface mirrors
    :class:`~repro.analysis.AnalysisSession`: ``execute`` /
    ``execute_all`` / ``watch`` / ``server_stats`` / typed
    :class:`QueryResult`s / :class:`AnalysisStats`.
    """

    def __init__(self, savime_addrs: Optional[Sequence[str]] = None, *,
                 gateway_addr: Optional[str] = None,
                 label: Optional[str] = None):
        if (savime_addrs is None) == (gateway_addr is None):
            raise ValueError("RouterSession needs exactly one of "
                             "savime_addrs= or gateway_addr=")
        self._ring = None
        if gateway_addr is not None:
            from repro.gateway.ring import HashRing   # local: leaf import
            sock = wire.connect(gateway_addr)
            try:
                h, _ = wire.request(sock, {"op": "ring"})
            finally:
                sock.close()
            if not h.get("ok"):
                raise RuntimeError(f"gateway ring fetch failed: "
                                   f"{h.get('error')}")
            self._ring = HashRing.decode(h["ring"])
            savime_addrs = [n.savime_addr for n in self._ring.nodes]
            if not all(savime_addrs):
                raise RuntimeError("gateway ring carries no analytical "
                                   "endpoints (savime_addr)")
        self.addrs = list(savime_addrs)
        self.stats = AnalysisStats(
            endpoint=label or f"router[{len(self.addrs)}]")
        self._clis: list[SavimeClient] = []
        self._opened = False
        self._closed = False

    # -- lifecycle ------------------------------------------------------
    def open(self) -> "RouterSession":
        if self._opened:
            return self
        self._clis = [SavimeClient(a) for a in self.addrs]
        self._opened = True
        return self

    def __enter__(self) -> "RouterSession":
        return self.open()

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        self._closed = True
        for cli in self._clis:
            cli.close()
        self._clis = []

    # -- execution ------------------------------------------------------
    def _place_hint(self, dataset: str) -> Optional[int]:
        if self._ring is None or not len(self._ring):
            return None
        node = self._ring.place(dataset)
        return self._ring.nodes.index(node)

    def execute(self, stmt: "Statement | str") -> QueryResult:
        self._check_live()
        q = stmt.compile() if isinstance(stmt, Statement) else str(stmt)
        kind = stmt.kind if isinstance(stmt, Statement) else "raw"
        t0 = time.perf_counter()
        raw = route_query(self._clis, q, place=self._place_hint)
        if hasattr(stmt, "finalize"):
            raw = stmt.finalize(raw)
        elapsed = time.perf_counter() - t0
        if isinstance(raw, np.ndarray):
            dtype, shape = str(raw.dtype), tuple(raw.shape)
            self.stats.result_bytes += raw.nbytes
        else:
            dtype = shape = None
        self.stats.n_queries += 1
        self.stats.query_s += elapsed
        self.stats.by_kind[kind] = self.stats.by_kind.get(kind, 0) + 1
        return QueryResult(query=q, kind=kind, value=raw, dtype=dtype,
                           shape=shape, elapsed_s=elapsed, attempts=1)

    def execute_all(self, stmts) -> list[QueryResult]:
        return [self.execute(s) for s in stmts]

    # -- live subscription ---------------------------------------------
    def watch(self, tar: str = "", *, timeout: Optional[float] = None,
              max_events: Optional[int] = None) -> MultiSubscription:
        self._check_live()
        return MultiSubscription(self.addrs, tar, timeout=timeout,
                                 max_events=max_events)

    # -- introspection --------------------------------------------------
    def server_stats(self) -> dict:
        """Summed engine counters across backends (+ ``backends``)."""
        self._check_live()
        out: dict = {"backends": len(self._clis)}
        for cli in self._clis:
            for k, v in cli.stats().items():
                if k != "ok" and isinstance(v, (int, float)):
                    out[k] = out.get(k, 0) + v
        return out

    def _check_live(self) -> None:
        if not self._opened:
            raise RuntimeError("RouterSession not opened "
                               "(use `with` or .open())")
        if self._closed:
            raise RuntimeError("RouterSession already closed")
