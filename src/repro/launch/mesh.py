"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e pod); 2x16x16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{max(n, 512)} (see launch/dryrun.py)")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU multi-device tests (8 fake devices)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
