"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Prefill a batch of prompts, then decode greedily with a donated KV cache —
the production path the decode_* dry-run shapes lower. Optionally stages
per-request latency diagnostics in transit (SAVIME) like a real fleet
would.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis, transport
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import Model
from repro.train import ServeSetup


def build_mesh(spec: str):
    if spec == "single":
        return make_production_mesh()
    if spec == "multi":
        return make_production_mesh(multi_pod=True)
    parts = [int(x) for x in spec.split("x")]
    return make_debug_mesh(*parts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--intransit", action="store_true",
                    help="stage per-step latencies into SAVIME")
    ap.add_argument("--transport", default="rdma_staged",
                    choices=transport.available(),
                    help="egress engine for the in-transit sink")
    ap.add_argument("--channels", type=int, default=1,
                    help="stripe egress across N concurrent connections "
                         "with credit-based flow control (1 = off)")
    ap.add_argument("--wire-format", default="json",
                    choices=["json", "bin1"],
                    help="negotiate the struct-packed binary fast path "
                         "for hot data frames (falls back to json)")
    ap.add_argument("--coalesce-kb", type=int, default=0,
                    help="coalesce datasets below this size into jumbo "
                         "batched frames (KiB, 0 = off)")
    ap.add_argument("--page-kb", type=int, default=0,
                    help="run staging on the paged store with this page "
                         "size (KiB, 0 = flat regions); cold pages spill "
                         "to disk under memory pressure (DESIGN.md §11)")
    ap.add_argument("--spill-dir", default=None,
                    help="directory for spilled cold pages (default: a "
                         "spill/ subdir of the staging disk tier)")
    ap.add_argument("--dedup", action="store_true",
                    help="content-addressed page dedup: identical sealed "
                         "pages stored once (needs --page-kb)")
    ap.add_argument("--codec", default="none",
                    help="egress reduction codec for staged datasets "
                         "(none | delta-rle | int8-block; DESIGN.md §13)")
    ap.add_argument("--decode-at", default="staging",
                    choices=["staging", "query"],
                    help="decode coded datasets at ingest (default) or "
                         "store them compressed and decode lazily on the "
                         "staging->SAVIME hop")
    ap.add_argument("--analyzer", default=None,
                    choices=analysis.analyzers.available(),
                    help="summarize staged decode latencies with a "
                         "registered analyzer (needs --intransit)")
    ap.add_argument("--pool", type=int, default=0,
                    help="run N staging backends behind one gateway "
                         "(DESIGN.md §12; 0 = single staging server)")
    ap.add_argument("--tenant", default=None, metavar="NAME[:TOKEN]",
                    help="gateway tenant to write as (needs --pool); "
                         "NAME:TOKEN registers the tenant with that token")
    ap.add_argument("--quota-mb", type=int, default=0,
                    help="per-tenant byte quota in MiB (needs --pool; "
                         "0 = unlimited)")
    ap.add_argument("--faults", default=None,
                    help="seeded fault plan for the staging path — a DSL "
                         "string ('seed=42;drop:op=stripe,prob=0.01;"
                         "kill:target=staging:0,at_s=0.5') or a JSON plan "
                         "file; exercises retry/replay (DESIGN.md §15)")
    args = ap.parse_args()
    if args.analyzer and not args.intransit:
        ap.error("--analyzer requires --intransit")
    if (args.tenant or args.quota_mb) and not args.pool:
        ap.error("--tenant/--quota-mb require --pool")
    if args.pool and args.transport != "rdma_staged":
        ap.error("--pool requires the rdma_staged transport")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    mesh = build_mesh(args.mesh)
    B, S, N = args.batch, args.prompt_len, args.new_tokens
    setup = ServeSetup(model, mesh, global_batch=B)
    print(f"[serve] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}, batch {B} x prompt {S} + {N} new")

    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab_size)
    prefill = jax.jit(setup.prefill_fn(max_len=S + N))
    decode = jax.jit(setup.decode_fn(), donate_argnums=(1,))

    sink = staging = savime = pool = fault_sched = None
    tenant_token = None
    if args.intransit:
        from repro.core import (InTransitConfig, InTransitSink, SavimeServer,
                                StagingServer)
        if args.pool:
            from repro.gateway import StagingPool, Tenant
            tenants = ()
            quota = (args.quota_mb << 20) or None
            if args.tenant:
                name, _, token = args.tenant.partition(":")
                tenant_token = token or name
                tenants = (Tenant(name, token=token or None,
                                  quota_bytes=quota),)
            pool = StagingPool(args.pool,
                               tenants=tenants,
                               default_quota_bytes=None if args.tenant
                               else quota,
                               staging_kwargs={
                                   "page_bytes": args.page_kb << 10,
                                   "spill_dir": args.spill_dir,
                                   "dedup": args.dedup}).start()
            sink_addr = pool.addr
            print(f"[serve] staging pool: {args.pool} backends behind "
                  f"gateway {pool.addr}")
        else:
            savime = SavimeServer().start()
            staging = StagingServer(savime.addr,
                                    page_bytes=args.page_kb << 10,
                                    spill_dir=args.spill_dir,
                                    dedup=args.dedup).start()
            sink_addr = (staging.addr if args.transport == "rdma_staged"
                         else savime.addr)
        if args.faults:
            from repro.faults import FaultPlan, FaultScheduler, install
            plan = FaultPlan.parse(args.faults)
            if pool is not None:
                scope = [pool.addr] + [st.addr for st in pool.stagings] \
                    + [sv.addr for sv in pool.savimes]
                targets = {"gateway": pool.gateway.stop}
                for i, st in enumerate(pool.stagings):
                    targets[f"staging:{i}"] = st.stop
                for i, sv in enumerate(pool.savimes):
                    targets[f"savime:{i}"] = sv.stop
            else:
                scope = [staging.addr, savime.addr]
                targets = {"staging:0": staging.stop,
                           "savime:0": savime.stop}
            install(plan, scope=scope)
            fault_sched = FaultScheduler(plan, targets).start()
            print(f"[serve] fault plan armed (seed={plan.seed}, "
                  f"{len(plan.rules)} rule(s))")
        sink = InTransitSink(sink_addr,
                             InTransitConfig(tar_prefix="serve",
                                             transport=args.transport,
                                             n_channels=args.channels,
                                             wire_format=args.wire_format,
                                             coalesce_bytes=(
                                                 args.coalesce_kb << 10),
                                             page_bytes=args.page_kb << 10,
                                             spill_dir=args.spill_dir,
                                             dedup=args.dedup,
                                             gateway=bool(args.pool),
                                             tenant=tenant_token,
                                             codec=args.codec,
                                             decode_at=args.decode_at))

    key = jax.random.PRNGKey(2)
    with jax.set_mesh(mesh):
        t0 = time.perf_counter()
        logits, cache = prefill(params, {"tokens": prompts})
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        def sample(lg, key):
            if args.temperature <= 0:
                return jnp.argmax(lg, -1)[:, None]
            return jax.random.categorical(
                key, lg / args.temperature, -1)[:, None]

        tok = sample(logits, key)
        out, lat = [tok], []
        for i in range(N - 1):
            key, sub = jax.random.split(key)
            pos = jnp.full((B,), S + i, jnp.int32)
            t1 = time.perf_counter()
            logits, cache = decode(params, cache, {"tokens": tok, "pos": pos})
            tok = sample(logits, sub)
            jax.block_until_ready(tok)
            lat.append(time.perf_counter() - t1)
            out.append(tok)
            if sink is not None:
                sink.stage_array("decode_ms",
                                 np.float32([lat[-1] * 1e3]), step=i)

    gen = jnp.concatenate(out, axis=1)
    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] prefill {t_prefill * 1e3:.0f} ms; decode p50 "
          f"{np.percentile(lat_ms, 50):.1f} ms/tok, p99 "
          f"{np.percentile(lat_ms, 99):.1f} ms/tok "
          f"({B * 1e3 / np.mean(lat_ms):.1f} tok/s aggregate)")
    print(f"[serve] sample (req 0): {gen[0, :16].tolist()}")
    if sink is not None:
        sink.flush()
        if args.analyzer:
            if pool is not None:
                from repro.gateway import RouterSession
                an_ctx = RouterSession(gateway_addr=pool.addr)
            else:
                an_ctx = analysis.AnalysisSession(savime.addr)
            with an_ctx as an:
                res = an.execute(
                    analysis.tar("serve_decode_ms").attr("v").select())
                a = analysis.analyzers.create(args.analyzer)
                a.update(res)
                s = a.summary()
                print(f"[serve] analyzer[{s.analyzer}] over "
                      f"{res.shape} staged latencies: {s.payload}")
        sink.close()
        if fault_sched is not None:
            from repro.faults import uninstall
            fault_sched.stop()
            uninstall()
        if pool is not None:
            gw = sink.session.stats.gateway
            if gw:
                print(f"[serve] gateway: {gw['totals']} across "
                      f"{gw['live_backends']}/{gw['n_backends']} backends; "
                      f"tenants: {gw['tenants']}")
            pool.stop()
        else:
            staging.stop()
            savime.stop()


if __name__ == "__main__":
    main()
