"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires together: config registry -> model -> mesh -> TrainSetup (pjit,
ZeRO-1, optional compressed cross-pod grads) -> synthetic data pipeline ->
fault-tolerant Supervisor (async checkpoints through the staging path) ->
in-transit diagnostics sink (the paper's consumer is a live SAVIME you can
query while training).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import transport
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import InTransitConfig, InTransitSink, SavimeServer, StagingServer
from repro.data import DataConfig, SyntheticLM, device_put_batch
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import Model
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import TrainConfig, TrainSetup


def build_mesh(spec: str):
    if spec == "single":
        return make_production_mesh()
    if spec == "multi":
        return make_production_mesh(multi_pod=True)
    parts = [int(x) for x in spec.split("x")]
    if len(parts) == 2:
        return make_debug_mesh(*parts)
    return make_debug_mesh(parts[1], parts[2], pod=parts[0])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--mesh", default="1x1",
                    help="single | multi | DxM | PxDxM (debug sizes)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--intransit", action="store_true",
                    help="stage per-step diagnostics into SAVIME")
    ap.add_argument("--transport", default="rdma_staged",
                    choices=transport.available(),
                    help="egress engine for the in-transit sink")
    ap.add_argument("--channels", type=int, default=1,
                    help="stripe egress across N concurrent connections "
                         "with credit-based flow control (1 = off)")
    ap.add_argument("--wire-format", default="json",
                    choices=["json", "bin1"],
                    help="negotiate the struct-packed binary fast path "
                         "for hot data frames (falls back to json)")
    ap.add_argument("--coalesce-kb", type=int, default=0,
                    help="coalesce datasets below this size into jumbo "
                         "batched frames (KiB, 0 = off)")
    ap.add_argument("--page-kb", type=int, default=0,
                    help="run staging on the paged store with this page "
                         "size (KiB, 0 = flat regions); cold pages spill "
                         "to disk under memory pressure (DESIGN.md §11)")
    ap.add_argument("--spill-dir", default=None,
                    help="directory for spilled cold pages (default: a "
                         "spill/ subdir of the staging disk tier)")
    ap.add_argument("--dedup", action="store_true",
                    help="content-addressed page dedup: identical sealed "
                         "pages (e.g. repeated checkpoint shards) stored "
                         "once (needs --page-kb)")
    ap.add_argument("--codec", default="none",
                    help="egress reduction codec for staged datasets "
                         "(none | delta-rle | int8-block; DESIGN.md §13)")
    ap.add_argument("--decode-at", default="staging",
                    choices=["staging", "query"],
                    help="decode coded datasets at ingest (default) or "
                         "store them compressed and decode lazily on the "
                         "staging->SAVIME hop")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--egress", default="diag",
                    choices=["none", "diag", "grads_int8"])
    ap.add_argument("--faults", default=None,
                    help="seeded fault plan for the staging path — a DSL "
                         "string ('seed=42;drop:op=stripe,prob=0.01;"
                         "kill:target=staging:0,at_s=0.5') or a JSON plan "
                         "file; exercises retry/replay (DESIGN.md §15)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    mesh = build_mesh(args.mesh)
    print(f"[train] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")

    setup = TrainSetup(model, mesh, TrainConfig(
        peak_lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
        total_steps=args.steps, compress_pods=args.compress_pods,
        egress=args.egress))
    state = setup.init_state(jax.random.PRNGKey(0))

    sink = savime = staging = None
    fault_sched = None
    if args.intransit:
        savime = SavimeServer().start()
        staging = StagingServer(savime.addr,
                                page_bytes=args.page_kb << 10,
                                spill_dir=args.spill_dir,
                                dedup=args.dedup).start()
        if args.faults:
            from repro.faults import FaultPlan, FaultScheduler, install
            plan = FaultPlan.parse(args.faults)
            install(plan, scope=[staging.addr, savime.addr])
            fault_sched = FaultScheduler(plan, {
                "staging:0": staging.stop,
                "savime:0": savime.stop}).start()
            print(f"[train] fault plan armed (seed={plan.seed}, "
                  f"{len(plan.rules)} rule(s))")
        # the staged path attaches to staging; copy-emulation transports
        # (scp_*, ssh_direct) reach SAVIME directly, as the baselines do
        sink_addr = (staging.addr if args.transport == "rdma_staged"
                     else savime.addr)
        sink = InTransitSink(sink_addr, InTransitConfig(
            io_threads=2, transport=args.transport,
            n_channels=args.channels, wire_format=args.wire_format,
            coalesce_bytes=args.coalesce_kb << 10,
            page_bytes=args.page_kb << 10, spill_dir=args.spill_dir,
            dedup=args.dedup,
            codec=args.codec, decode_at=args.decode_at))
        print(f"[train] in-transit sink --{args.transport}"
              f"(x{args.channels} channels, {args.wire_format} wire"
              f"{', coalescing' if args.coalesce_kb else ''}"
              f"{f', codec={args.codec}' if args.codec != 'none' else ''})"
              f"--> SAVIME {savime.addr}")

    ckpt = CheckpointManager(args.ckpt_dir, sink=sink)
    sup = Supervisor(jax.jit(setup.step_fn(), donate_argnums=(0,)), ckpt,
                     SupervisorConfig(ckpt_every=args.ckpt_every))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, n_prefix=cfg.n_prefix,
                    d_model=cfg.d_model)
    raw = SyntheticLM(dc).batches()

    def batches():
        for b in raw:
            yield device_put_batch(b, mesh, setup.rules)

    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        state = sup.run(state, batches(), args.steps,
                        abstract_state=setup.abstract_state(),
                        shardings=setup.state_shardings())
    dt = time.perf_counter() - t0
    losses = [m["loss"] for m in sup.metrics_log if "loss" in m]
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({dt / max(args.steps, 1) * 1e3:.0f} ms/step) "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if sink is not None:
        sink.flush()
        print(f"[train] staged {sink.staged_arrays} arrays, "
              f"{sink.staged_bytes / 1e6:.1f} MB into SAVIME")
        sink.close()
        if fault_sched is not None:
            from repro.faults import uninstall
            fault_sched.stop()
            uninstall()
        staging.stop()
        savime.stop()


if __name__ == "__main__":
    main()
