"""Optimized-HLO analyzer with while-loop trip-count multiplicity.

XLA's HloCostAnalysis counts every computation ONCE — a lax.scan over 40
layer-periods under-reports flops/bytes/collectives by 40x. This analyzer
parses `compiled.as_text()` (post-SPMD optimized HLO) and:

  * builds the computation call graph (while bodies/conds, fusions, calls,
    conditionals),
  * extracts scan trip counts from while-condition `compare(iv, constant)`,
  * multiplies per-computation costs by their call-chain multiplicity,

yielding the three roofline inputs per device:
  flops            — 2·M·N·K per dot (+ trip counts)
  hbm_bytes        — operand+result bytes of top-level (post-fusion) ops
                     (fusion internals excluded = fused intermediates never
                     touch HBM)
  collective_bytes — per class, max(result, operands) per op × multiplicity
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str          # text after the opcode (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_fusion: bool = False


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$")
# shape group: either a (possibly /*index=N*/-annotated) flat tuple "(...)"
# (lazy — tuple shapes do not nest parens) or a single non-space token like
# bf16[8,16,512]{3,2,1,0}
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\(.*?\)|\S+)\s+"
    r"([a-z][\w\-]*)\((.*)$")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEAD.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1), [],
                                  is_fusion="fused" in m.group(1))
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(*m.groups()))
    return comps


def _trip_count(while_ins: Instr, comps: dict) -> int:
    """Prefer XLA's own annotation: backend_config known_trip_count."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_ins.rest)
    if m:
        return max(int(m.group(1)), 1)
    # fallback: cond computation compare(iv, constant(N))
    mc = re.search(r"condition=%?([\w.\-]+)", while_ins.rest)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        consts = {}
        for ins in cond.instrs:
            if ins.op == "constant" and ins.shape.strip().startswith(
                    ("s32", "s64", "u32")):
                mm = re.match(r"([\d]+)", ins.rest)
                if mm:
                    consts[ins.name] = int(mm.group(1))
        for ins in cond.instrs:
            if ins.op in ("compare", "fusion"):
                for ref in re.findall(r"%?([\w.\-]+)", ins.rest):
                    if ref in consts:
                        return max(consts[ref], 1)
    return 1


def _callees(ins: Instr) -> list[tuple[str, str]]:
    """[(kind, computation_name)] referenced by this instruction."""
    out = []
    for attr, kind in (("body", "body"), ("condition", "cond"),
                       ("calls", "call"), ("to_apply", "call"),
                       ("branch_computations", "branch")):
        m = re.search(attr + r"=\{?([\w.\-%,\s]+)\}?", ins.rest)
        if m:
            for name in m.group(1).split(","):
                out.append((kind, name.strip().lstrip("%")))
    return out


def multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    """computation name -> times executed (entry = 1)."""
    entry = None
    for name in comps:
        if "main" in name or entry is None:
            pass
    # the entry computation is the one never referenced
    referenced = set()
    for c in comps.values():
        for ins in c.instrs:
            for _, callee in _callees(ins):
                referenced.add(callee)
    entries = [n for n in comps if n not in referenced]
    mult: dict[str, float] = defaultdict(float)
    for e in entries:
        mult[e] = 1.0

    # propagate in topological-ish order (iterate until fixed point; graphs
    # are DAGs of modest depth)
    for _ in range(50):
        changed = False
        for name, c in comps.items():
            base = mult.get(name, 0.0)
            if base == 0.0:
                continue
            for ins in c.instrs:
                for kind, callee in _callees(ins):
                    if callee not in comps:
                        continue
                    factor = 1.0
                    if kind in ("body", "cond") and ins.op == "while":
                        factor = float(_trip_count(ins, comps))
                    new = base * factor
                    if abs(mult.get(callee, 0.0) - new) > 1e-9:
                        # accumulate across multiple callers: recompute from
                        # scratch is complex; assume single-caller (true for
                        # jax-emitted HLO) and take max
                        if new > mult.get(callee, 0.0):
                            mult[callee] = new
                            changed = True
        if not changed:
            break
    return dict(mult)


def _dot_flops(ins: Instr, sizes: dict[str, int]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    res_elems = _shape_elems(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not m:
        return 2.0 * res_elems  # unknown: count as elementwise-ish
    # lhs shape: either inline `bf16[a,b]{..} %ref` or via symbol table
    lhs_txt = ins.rest.split(",")[0]
    mi = _SHAPE_RE.search(lhs_txt)
    if mi:
        lhs_shape = mi.group(2)
    else:
        refs = re.findall(r"%([\w.\-]+)", ins.rest)
        lhs_shape = sizes.get(refs[0] + "__shape") if refs else None
    if lhs_shape is None:
        return 2.0 * res_elems
    dims = [int(d) for d in lhs_shape.split(",") if d]
    k = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(dims):
            k *= dims[i]
    return 2.0 * res_elems * k


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    mult = multiplicities(comps)

    flops = 0.0
    vpu_flops = 0.0
    hbm_bytes = 0.0
    coll_bytes = dict.fromkeys(COLLECTIVES, 0.0)
    coll_counts = dict.fromkeys(COLLECTIVES, 0.0)
    # elementwise float ops executed by the VPU (dominant for SSM scans)
    _VPU_OPS = {"multiply", "add", "subtract", "divide", "maximum",
                "minimum", "exponential", "tanh", "log", "rsqrt", "sqrt",
                "power", "negate", "abs", "logistic", "cosine", "sine"}

    for name, c in comps.items():
        w = mult.get(name, 0.0)
        if w == 0.0:
            continue
        # symbol tables for this computation
        sizes: dict[str, int] = {}
        for ins in c.instrs:
            sizes[ins.name] = _shape_bytes(ins.shape)
            m = _SHAPE_RE.search(ins.shape)
            if m:
                sizes[ins.name + "__shape"] = m.group(2)
        for ins in c.instrs:
            if ins.op in ("dot", "dot-general"):
                flops += w * _dot_flops(ins, sizes)
            elif ins.op in _VPU_OPS and ins.shape.strip().startswith(
                    ("f32", "bf16", "f16", "f64")):
                vpu_flops += w * _shape_elems(ins.shape)
            base_op = ins.op.removesuffix("-start").removesuffix("-done")
            if base_op in COLLECTIVES and not ins.op.endswith("-done"):
                res = _shape_bytes(ins.shape)
                opnd = sum(sizes.get(r, 0) for r in
                           re.findall(r"%([\w.\-]+)", ins.rest))
                wire = max(res, opnd)
                # XLA:CPU promotes 16-bit all-reduces to f32 (reducer
                # "*_promoted"); the TPU target reduces at native 16-bit
                # width — count the unpromoted wire bytes
                if base_op == "all-reduce" and "promoted" in ins.rest \
                        and ins.shape.lstrip("(").strip().startswith("f32"):
                    wire *= 0.5
                coll_bytes[base_op] += w * wire
                coll_counts[base_op] += w
            if not c.is_fusion:  # post-fusion HBM traffic proxy
                res = _shape_bytes(ins.shape)
                refs = re.findall(r"%([\w.\-]+)", ins.rest)
                opnd = sum(sizes.get(r, 0) for r in refs)
                if ins.op in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast",
                              # wrappers: internals counted via their own
                              # computations; the call-site carry is not
                              # real traffic
                              "while", "conditional", "call"):
                    continue
                if ins.op == "dynamic-slice":
                    hbm_bytes += w * 2 * res          # read+write slice only
                elif ins.op == "dynamic-update-slice":
                    upd = sizes.get(refs[1], res) if len(refs) > 1 else res
                    hbm_bytes += w * 2 * min(upd, res)  # in-place update
                else:
                    hbm_bytes += w * (res + opnd)

    return {
        "flops": flops,
        "vpu_flops": vpu_flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total": sum(coll_bytes.values()),
        "n_computations": len(comps),
    }
