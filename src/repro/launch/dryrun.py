import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, record memory/cost analysis + collective schedule.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep (subprocesses)

Per-cell JSON lands in results/dryrun/<arch>__<shape>__<mesh>.json — the
roofline reader (benchmarks/roofline.py) consumes these.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             opts: str = "") -> dict:
    import contextlib
    import dataclasses
    from repro.configs import SHAPES, get_config, input_specs, \
        shape_applicable, flops_per_step
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import Model
    from repro.models import layers as layers_lib
    from repro.train.train_step import TrainSetup, TrainConfig
    from repro.train.serve_step import ServeSetup

    cfg = get_config(arch)
    opt_set = set(o for o in opts.split(",") if o)
    if "comm_remat" in opt_set:   # save post-AR outputs; no bwd re-AR
        cfg = dataclasses.replace(cfg, remat="comm")
    micro = 1
    for o in opt_set:
        if o.startswith("micro"):
            micro = int(o[5:])
        elif o.startswith("padheads"):
            cfg = dataclasses.replace(cfg, pad_heads_to=int(o[8:]))
    if "bf16_params" in opt_set:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    lowp = (layers_lib.lowp_collectives(True) if "lowp" in opt_set
            else contextlib.nullcontext())
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "when": time.strftime("%F %T")}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    specs = input_specs(cfg, shape)
    t0 = time.time()
    ctx = lowp
    ctx.__enter__()

    if shape.kind == "train":
        setup = TrainSetup(Model(cfg), mesh, TrainConfig(
            microbatches=micro, fsdp_experts="fsdp" in opt_set))
        fn = setup.jitted(shape)
        lowered = fn.lower(setup.abstract_state(), specs)
    elif shape.kind == "prefill":
        setup = ServeSetup(Model(cfg), mesh, global_batch=shape.global_batch)
        fn = setup.jitted_prefill(shape.global_batch, shape.seq_len)
        lowered = fn.lower(
            jax.tree.map(lambda s: s, setup.model.abstract_params()), specs)
    else:  # decode
        long_ctx = shape.seq_len >= 100_000
        setup = ServeSetup(Model(cfg), mesh, seq_shard_kv=long_ctx,
                           global_batch=shape.global_batch)
        fn = setup.jitted_decode(shape.global_batch, shape.seq_len)
        cache = setup.abstract_cache(shape.global_batch, shape.seq_len)
        lowered = fn.lower(setup.model.abstract_params(), cache, specs)

    ctx.__exit__(None, None, None)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    from repro.launch import hlo_analysis
    deep = hlo_analysis.analyze(hlo)

    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        # loop-corrected per-device numbers (repro.launch.hlo_analysis;
        # raw cost_analysis counts while bodies once — kept for reference)
        flops=deep["flops"],
        vpu_flops=deep.get("vpu_flops", 0.0),
        hbm_bytes=deep["hbm_bytes"],
        raw_cost_flops=float(cost.get("flops", 0.0)),
        raw_bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        model_flops=flops_per_step(cfg, shape),
        collectives={"bytes": deep["collective_bytes"],
                     "counts": deep["collective_counts"],
                     "total_bytes": deep["collective_total"]},
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        hlo_instr_count=hlo.count("\n"),
    )
    return rec


def cell_path(arch: str, shape: str, mesh: str, tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--opts", default="", help="lowp,comm_remat")
    ap.add_argument("--tag", default="", help="variant suffix for the JSON")
    args = ap.parse_args()

    if args.all:
        from repro.configs import ARCH_IDS, SHAPES
        failures = 0
        for mesh in ("single", "multi"):
            for arch in ARCH_IDS:
                for shape in SHAPES:
                    p = cell_path(arch, shape, mesh)
                    if os.path.exists(p) and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mesh,
                           "--quiet"]
                    print(f"[dryrun] {arch} x {shape} x {mesh} ...",
                          flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures += 1
                        print(r.stdout[-2000:], r.stderr[-4000:], flush=True)
        print(f"[dryrun] sweep done, {failures} failures")
        return 1 if failures else 0

    rec = {}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, opts=args.opts)
        rec["opts"] = args.opts
    except Exception as e:  # noqa: BLE001
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(cell_path(args.arch, args.shape, args.mesh, args.tag), "w") as f:
        json.dump(rec, f, indent=1)
    if not args.quiet:
        print(json.dumps({k: v for k, v in rec.items()
                          if k != "traceback"}, indent=1))
    if rec.get("status") == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
