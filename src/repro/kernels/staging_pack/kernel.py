"""staging_pack — egress pack (+ optional int8 quantize) Pallas TPU kernel.

The paper's RDMA *block* becomes a VMEM-resident tile: the kernel re-tiles a
2D tensor into block-major layout so every transfer block is contiguous in
HBM (one DMA descriptor per block on egress), optionally fusing symmetric
int8 quantization (per-block scale) — the paper's §6 "data reduction at
staging", pushed all the way into the producing chip.

Tile shape obeys TPU packing: lanes = 128, sublanes a multiple of
32 bytes / itemsize. Grid = (rows/TR, cols/TC); out block n = i·ncols + j.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref, s_ref, *, quantize: bool):
    x = x_ref[...]
    tr, tc = x.shape
    if quantize:
        x32 = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x32))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(x32 / scale), -127, 127)
        o_ref[...] = q.astype(o_ref.dtype).reshape(1, tr * tc)
        s_ref[0, 0] = scale
    else:
        o_ref[...] = x.astype(o_ref.dtype).reshape(1, tr * tc)
        s_ref[0, 0] = jnp.float32(1.0)


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype", "interpret"))
def pack_blocks(x: jax.Array, *, tile: tuple[int, int] = (256, 128),
                out_dtype=None, interpret: bool = False):
    """x: (R, C) with R % tile[0] == 0 == C % tile[1] (ops.py pads).

    Returns (blocks (n_blocks, TR*TC) out_dtype, scales (n_blocks,) f32).
    out_dtype int8 -> fused quantization.
    """
    R, C = x.shape
    TR, TC = tile
    assert R % TR == 0 and C % TC == 0, (x.shape, tile)
    ni, nj = R // TR, C // TC
    out_dtype = out_dtype or x.dtype
    quantize = jnp.dtype(out_dtype) == jnp.int8

    blocks, scales = pl.pallas_call(
        functools.partial(_pack_kernel, quantize=quantize),
        grid=(ni, nj),
        in_specs=[pl.BlockSpec((TR, TC), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((1, TR * TC), lambda i, j, nj=nj: (i * nj + j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, nj=nj: (i * nj + j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ni * nj, TR * TC), out_dtype),
            jax.ShapeDtypeStruct((ni * nj, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return blocks, scales[:, 0]
