"""Pure-jnp oracle for staging_pack."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_blocks_ref(x: jax.Array, *, tile: tuple[int, int] = (256, 128),
                    out_dtype=None):
    R, C = x.shape
    TR, TC = tile
    ni, nj = R // TR, C // TC
    out_dtype = out_dtype or x.dtype
    # block-major re-tiling
    t = x.reshape(ni, TR, nj, TC).transpose(0, 2, 1, 3).reshape(ni * nj, TR * TC)
    if jnp.dtype(out_dtype) == jnp.int8:
        t32 = t.astype(jnp.float32)
        amax = jnp.max(jnp.abs(t32), axis=1)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(t32 / scale[:, None]), -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)
    return t.astype(out_dtype), jnp.ones((ni * nj,), jnp.float32)


def unpack_blocks_ref(blocks: jax.Array, scales: jax.Array, shape,
                      tile: tuple[int, int] = (256, 128), dtype=jnp.float32):
    R, C = shape
    TR, TC = tile
    ni, nj = R // TR, C // TC
    t = blocks.astype(jnp.float32)
    if blocks.dtype == jnp.int8:
        t = t * scales[:, None]
    return (t.reshape(ni, nj, TR, TC).transpose(0, 2, 1, 3)
            .reshape(R, C).astype(dtype))
