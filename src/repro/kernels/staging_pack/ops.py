"""jit'd public wrapper: arbitrary-shape arrays -> contiguous egress blocks.

impl="pallas" targets TPU (validated with interpret=True on CPU);
impl="xla" is the lowering used by the CPU dry-run. Block size in *bytes*
is the paper's knob; `tile_for_block` converts it to a VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import vmem_tile
from repro.kernels.staging_pack import kernel, ref


def tile_for_block(block_bytes: int, dtype) -> tuple[int, int]:
    d = jnp.dtype(dtype)
    return vmem_tile(block_bytes // d.itemsize, d.itemsize)


def _to_2d(x: jax.Array, tc: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    cols = tc
    pad = (-flat.size) % cols
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, cols), pad


@functools.partial(jax.jit,
                   static_argnames=("block_bytes", "out_dtype", "impl",
                                    "interpret"))
def pack(x: jax.Array, *, block_bytes: int = 4 << 20,
         out_dtype=None, impl: str = "xla",
         interpret: bool = False):
    """Pack any-shape array into (n_blocks, block_elems) + scales."""
    out_dtype = out_dtype or x.dtype
    tr, tc = tile_for_block(block_bytes, out_dtype)
    x2, _ = _to_2d(x, tc)
    rpad = (-x2.shape[0]) % tr
    if rpad:
        x2 = jnp.pad(x2, ((0, rpad), (0, 0)))
    if impl == "pallas":
        return kernel.pack_blocks(x2, tile=(tr, tc), out_dtype=out_dtype,
                                  interpret=interpret)
    return ref.pack_blocks_ref(x2, tile=(tr, tc), out_dtype=out_dtype)


def unpack(blocks: jax.Array, scales: jax.Array, shape: tuple[int, ...],
           *, block_bytes: int = 4 << 20, dtype=jnp.float32) -> jax.Array:
    """Inverse of pack (host/analysis side). The lane width comes from
    `tile_for_block` on the *packed* dtype — the same computation pack
    used — so round-trips survive `vmem_tile` picking a non-128 lane
    width; rows-per-block is recovered from the packed shape, which keeps
    unpack independent of the exact `block_bytes` pack was called with."""
    _, tc = tile_for_block(block_bytes, blocks.dtype)
    if blocks.shape[1] % tc:
        raise ValueError(
            f"blocks have {blocks.shape[1]} elems/block, not a multiple "
            f"of the {tc}-lane tile width for dtype {blocks.dtype}")
    tr = blocks.shape[1] // tc
    n = int(np.prod(shape))
    rows = -(-n // tc)
    rows += (-rows) % tr
    full = ref.unpack_blocks_ref(blocks, scales, (rows, tc), (tr, tc), dtype)
    return full.reshape(-1)[:n].reshape(shape)


def quantize_blocks(x: jax.Array, *, block_elems: int = 4096,
                    impl: str = "xla", interpret: bool = False):
    """Egress-codec quantizing variant: flatten, pad to `block_elems`, and
    emit `(n_blocks, block_elems)` int8 plus one f32 amax/127 scale per
    block.  Blocks cover *consecutive flat elements* (the column grid is a
    single tile wide), matching the int8-block codec's host layout, so the
    device->host copy moves int8 + scales instead of full-width floats.
    """
    if block_elems % 128:
        raise ValueError(f"block_elems must be a multiple of 128 lanes, "
                         f"got {block_elems}")
    tc = 128
    tr = block_elems // tc
    n = int(x.size)
    nb = -(-n // block_elems)
    if n == 0:
        return (jnp.zeros((0, block_elems), jnp.int8),
                jnp.zeros((0,), jnp.float32))
    flat = x.reshape(-1)
    pad = nb * block_elems - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    x2 = flat.reshape(nb * tr, tc)
    if impl == "pallas":
        return kernel.pack_blocks(x2, tile=(tr, tc), out_dtype=jnp.int8,
                                  interpret=interpret)
    return ref.pack_blocks_ref(x2, tile=(tr, tc), out_dtype=jnp.int8)


def dequantize_blocks(blocks: jax.Array, scales: jax.Array, n: int, *,
                      dtype=jnp.float32) -> jax.Array:
    """Inverse of `quantize_blocks` (flat, truncated to `n` elements)."""
    t = blocks.astype(jnp.float32) * scales[:, None]
    return t.reshape(-1)[:n].astype(dtype)
