"""jit'd public wrapper: arbitrary-shape arrays -> contiguous egress blocks.

impl="pallas" targets TPU (validated with interpret=True on CPU);
impl="xla" is the lowering used by the CPU dry-run. Block size in *bytes*
is the paper's knob; `tile_for_block` converts it to a VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import vmem_tile
from repro.kernels.staging_pack import kernel, ref


def tile_for_block(block_bytes: int, dtype) -> tuple[int, int]:
    d = jnp.dtype(dtype)
    return vmem_tile(block_bytes // d.itemsize, d.itemsize)


def _to_2d(x: jax.Array, tc: int) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    cols = tc
    pad = (-flat.size) % cols
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, cols), pad


@functools.partial(jax.jit,
                   static_argnames=("block_bytes", "out_dtype", "impl",
                                    "interpret"))
def pack(x: jax.Array, *, block_bytes: int = 4 << 20,
         out_dtype=None, impl: str = "xla",
         interpret: bool = False):
    """Pack any-shape array into (n_blocks, block_elems) + scales."""
    out_dtype = out_dtype or x.dtype
    tr, tc = tile_for_block(block_bytes, out_dtype)
    x2, _ = _to_2d(x, tc)
    rpad = (-x2.shape[0]) % tr
    if rpad:
        x2 = jnp.pad(x2, ((0, rpad), (0, 0)))
    if impl == "pallas":
        return kernel.pack_blocks(x2, tile=(tr, tc), out_dtype=out_dtype,
                                  interpret=interpret)
    return ref.pack_blocks_ref(x2, tile=(tr, tc), out_dtype=out_dtype)


def unpack(blocks: jax.Array, scales: jax.Array, shape: tuple[int, ...],
           *, block_bytes: int = 4 << 20, dtype=jnp.float32) -> jax.Array:
    """Inverse of pack (host/analysis side). Tile geometry is recovered
    from the block array itself (TC is always the 128-lane width)."""
    del block_bytes
    tc = 128
    tr = blocks.shape[1] // tc
    n = int(np.prod(shape))
    rows = -(-n // tc)
    rows += (-rows) % tr
    full = ref.unpack_blocks_ref(blocks, scales, (rows, tc), (tr, tc), dtype)
    return full.reshape(-1)[:n].reshape(shape)
