"""Pure-jnp oracle for ssm_scan (naive per-step recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(xi, dt, Bm, Cm, A, h0):
    """Same contract as kernel.ssm_scan, step-by-step in fp32."""
    def step(h, t):
        xi_t, dt_t, b_t, c_t = t
        dt32 = dt_t.astype(jnp.float32)
        decay = jnp.exp(dt32[:, :, None] * A)
        h = decay * h + (dt32 * xi_t.astype(jnp.float32))[:, :, None] \
            * b_t.astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y.astype(xi.dtype)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xi, dt, Bm, Cm))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
