"""Public wrapper with padding + impl dispatch (pallas | xla).

impl="xla" = the chunked two-level lax.scan from repro.models.ssm (what the
dry-run lowers); impl="pallas" = the VMEM-resident TPU kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import kernel, ref


@functools.partial(jax.jit, static_argnames=("chunk", "d_tile", "impl",
                                             "interpret"))
def selective_scan(xi, dt, Bm, Cm, A, h0, *, chunk: int = 64,
                   d_tile: int = 512, impl: str = "pallas",
                   interpret: bool = False):
    """xi, dt: (B,S,di); Bm, Cm: (B,S,N); A: (di,N); h0: (B,di,N)."""
    if impl == "xla":
        from repro.models.ssm import selective_scan as xla_scan
        return xla_scan(xi, dt, Bm, Cm, A, h0, chunk=chunk)
    B, S, di = xi.shape
    spad = (-S) % chunk
    dpad = (-di) % min(d_tile, max(di, 128))
    d_tile = min(d_tile, di + dpad)
    if spad:  # dt=0 -> identity steps; y rows sliced off
        xi = jnp.pad(xi, ((0, 0), (0, spad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, spad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, spad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, spad), (0, 0)))
    if dpad:
        xi = jnp.pad(xi, ((0, 0), (0, 0), (0, dpad)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, dpad)))
        A = jnp.pad(A, ((0, dpad), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, dpad), (0, 0)))
    y, h = kernel.ssm_scan(xi, dt, Bm, Cm, A, h0, chunk=chunk,
                           d_tile=d_tile, interpret=interpret)
    return y[:, :S, :di], h[:, :di]
