"""Selective-scan (mamba1 recurrence) — Pallas TPU kernel.

h_t = exp(dt_t·A) ⊙ h_{t-1} + (dt_t·x_t)·B_t ;  y_t = h_t · C_t

The state h (d_inner × d_state) stays resident in VMEM across the whole
sequence; the grid walks (batch, d_inner tiles) × sequence chunks with the
chunk axis innermost/sequential, so HBM traffic is exactly one read of the
inputs + one write of y (the XLA scan path re-materializes h per chunk
boundary). d_inner is tiled to the 128-lane width; d_state (16) rides the
sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(xi_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hout_ref,
                h_sc, *, chunk: int, n_chunks: int, d_tile: int, n_state: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                 # (d_tile, N)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)        # (d_tile,)
        xi_t = xi_ref[0, t].astype(jnp.float32)        # (d_tile,)
        b_t = b_ref[0, t].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)          # (N,)
        decay = jnp.exp(dt_t[:, None] * a)             # (d_tile, N)
        h = decay * h + (dt_t * xi_t)[:, None] * b_t[None, :]
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h_sc[...] = jax.lax.fori_loop(0, chunk, step, h_sc[...])

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hout_ref[0] = h_sc[...].astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "d_tile", "interpret"))
def ssm_scan(xi: jax.Array, dt: jax.Array, Bm: jax.Array, Cm: jax.Array,
             A: jax.Array, h0: jax.Array, *, chunk: int = 64,
             d_tile: int = 512, interpret: bool = False):
    """xi, dt: (B,S,di); Bm, Cm: (B,S,N); A: (di,N); h0: (B,di,N) f32.
    S % chunk == 0, di % d_tile == 0 (ops.py pads). Returns (y, h_last)."""
    B, S, di = xi.shape
    N = A.shape[1]
    assert S % chunk == 0 and di % d_tile == 0, (S, chunk, di, d_tile)
    n_chunks = S // chunk
    n_d = di // d_tile

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks,
                               d_tile=d_tile, n_state=N)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, d, c: (b, c, d)),  # xi
            pl.BlockSpec((1, chunk, d_tile), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),       # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),       # C
            pl.BlockSpec((d_tile, N), lambda b, d, c: (d, 0)),            # A
            pl.BlockSpec((1, d_tile, N), lambda b, d, c: (b, d, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_tile), lambda b, d, c: (b, c, d)),  # y
            pl.BlockSpec((1, d_tile, N), lambda b, d, c: (b, d, 0)),      # h
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), xi.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_tile, N), jnp.float32)],
        interpret=interpret,
    )(xi, dt, Bm, Cm, A, h0)
    return y, h_last
