# Pallas TPU kernels (validated with interpret=True on CPU; the XLA twins
# in repro.models.* are what the CPU dry-run lowers):
#   staging_pack    — egress block pack + fused int8 quantize (paper's block
#                     knob as a BlockSpec tile; §6 data reduction)
#   flash_attention — online-softmax prefill kernel, GQA via index_map,
#                     causal block skip, window + softcap
#   ssm_scan        — mamba1 selective scan, VMEM-resident state
