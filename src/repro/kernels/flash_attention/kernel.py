"""Flash attention (prefill forward) — Pallas TPU kernel.

Online-softmax attention with VMEM-resident (m, l, acc) carry, GQA via
BlockSpec index_map (kv block = q head // group — no KV repeat in HBM),
causal block skipping (fully-masked kv tiles are not computed — the FLOPs
the XLA rectangle path wastes), optional sliding window and logit softcap
(gemma2). MXU-aligned tiles: (block_q, d) x (d, block_k).

Grid = (B*Hq, Sq/block_q, Sk/block_k); the kv axis is innermost and
sequential — the carry lives in VMEM scratch across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, softcap: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: causal (kv entirely in the future) or out-of-window
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window:
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 >= q_start - window + 1) \
            if causal else needed

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("groups", "scale", "softcap", "causal",
                              "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    groups: int = 1, scale: float = 1.0,
                    softcap: float = 0.0, causal: bool = True,
                    window: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q: (BHq, Sq, D); k/v: (BHkv, Sk, D) with BHq = BHkv * groups.
    Layout: head-major (b*Hq + h), so kv index = q index // groups works
    only when heads are outer dim per batch -> ops.py flattens as
    (B, H, S, D) -> (B*H, S, D) and passes groups=Hq//Hkv. Returns (BHq, Sq, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, softcap=softcap, causal=causal,
        window=window, block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // groups, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // groups, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
