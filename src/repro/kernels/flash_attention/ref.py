"""Pure-jnp oracle for flash_attention (dense softmax attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  groups: int = 1, scale: float = 1.0, softcap: float = 0.0,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Same layout/semantics as kernel.flash_attention."""
    if groups > 1:
        k = jnp.repeat(k, groups, axis=0)
        v = jnp.repeat(v, groups, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    Sq, Sk = q.shape[1], k.shape[1]
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
