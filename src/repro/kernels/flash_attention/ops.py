"""Public wrapper: (B,S,H,D)-layout GQA attention with impl dispatch.

impl="pallas": the TPU flash kernel (use interpret=True on CPU).
impl="xla":    the chunked-flash XLA path from repro.models.attention —
               what the dry-run lowers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


def _to_bh(x: jax.Array) -> jax.Array:  # (B,S,H,D) -> (B*H, S, D)
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _from_bh(x: jax.Array, B: int) -> jax.Array:
    BH, S, D = x.shape
    H = BH // B
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "causal",
                                             "window", "impl", "block_q",
                                             "block_k", "interpret"))
def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: float = 0.0, softcap: float = 0.0,
                  causal: bool = True, window: int = 0,
                  impl: str = "pallas", block_q: int = 512,
                  block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B,S,Hq,D); k/v: (B,S,Hkv,D). Returns (B,S,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    groups = Hq // Hkv
    scale = scale or D ** -0.5
    if impl == "xla":
        from repro.models.attention import flash_attention_xla, make_mask_fn, \
            local_attention_xla
        qg = q.reshape(B, Sq, Hkv, groups, D)
        if window:
            o = local_attention_xla(qg, k, v, window=window, scale=scale,
                                    cap=softcap)
        else:
            o = flash_attention_xla(
                qg, k, v, mask_fn=make_mask_fn(causal=causal, window=0,
                                               prefix=0),
                scale=scale, cap=softcap, chunk_q=block_q, chunk_k=block_k)
        return o.reshape(B, Sq, Hq, D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sq)
    o = kernel.flash_attention(
        _to_bh(q), _to_bh(k), _to_bh(v), groups=groups, scale=scale,
        softcap=softcap, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=interpret)
    return _from_bh(o, B)


def gqa_attention_ref(q, k, v, *, scale=0.0, softcap=0.0, causal=True,
                      window=0):
    B, Sq, Hq, D = q.shape
    groups = Hq // k.shape[2]
    scale = scale or D ** -0.5
    o = ref.attention_ref(_to_bh(q), _to_bh(k), _to_bh(v), groups=groups,
                          scale=scale, softcap=softcap, causal=causal,
                          window=window)
    return _from_bh(o, B)
