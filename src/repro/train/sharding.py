"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §4).

DP over (pod, data); TP/EP over model; SP (sequence-sharded KV cache) over
data for long-context decode. Rules are arch-aware: axes whose size does
not divide the mesh axis are replicated when padding would be degenerate
(e.g. MQA kv_heads=1), otherwise GSPMD pads (recorded in the roofline
useful-FLOPs ratio; a §Perf lever).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(mesh, cfg=None, *, seq_shard_kv: bool = False,
               global_batch: int = 0) -> dict[str, Any]:
    model_n = mesh.shape.get("model", 1)
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    if global_batch and global_batch % dp_total:
        dp_rule: Any = None   # batch=1 long-context decode: replicate batch,
                              # parallelism comes from SP (kv_seq) + model
    else:
        dp_rule = dp if len(dp) > 1 else (dp[0] if dp else None)

    def maybe_model(size: Optional[int], min_per_shard: int = 1):
        """Shard over model only when evenly divisible (pjit argument
        shardings require it); else replicate."""
        if size is None or (size % model_n == 0
                            and size >= model_n * min_per_shard):
            return "model"
        return None

    kv = cfg.n_kv_heads if cfg is not None else None
    return {
        "batch": dp_rule,
        "vocab": "model",
        "embed": None,
        # attention projections are stored flattened (H*D divisible by 16
        # for every assigned arch) -> TP always shards them
        "qkv": "model",
        "kv_flat": "model",
        # per-head axes appear only on caches/activations: shard when a
        # whole head fits per shard (MQA caches replicate — tiny anyway)
        "heads": maybe_model(cfg.n_heads if cfg is not None else None),
        "heads_padded": maybe_model(
            max(cfg.pad_heads_to, cfg.n_heads) if cfg is not None else None),
        "kv_heads": maybe_model(kv),
        "head_dim": None,
        "ffn": "model",
        "expert": "model",
        "expert_ffn": None,
        "capacity": None,
        "inner": "model",
        "rnn": "model",
        "state": None,
        "conv": None,
        "dt": None,
        "layers": None,
        # decode-time KV cache sequence axis: sharded over `data` for
        # long-context (SP decode; batch=1 cannot use DP), else replicated
        "kv_seq": "data" if seq_shard_kv else None,
    }


def batch_shardings(mesh, rules, batch: dict) -> dict:
    dp = rules["batch"]
    out = {}
    for k, v in batch.items():
        spec = [dp] + [None] * (v.ndim - 1)
        out[k] = jax.sharding.NamedSharding(mesh, P(*spec))
    return out


def replicated(mesh):
    return jax.sharding.NamedSharding(mesh, P())
