"""Serving steps: prefill (builds the KV/state cache) and decode (one token
against a seq_len cache) — what the inference dry-run shapes lower.

long-context decode uses seq-sharded global KV caches (seq_shard_kv=True):
batch=1 cannot use DP, so the `data` axis shards the cache sequence dim and
GSPMD turns the softmax reductions into the SP all-reduces (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import abstract_params, param_shardings
from repro.models.model import Model
from repro.train.sharding import batch_shardings, make_rules


class ServeSetup:
    def __init__(self, model: Model, mesh, *, seq_shard_kv: bool = False,
                 global_batch: int = 0):
        self.model = model
        self.mesh = mesh
        self.rules = make_rules(mesh, model.cfg, seq_shard_kv=seq_shard_kv,
                                global_batch=global_batch)

    def param_shardings(self):
        return param_shardings(self.model.param_specs(), self.mesh,
                               self.rules)

    def cache_shardings(self, B: int, T: int):
        return param_shardings(self.model.cache_specs(B, T), self.mesh,
                               self.rules)

    def abstract_cache(self, B: int, T: int):
        return self.model.abstract_cache(B, T)

    # -- steps -------------------------------------------------------------
    def prefill_fn(self, max_len: int = 0) -> Callable:
        def prefill(params, batch: dict):
            logits, cache = self.model.prefill(
                params, batch["tokens"], self.rules,
                prefix_embed=batch.get("prefix_embed"), max_len=max_len)
            return logits, cache
        return prefill

    def decode_fn(self) -> Callable:
        def decode(params, cache, batch: dict):
            logits, new_cache = self.model.decode_step(
                params, batch["tokens"], batch["pos"], cache, self.rules)
            return logits, new_cache
        return decode

    def jitted_prefill(self, B: int, S: int, max_len: int = 0):
        ps = self.param_shardings()
        cs = self.cache_shardings(B, max_len or S)
        from repro.configs import input_specs  # noqa: F401 (callers use it)
        return jax.jit(self.prefill_fn(max_len),
                       in_shardings=(ps, None),
                       out_shardings=(None, cs))

    def jitted_decode(self, B: int, T: int):
        ps = self.param_shardings()
        cs = self.cache_shardings(B, T)
        return jax.jit(self.decode_fn(),
                       in_shardings=(ps, cs, None),
                       out_shardings=(None, cs),
                       donate_argnums=(1,))
