"""Distributed train step: pjit + logical sharding rules, ZeRO-1 optimizer,
optional compressed cross-pod gradient reduction, optional in-step egress
packing for the in-transit sink (the paper's producer side).

The returned `step_fn` is jit'd with explicit in/out shardings and state
donation; `abstract_state()` + `repro.configs.input_specs` are everything
the multi-pod dry-run needs (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import abstract_params, init_params, param_shardings
from repro.models.model import Model
from repro.optim import grad_compress
from repro.optim.optimizer import AdamWConfig, make_optimizer, opt_state_specs
from repro.optim.schedule import warmup_cosine
from repro.train.sharding import batch_shardings, make_rules

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_pods: bool = False      # int8 EF cross-pod gradient reduction
    egress: str = "diag"             # none | diag | grads_int8
    egress_blocks: int = 64          # int8 blocks sampled for egress
    xent_chunk: int = 512
    microbatches: int = 1            # gradient accumulation (activation
                                     # memory / microbatches; grads fp32)
    fsdp_experts: bool = False       # shard expert ffn dim over `data`
                                     # (FSDP: per-layer weight all-gather;
                                     # required for 400B+ MoE to fit HBM)


class TrainSetup:
    def __init__(self, model: Model, mesh, cfg: TrainConfig = TrainConfig()):
        self.model = model
        self.mesh = mesh
        self.cfg = cfg
        rules = make_rules(mesh, model.cfg)
        if cfg.fsdp_experts:
            rules["expert_ffn"] = "data"
        self.rules = dict(rules, __zero1__=rules["batch"])
        self.spec_tree = model.param_specs()
        self.opt_specs = opt_state_specs(self.spec_tree, cfg.opt, mesh,
                                         self.rules)
        self._init_opt, self._update = make_optimizer(
            self.spec_tree, cfg.opt, mesh, self.rules)
        self.compress = cfg.compress_pods and "pod" in mesh.axis_names \
            and mesh.shape["pod"] > 1

    # -- state ------------------------------------------------------------
    def state_specs(self) -> dict:
        from repro.models.layers import ParamSpec
        s = {"params": self.spec_tree, "opt": self.opt_specs,
             "step": ParamSpec((), (), jnp.int32, init="zeros")}
        if self.compress:
            n_pods = self.mesh.shape["pod"]
            err = grad_compress.error_state(
                abstract_params(self.spec_tree), n_pods)
            s["err"] = ParamSpec((n_pods, *err.shape),
                                 ("__pod__", None, None), jnp.float32,
                                 init="zeros")
        return s

    def state_shardings(self) -> dict:
        rules = dict(self.rules, __pod__="pod")
        return param_shardings(self.state_specs(), self.mesh, rules)

    def abstract_state(self) -> dict:
        return abstract_params(self.state_specs())

    def init_state(self, key: jax.Array) -> dict:
        st = init_params(self.state_specs(), key)
        # params need real random init (init_params gave them random too)
        return st

    # -- the step -----------------------------------------------------------
    def _loss(self, params: PyTree, batch: dict):
        return self.model.loss_fn(params, batch, self.rules,
                                  xent_chunk=self.cfg.xent_chunk)

    def _egress(self, grads: PyTree, loss, gnorm):
        if self.cfg.egress == "none":
            return {}
        diag = jnp.stack([loss.astype(jnp.float32), gnorm])
        if self.cfg.egress == "diag":
            return {"diag": diag}
        # grads_int8: pack a fixed sample of gradient blocks through the
        # staging_pack XLA twin (the Pallas kernel is the TPU version)
        from repro.kernels.staging_pack import ref as pack_ref
        nb = self.cfg.egress_blocks
        flat = jnp.concatenate(
            [g.reshape(-1)[: nb * 1024].astype(jnp.float32)
             for g in jax.tree.leaves(grads)][:1])
        pad = (-flat.size) % (nb * 1024)
        flat = jnp.pad(flat, (0, pad)).reshape(nb * 8, 128)
        blocks, scales = pack_ref.pack_blocks_ref(
            flat, tile=(8, 128), out_dtype=jnp.int8)
        return {"diag": diag, "blocks": blocks, "scales": scales}

    def step_fn(self) -> Callable:
        cfg = self.cfg

        def train_step(state: dict, batch: dict):
            lr = warmup_cosine(state["step"], peak_lr=cfg.peak_lr,
                               warmup_steps=cfg.warmup_steps,
                               total_steps=cfg.total_steps)
            grad_fn = jax.value_and_grad(self._loss, has_aux=True)

            if self.compress:
                n_pods = self.mesh.shape["pod"]

                def body(params, batch_pod, err_pod):
                    (loss, metrics), grads = grad_fn(params, batch_pod)
                    # _flatten row-pads to a multiple of n_pods (ring RS
                    # needs n|rows), matching error_state's layout.
                    flat, pad = grad_compress._flatten(grads, n_pods)
                    red, new_err = _pod_reduce(flat, err_pod[0], n_pods)
                    loss = jax.lax.pmean(loss, "pod")
                    metrics = jax.tree.map(
                        lambda m: jax.lax.pmean(m, "pod"), metrics)
                    grads = grad_compress._unflatten(red, pad, grads)
                    return loss, metrics, grads, new_err[None]

                bspecs = jax.tree.map(lambda _: P("pod"), batch)
                loss, metrics, grads, new_err = jax.shard_map(
                    body, mesh=self.mesh,
                    in_specs=(P(), bspecs, P("pod")),
                    out_specs=(P(), jax.tree.map(lambda _: P(), _metric_tree()),
                               jax.tree.map(lambda _: P(),
                                            abstract_params(self.spec_tree)),
                               P("pod")),
                    axis_names={"pod"}, check_vma=False,
                )(state["params"], batch, state["err"])
            elif cfg.microbatches > 1:
                n = cfg.microbatches
                dp_rule = self.rules["batch"]

                def split(x):
                    mb = x.reshape(n, x.shape[0] // n, *x.shape[1:])
                    # keep DP on the per-micro batch dim — without this the
                    # contiguous reshape puts the DP shards on the MICRO
                    # axis and every device replicates the whole batch
                    spec = jax.sharding.PartitionSpec(
                        None, dp_rule, *([None] * (mb.ndim - 2)))
                    return jax.lax.with_sharding_constraint(
                        mb, jax.sharding.NamedSharding(self.mesh, spec))

                mbs = jax.tree.map(split, batch)
                # grad accumulator lives in the ZeRO-1 (moment) layout:
                # the DP reduction becomes reduce-scatter and the f32
                # buffer is 1/dp per device
                acc_sh = param_shardings(
                    self.opt_specs["mu"], self.mesh,
                    dict(self.rules, __zero1__=self.rules["batch"]))
                g0 = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32), s),
                    state["params"], acc_sh)
                m0 = (jnp.float32(0), _metric_tree())

                def micro(carry, mb):
                    acc_g, (acc_l, acc_m) = carry
                    (l, m), g = grad_fn(state["params"], mb)
                    acc_g = jax.tree.map(
                        lambda a, b, s: jax.lax.with_sharding_constraint(
                            a + b.astype(jnp.float32) / n, s),
                        acc_g, g, acc_sh)
                    acc_m = jax.tree.map(lambda a, b: a + b / n, acc_m, m)
                    return (acc_g, (acc_l + l / n, acc_m)), None

                (grads, (loss, metrics)), _ = jax.lax.scan(
                    micro, (g0, m0), mbs)
                new_err = None
            else:
                (loss, metrics), grads = grad_fn(state["params"], batch)
                new_err = None

            new_params, new_opt, stats = self._update(
                grads, state["opt"], state["params"], lr)
            metrics = {**metrics, **stats, "loss": loss, "lr": lr}
            egress = self._egress(grads, loss, stats["grad_norm"])
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1}
            if new_err is not None:
                new_state["err"] = new_err
            return new_state, metrics, egress

        return train_step

    def jitted(self, shape_cfg=None):
        sh = self.state_shardings()
        bs = None
        if shape_cfg is not None:
            from repro.configs import input_specs
            bs = batch_shardings(self.mesh, self.rules,
                                 input_specs(self.model.cfg, shape_cfg))
        return jax.jit(self.step_fn(),
                       in_shardings=(sh, bs),
                       out_shardings=(sh, None, None),
                       donate_argnums=(0,))


def _metric_tree():
    return {"nll": 0.0, "z2": 0.0, "moe_lb": 0.0, "moe_z": 0.0}


def _pod_reduce(flat: jax.Array, err: jax.Array, n_pods: int):
    """int8 ring reduce-scatter + all-gather over `pod` with error feedback
    (runs inside a shard_map manual over {pod})."""
    g = flat + err
    q, s = grad_compress._quant_blocks(g)
    new_err = g - q.astype(jnp.float32) * s[:, None]
    n_blocks = flat.shape[0]
    shard_rows = n_blocks // n_pods
    mine = jax.lax.axis_index("pod")

    def rows_of(qr, sr):
        r = jax.lax.dynamic_slice_in_dim(qr, mine * shard_rows, shard_rows, 0)
        c = jax.lax.dynamic_slice_in_dim(sr, mine * shard_rows, shard_rows, 0)
        return r.astype(jnp.float32) * c[:, None]

    acc = rows_of(q, s)
    qr, sr = q, s
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
    for _ in range(1, n_pods):
        qr = jax.lax.ppermute(qr, "pod", perm)        # int8 on the wire
        sr = jax.lax.ppermute(sr, "pod", perm)
        acc = acc + rows_of(qr, sr)
    acc = acc / n_pods
    qa, sa = grad_compress._quant_blocks(acc)
    q_all = jax.lax.all_gather(qa, "pod", axis=0, tiled=True)
    s_all = jax.lax.all_gather(sa, "pod", axis=0, tiled=True)
    return q_all.astype(jnp.float32) * s_all[:, None], new_err
