from repro.train.sharding import make_rules  # noqa: F401
from repro.train.train_step import TrainConfig, TrainSetup  # noqa: F401
from repro.train.serve_step import ServeSetup  # noqa: F401
