"""Public model API: init / loss / prefill / decode for any ArchConfig.

Loss uses sequence-chunked cross-entropy (never materializes the full
(B,S,V) logits — V is up to 262k) with the unembed recomputed in backward
(jax.checkpoint around the chunk body).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    abstract_params, init_params, param_shardings, softcap, unembed,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params -----------------------------------------------------------
    def param_specs(self) -> PyTree:
        return tfm.transformer_specs(self.cfg)

    def abstract_params(self) -> PyTree:
        return abstract_params(self.param_specs())

    def init(self, key: jax.Array) -> PyTree:
        return init_params(self.param_specs(), key)

    def param_shardings(self, mesh, rules: dict) -> PyTree:
        return param_shardings(self.param_specs(), mesh, rules)

    # -- caches -----------------------------------------------------------
    def cache_specs(self, B: int, T: int) -> PyTree:
        return tfm.cache_specs(self.cfg, B, T)

    def abstract_cache(self, B: int, T: int) -> PyTree:
        return abstract_params(self.cache_specs(B, T))

    def init_cache(self, B: int, T: int) -> PyTree:
        # zeros/neg-ones init — deterministic, key unused
        return init_params(self.cache_specs(B, T), jax.random.PRNGKey(0))

    def cache_shardings(self, B: int, T: int, mesh, rules: dict) -> PyTree:
        return param_shardings(self.cache_specs(B, T), mesh, rules)

    # -- forward ----------------------------------------------------------
    def loss_fn(self, params: PyTree, batch: dict, rules: dict,
                xent_chunk: int = 512):
        """batch: tokens/targets/loss_mask (B,S) [+ prefix_embed]. Returns
        (loss, metrics)."""
        cfg = self.cfg
        hidden, aux, _ = tfm.apply_transformer(
            params, batch["tokens"], cfg=cfg, rules=rules,
            prefix_embed=batch.get("prefix_embed"))
        if cfg.n_prefix and "prefix_embed" in batch:
            hidden = hidden[:, cfg.n_prefix:]  # loss on text positions only
        nll, z2 = _chunked_xent(params, hidden, batch["targets"],
                                batch["loss_mask"], cfg, xent_chunk)
        loss = nll + 1e-4 * z2 + 1e-2 * aux["moe_lb"] + 1e-3 * aux["moe_z"]
        metrics = {"nll": nll, "z2": z2, **aux}
        return loss, metrics

    def prefill(self, params: PyTree, tokens: jax.Array,
                rules: dict, prefix_embed: Optional[jax.Array] = None,
                max_len: int = 0):
        """Returns (last_token_logits (B,V), cache). max_len = cache
        capacity (>= prefill length; gives decode headroom)."""
        hidden, _, cache = tfm.apply_transformer(
            params, tokens, cfg=self.cfg, rules=rules,
            prefix_embed=prefix_embed, return_cache=True, cache_len=max_len)
        logits = tfm.logits_from_hidden(params, hidden[:, -1:], self.cfg)
        return logits[:, 0], cache

    def decode_step(self, params: PyTree, tokens: jax.Array, pos: jax.Array,
                    cache: PyTree, rules: dict):
        """tokens: (B,1); pos: (B,). Returns (logits (B,V), new_cache)."""
        hidden, _, new_cache = tfm.apply_transformer(
            params, tokens, cfg=self.cfg, rules=rules,
            positions=pos[:, None], cache=cache)
        logits = tfm.logits_from_hidden(params, hidden, self.cfg)
        return logits[:, 0], new_cache


def _chunked_xent(params, hidden, targets, mask, cfg, chunk: int):
    """Sequence-chunked masked cross-entropy + z-loss term.

    hidden: (B,S,M); targets/mask: (B,S). Unembed is recomputed in backward.
    """
    B, S, M = hidden.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (S + pad) // c
    hc = jnp.moveaxis(hidden.reshape(B, n, c, M), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, n, c), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, c), 1, 0)

    @jax.checkpoint
    def chunk_fn(carry, xs):
        h, t, m = xs
        lg = unembed(params["embed"], h, cfg.tie_embeddings)
        lg = softcap(lg, cfg.logit_softcap).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)                      # (B,c)
        tgt = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        nll_sum, z2_sum, m_sum = carry
        nll_sum = nll_sum + jnp.sum((logz - tgt) * m)
        z2_sum = z2_sum + jnp.sum(jnp.square(logz) * m)
        return (nll_sum, z2_sum, m_sum + jnp.sum(m)), None

    (nll_sum, z2_sum, m_sum), _ = jax.lax.scan(
        chunk_fn, (jnp.float32(0), jnp.float32(0), jnp.float32(0)),
        (hc, tc, mc))
    denom = jnp.maximum(m_sum, 1.0)
    return nll_sum / denom, z2_sum / denom


@functools.lru_cache(maxsize=None)
def get_model(arch: str) -> Model:
    from repro.configs import get_config
    return Model(get_config(arch))
