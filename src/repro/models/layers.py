"""Parameter machinery + shared layers (pure-pytree, no flax).

Every parameter is declared once as a ``ParamSpec`` carrying shape, dtype,
logical axis names and an initializer. From the same spec tree we derive:
  * materialized params         (init_params)
  * ShapeDtypeStruct stand-ins  (abstract_params — dry-run, no allocation)
  * NamedShardings              (param_shardings via logical->mesh rules)

Logical axis vocabulary (see rules in train/sharding.py):
  batch seq embed vocab heads kv_heads head_dim qkv ffn
  expert capacity rnn inner state conv dt layers
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical_axes: tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"   # normal | zeros | ones | conv | a_log
    scale: float = 1.0     # fan-in style scale multiplier for "normal"
    # zero the tail of one axis (inert padded attention heads):
    zero_from: Optional[tuple[int, int]] = None   # (axis, start_index)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "neg_ones":
            return jnp.full(self.shape, -1, self.dtype)
        if self.init == "a_log":  # mamba A init: log(1..d_state) per channel
            d_state = self.shape[-1]
            a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), self.shape[:-1] + (1,))
            return jnp.log(a).astype(self.dtype)
        # truncated-normal, fan-in scaled
        fan_in = self.shape[0] if len(self.shape) >= 2 else max(self.shape[-1], 1)
        std = self.scale / np.sqrt(fan_in)
        arr = (std * jax.random.truncated_normal(
            key, -2.0, 2.0, self.shape)).astype(self.dtype)
        if self.zero_from is not None:
            ax, start = self.zero_from
            idx = [slice(None)] * len(self.shape)
            idx[ax] = slice(start, None)
            arr = arr.at[tuple(idx)].set(0)   # inert padded heads stay 0
        return arr

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [s.materialize(k) for s, k in zip(leaves, keys)])


def abstract_params(spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.abstract(), spec_tree, is_leaf=is_spec)


def param_logical_axes(spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.logical_axes, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree: PyTree, n: int) -> PyTree:
    """Prepend a scanned `layers` axis of length n to every spec."""
    def f(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            s, shape=(n, *s.shape), logical_axes=("layers", *s.logical_axes)
        )
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Logical-axis sharding
# ---------------------------------------------------------------------------


def logical_to_pspec(axes: Sequence[Optional[str]], rules: dict[str, Any]) -> jax.sharding.PartitionSpec:
    return jax.sharding.PartitionSpec(*[rules.get(a) if a else None for a in axes])


def param_shardings(spec_tree: PyTree, mesh, rules: dict[str, Any]) -> PyTree:
    def f(s: ParamSpec):
        return jax.sharding.NamedSharding(mesh, logical_to_pspec(s.logical_axes, rules))
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


def constrain(x: jax.Array, rules: dict[str, Any], *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axis names (no-op outside a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, logical_to_pspec(axes, rules))
    except (ValueError, RuntimeError):
        return x  # no mesh context (pure-CPU smoke path)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float, plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (y * s).astype(dt)


def rms_norm_spec(dim: int, plus_one: bool = False) -> ParamSpec:
    return ParamSpec((dim,), ("embed",), init="zeros" if plus_one else "ones")


_LOWP_COLLECTIVES = False  # set via lowp_collectives(); read at trace time


def lowp_collectives(enabled: bool = True):
    """Context manager: emit TP-contraction outputs in the compute dtype so
    GSPMD's partial-sum all-reduces ride the wire in bf16 instead of the
    dot's f32 accumulator (per-shard accumulation stays f32 inside the MXU;
    only the cross-shard reduction is bf16 — standard Megatron practice).
    Halves the dominant collective bytes (§Perf hillclimb)."""
    import contextlib

    @contextlib.contextmanager
    def _ctx():
        global _LOWP_COLLECTIVES
        prev = _LOWP_COLLECTIVES
        _LOWP_COLLECTIVES = enabled
        try:
            yield
        finally:
            _LOWP_COLLECTIVES = prev

    return _ctx()


def prefer_dtype(dt):
    return dt if _LOWP_COLLECTIVES else None


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...m,mn->...n", x, w.astype(x.dtype),
                   preferred_element_type=prefer_dtype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x


ACTS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}


# -- MLP --------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int, glu: bool, pdt) -> dict[str, ParamSpec]:
    specs = {
        "wi": ParamSpec((d_model, d_ff), ("embed", "ffn"), pdt),
        "wo": ParamSpec((d_ff, d_model), ("ffn", "embed"), pdt),
    }
    if glu:
        specs["wg"] = ParamSpec((d_model, d_ff), ("embed", "ffn"), pdt)
    return specs


def mlp(params: dict, x: jax.Array, act: str, rules: dict) -> jax.Array:
    h = dense(x, params["wi"])
    h = constrain(h, rules, "batch", None, "ffn")
    a = ACTS[act](h)
    if "wg" in params:
        a = a * dense(x, params["wg"])
    y = dense(a, params["wo"])
    return constrain(y, rules, "batch", None, None)


# -- RoPE -------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # (B,S,half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (B,S,1,half)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# -- Embedding --------------------------------------------------------------


def embed_specs(vocab: int, d_model: int, tie: bool, pdt) -> dict[str, ParamSpec]:
    specs = {"table": ParamSpec((vocab, d_model), ("vocab", "embed"), pdt, scale=1.0)}
    if not tie:
        specs["head"] = ParamSpec((d_model, vocab), ("embed", "vocab"), pdt)
    return specs


def embed(params: dict, tokens: jax.Array, scale: bool, dtype) -> jax.Array:
    x = params["table"].astype(dtype)[tokens]
    if scale:
        x = x * jnp.asarray(np.sqrt(params["table"].shape[1]), dtype)
    return x


def unembed(params: dict, x: jax.Array, tie: bool) -> jax.Array:
    w = params["table"].T if tie else params["head"]
    return jnp.einsum("...m,mv->...v", x, w.astype(x.dtype))
