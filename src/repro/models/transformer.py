"""Decoder-stack assembly for all 10 assigned architectures.

Heterogeneous layer stacks (gemma2 LG, gemma3 LLLLLG, recurrentgemma RRA) are
scanned over *periods*: the scan body unrolls one period of distinct layer
kinds, the scan runs n_layers // period times, remainder layers run unrolled.
This keeps HLO size ~constant in depth (critical for the 80-compile dry-run)
and bounds live activations to one period (+remat policy).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import kvcache, moe as moe_lib, rglru as rglru_lib, ssm as ssm_lib
from repro.models.layers import (
    ParamSpec, constrain, embed, embed_specs, mlp, mlp_specs, rms_norm,
    rms_norm_spec, softcap, stack_specs, unembed,
)

AUX0 = {"moe_lb": 0.0, "moe_z": 0.0}


def _key(i: int, kind: str) -> str:
    return f"{i}:{kind}"


def _plan(cfg) -> tuple[int, int]:
    """(n_scan_periods, n_remainder_layers)."""
    p = len(cfg.layer_pattern)
    n_scan = cfg.n_layers // p if cfg.scan_layers else 0
    if n_scan < 2:
        n_scan = 0
    return n_scan, cfg.n_layers - n_scan * p


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def block_specs(cfg, kind: str) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    plus = cfg.scale_embeddings  # gemma-family (1+w) norm convention
    s: dict[str, Any] = {"ln1": rms_norm_spec(cfg.d_model, plus)}
    if kind in ("dense", "global", "local", "moe"):
        s["attn"] = attn_lib.attn_specs(cfg)
        s["ln2"] = rms_norm_spec(cfg.d_model, plus)
        if kind == "moe":
            s["moe"] = moe_lib.moe_specs(cfg)
        else:
            s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_glu, pdt)
        if cfg.post_norms:
            s["ln1_post"] = rms_norm_spec(cfg.d_model, plus)
            s["ln2_post"] = rms_norm_spec(cfg.d_model, plus)
    elif kind == "mamba":
        s["mamba"] = ssm_lib.mamba_specs(cfg)
    elif kind == "rglru":
        s["rglru"] = rglru_lib.rglru_specs(cfg)
        s["ln2"] = rms_norm_spec(cfg.d_model, plus)
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_glu, pdt)
    else:
        raise ValueError(kind)
    return s


def transformer_specs(cfg) -> dict:
    n_scan, n_rem = _plan(cfg)
    pat = cfg.layer_pattern
    specs: dict[str, Any] = {
        "embed": embed_specs(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings,
                             jnp.dtype(cfg.param_dtype)),
        "final_ln": rms_norm_spec(cfg.d_model, cfg.scale_embeddings),
        "scan": {_key(i, k): stack_specs(block_specs(cfg, k), n_scan)
                 for i, k in enumerate(pat)} if n_scan else {},
        "rem": {_key(j, pat[j % len(pat)]): block_specs(cfg, pat[j % len(pat)])
                for j in range(n_rem)},
    }
    return specs


def cache_specs(cfg, B: int, T: int) -> dict:
    n_scan, n_rem = _plan(cfg)
    pat = cfg.layer_pattern

    def layer(kind):
        return kvcache.layer_cache_specs(cfg, kind, B, T)

    return {
        "scan": {_key(i, k): stack_specs(layer(k), n_scan)
                 for i, k in enumerate(pat)} if n_scan else {},
        "rem": {_key(j, pat[j % len(pat)]): layer(pat[j % len(pat)])
                for j in range(n_rem)},
    }


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def apply_block(kind: str, p: dict, x: jax.Array, aux: dict, *, cfg,
                rules: dict, positions: jax.Array,
                cache: Optional[dict], return_cache: bool,
                cache_len: int = 0):
    from jax.ad_checkpoint import checkpoint_name as name
    eps, plus = cfg.norm_eps, cfg.scale_embeddings
    new_cache = None
    if kind in ("dense", "global", "local", "moe"):
        h = rms_norm(x, p["ln1"], eps, plus)
        a_out, new_cache = attn_lib.attention(
            p["attn"], h, cfg=cfg, rules=rules,
            kind="global" if kind == "moe" else kind,
            positions=positions, cache=cache, return_cache=return_cache,
            cache_len=cache_len)
        a_out = name(a_out, "attn_out")
        if cfg.post_norms:
            a_out = rms_norm(a_out, p["ln1_post"], eps, plus)
        x = x + a_out
        h2 = rms_norm(x, p["ln2"], eps, plus)
        if kind == "moe":
            f_out, moe_aux = moe_lib.moe_block(p["moe"], h2, cfg=cfg, rules=rules)
            aux = {k: aux[k] + moe_aux.get(k, 0.0) for k in aux}
        else:
            f_out = mlp(p["mlp"], h2, cfg.mlp_act, rules)
        f_out = name(f_out, "ffn_out")
        if cfg.post_norms:
            f_out = rms_norm(f_out, p["ln2_post"], eps, plus)
        x = x + f_out
    elif kind == "mamba":
        h = rms_norm(x, p["ln1"], eps, plus)
        out, new_cache = ssm_lib.mamba_block(
            p["mamba"], h, cfg=cfg, rules=rules, cache=cache,
            return_cache=return_cache)
        x = x + name(out, "mixer_out")
    elif kind == "rglru":
        h = rms_norm(x, p["ln1"], eps, plus)
        out, new_cache = rglru_lib.rglru_block(
            p["rglru"], h, cfg=cfg, rules=rules, cache=cache,
            return_cache=return_cache)
        x = x + name(out, "mixer_out")
        h2 = rms_norm(x, p["ln2"], eps, plus)
        x = x + name(mlp(p["mlp"], h2, cfg.mlp_act, rules), "ffn_out")
    else:
        raise ValueError(kind)
    return constrain(x, rules, "batch", None, None), aux, new_cache


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    if cfg.remat == "comm":
        # save the post-all-reduce sublayer outputs: backward recompute
        # stops at them, so the forward TP all-reduces are NOT re-issued
        # in the backward pass (§Perf hillclimb; costs one extra saved
        # (B,S,M) tensor per sublayer)
        pol = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out", "mixer_out")
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def apply_stack(params: dict, x: jax.Array, *, cfg, rules: dict,
                positions: jax.Array, cache: Optional[dict] = None,
                return_cache: bool = False, cache_len: int = 0):
    """Runs all layers. Returns (x, aux, new_cache|None)."""
    pat = cfg.layer_pattern
    n_scan, n_rem = _plan(cfg)
    aux = dict(AUX0)
    new_cache: dict[str, Any] = {"scan": {}, "rem": {}}
    use_cache = cache is not None

    if n_scan:
        # remat at BLOCK granularity: the scan saves only the carry per
        # period; backward recomputes one block at a time (working set =
        # one layer, not one period)
        def block_fn(kind, p, xc, auxc, c_in):
            return apply_block(
                kind, p, xc, auxc, cfg=cfg, rules=rules,
                positions=positions, cache=c_in, return_cache=return_cache,
                cache_len=cache_len)

        def body(carry, xs):
            xc, auxc = carry
            p_period, c_period = xs if use_cache else (xs, None)
            outs = {}
            for i, kind in enumerate(pat):
                key = _key(i, kind)
                c_in = c_period[key] if use_cache else None
                fn = _remat(cfg, functools.partial(block_fn, kind))
                xc, auxc, nc = fn(p_period[key], xc, auxc, c_in)
                if nc is not None:
                    outs[key] = nc
            return (xc, auxc), (outs if outs else 0.0)

        xs = (params["scan"], cache["scan"]) if use_cache else params["scan"]
        (x, aux), ys = jax.lax.scan(body, (x, aux), xs)
        if use_cache or return_cache:
            new_cache["scan"] = ys

    for j in range(n_rem):
        kind = pat[j % len(pat)]
        key = _key(j, kind)
        c_in = cache["rem"][key] if use_cache else None

        def one(carry, p, kind=kind, c_in=c_in):
            xc, auxc = carry
            return apply_block(kind, p, xc, auxc, cfg=cfg, rules=rules,
                               positions=positions, cache=c_in,
                               return_cache=return_cache,
                               cache_len=cache_len)  # rematted below

        xr, aux, nc = _remat(cfg, one)((x, aux), params["rem"][key])
        x = xr
        if nc is not None:
            new_cache["rem"][key] = nc

    out_cache = new_cache if (use_cache or return_cache) else None
    return x, aux, out_cache


def apply_transformer(params: dict, tokens: jax.Array, *, cfg, rules: dict,
                      positions: Optional[jax.Array] = None,
                      prefix_embed: Optional[jax.Array] = None,
                      cache: Optional[dict] = None,
                      return_cache: bool = False, cache_len: int = 0):
    """Returns (hidden (B,S_total,M), aux, new_cache). Logits are computed by
    the caller (chunked xent for train; last-token unembed for prefill)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed(params["embed"], tokens, cfg.scale_embeddings, cdt)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(cdt), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, rules, "batch", None, None)
    x, aux, new_cache = apply_stack(
        params, x, cfg=cfg, rules=rules, positions=positions, cache=cache,
        return_cache=return_cache, cache_len=cache_len)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps, cfg.scale_embeddings)
    return x, aux, new_cache


def logits_from_hidden(params: dict, hidden: jax.Array, cfg,
                       rules: Optional[dict] = None) -> jax.Array:
    lg = unembed(params["embed"], hidden, cfg.tie_embeddings)
    if rules is not None:
        lg = constrain(lg, rules, "batch", None, "vocab")
    return softcap(lg, cfg.logit_softcap)
