"""KV / state caches for decode.

Attention caches hold absolute positions per slot so local layers can use a
ring buffer (slot = pos % window) with the same insert path as global layers.
Global-layer caches are sequence-shardable over the `data` mesh axis for
long-context decode (SP decode; see DESIGN.md §4) via the `kv_seq` logical
axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec

# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def attn_cache_specs(cfg, B: int, T: int, kind: str) -> dict[str, ParamSpec]:
    """kind local -> ring buffer of size window; else full T.

    k/v are stored FLATTENED (B, T, Hkv*D) on the `kv_flat` logical axis —
    divisible by the 16-way model axis for every assigned arch (unlike the
    head count), so caches always TP-shard (incl. MQA) and match the
    in-loop sharding GSPMD picks (no loop-boundary cache gathers)."""
    size = min(cfg.attn_window, T) if kind == "local" else T
    cdt = jnp.dtype(cfg.compute_dtype)
    seq_ax = "kv_seq" if kind != "local" else None  # rings are small
    F = cfg.n_kv_heads * cfg.head_dim
    return {
        "k": ParamSpec((B, size, F), ("batch", seq_ax, "kv_flat"), cdt,
                       init="zeros"),
        "v": ParamSpec((B, size, F), ("batch", seq_ax, "kv_flat"), cdt,
                       init="zeros"),
        "pos": ParamSpec((B, size), ("batch", seq_ax), jnp.int32, init="neg_ones"),
    }


def mamba_cache_specs(cfg, B: int) -> dict[str, ParamSpec]:
    s, di = cfg.ssm, cfg.d_inner
    return {
        "conv": ParamSpec((B, s.d_conv - 1, di), ("batch", None, "inner"),
                          jnp.dtype(cfg.compute_dtype), init="zeros"),
        "h": ParamSpec((B, di, s.d_state), ("batch", "inner", "state"),
                       jnp.float32, init="zeros"),
    }


def rglru_cache_specs(cfg, B: int) -> dict[str, ParamSpec]:
    dr = cfg.d_rnn
    return {
        "conv": ParamSpec((B, cfg.rglru.d_conv - 1, dr), ("batch", None, "rnn"),
                          jnp.dtype(cfg.compute_dtype), init="zeros"),
        "h": ParamSpec((B, dr), ("batch", "rnn"), jnp.float32, init="zeros"),
    }


def layer_cache_specs(cfg, kind: str, B: int, T: int) -> Optional[dict]:
    if kind in ("dense", "global", "local", "moe"):
        return attn_cache_specs(cfg, B, T, kind)
    if kind == "mamba":
        return mamba_cache_specs(cfg, B)
    if kind == "rglru":
        return rglru_cache_specs(cfg, B)
    return None


# ---------------------------------------------------------------------------
# Attention-cache ops
# ---------------------------------------------------------------------------


def cache_insert(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, window: int = 0) -> dict:
    """Insert one token per sequence. k_new/v_new: (B,1,Hkv,D); pos: (B,).
    Cache k/v are stored flat (B,T,Hkv*D)."""
    B = k_new.shape[0]
    T = cache["k"].shape[1]
    b = jnp.arange(B)
    slot = pos % T
    return {
        "k": cache["k"].at[b, slot].set(k_new.reshape(B, -1)),
        "v": cache["v"].at[b, slot].set(v_new.reshape(B, -1)),
        "pos": cache["pos"].at[b, slot].set(pos),
    }


def cache_from_prefill(k: jax.Array, v: jax.Array, positions: jax.Array,
                       window: int = 0, max_len: int = 0) -> dict:
    """Build a cache from prefill-computed k/v (B,S,Hkv,D), rope applied.

    Global: the cache IS the kv sequence, padded to `max_len` capacity so
    subsequent decode inserts don't evict (slots beyond S hold pos=-1).
    Local: keep the last `window` entries, scattered to their ring slots
    (slot = pos % window; rings wrap by design). Stored flat (B,T,Hkv*D).
    """
    B, S = k.shape[:2]
    k = k.reshape(B, S, -1)
    v = v.reshape(B, S, -1)
    if not window or S <= window:
        if window and S < window:  # pad ring to full window size
            pad = window - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
            positions = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
        if window:  # scatter to ring slots
            return _scatter_ring(k, v, positions, window)
        if max_len and max_len > S:  # global: headroom for decode
            pad = max_len - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
            positions = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
        return {"k": k, "v": v, "pos": positions}
    return _scatter_ring(k[:, -window:], v[:, -window:], positions[:, -window:],
                         window)


def _scatter_ring(k, v, positions, window):
    B = k.shape[0]
    slots = jnp.where(positions >= 0, positions % window, 0)
    b = jnp.arange(B)[:, None]
    return {
        "k": jnp.zeros_like(k).at[b, slots].set(k),
        "v": jnp.zeros_like(v).at[b, slots].set(v),
        "pos": jnp.full_like(positions, -1).at[b, slots].set(positions),
    }
