"""Mixture-of-Experts with capacity-based top-k dispatch (llama4 / arctic).

GShard-style grouped dispatch: tokens grouped by sequence (train/prefill) or
into a single group (decode), position-in-expert via in-group cumsum, gather
to a dense (G, E, C, M) tensor, grouped einsum against expert weights sharded
over the `model` mesh axis (EP), scatter-add combine. Tokens over capacity
are dropped (contribute via residual only) — capacity_factor 1.25 default.

GSPMD inserts the routing collectives for the (data-sharded G) ×
(model-sharded E) transition; replacing them with an explicit shard_map
all-to-all is a §Perf hillclimb lever (see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ACTS, ParamSpec, constrain, mlp, mlp_specs


def moe_specs(cfg) -> dict:
    m = cfg.moe
    M, E, F = cfg.d_model, m.n_experts, m.d_expert
    pdt = jnp.dtype(cfg.param_dtype)
    specs: dict = {
        "router": ParamSpec((M, E), ("embed", "expert"), jnp.float32),
        # EP shards the expert axis over `model`; the per-expert ffn dim
        # stays unsharded (one mesh axis cannot shard two dims)
        "wi": ParamSpec((E, M, F), ("expert", "embed", "expert_ffn"), pdt),
        "wo": ParamSpec((E, F, M), ("expert", "expert_ffn", "embed"), pdt),
    }
    if cfg.mlp_glu:
        specs["wg"] = ParamSpec((E, M, F), ("expert", "embed", "expert_ffn"), pdt)
    if m.shared_expert:
        specs["shared"] = mlp_specs(M, F, cfg.mlp_glu, pdt)
    if m.dense_residual:
        specs["dense"] = mlp_specs(M, cfg.d_ff, cfg.mlp_glu, pdt)
    return specs


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    return max(1, math.ceil(T * k / E * factor))


def moe_block(params: dict, x: jax.Array, *, cfg, rules: dict):
    """x: (B,S,M). Returns (y, aux_losses dict of scalars)."""
    m = cfg.moe
    B, S, M = x.shape
    E, k = m.n_experts, m.top_k
    decode = S == 1
    if decode:                       # one group of B tokens
        xg = x.reshape(1, B, M)
    else:                            # group = sequence
        xg = x
    G, T, _ = xg.shape
    C = _capacity(T, k, E, m.capacity_factor)

    # ---- routing (fp32) ----------------------------------------------------
    logits = jnp.einsum("gtm,me->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (G,T,k)
    top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- dispatch plan: position-in-expert via in-group cumsum -------------
    flat_e = top_e.reshape(G, T * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # (G,Tk,E)
    pos = (jnp.cumsum(oh, axis=1) * oh).sum(-1)                  # 1-based queue pos
    keep = (pos >= 1) & (pos <= C)
    slot = jnp.where(keep, flat_e * C + (pos - 1), E * C)        # E*C = drop
    tok = jnp.tile(jnp.arange(T)[:, None], (1, k)).reshape(T * k)

    g_idx = jnp.arange(G)[:, None]
    token_for_slot = jnp.zeros((G, E * C), jnp.int32).at[g_idx, slot].set(
        jnp.broadcast_to(tok, (G, T * k)), mode="drop")
    w_for_slot = jnp.zeros((G, E * C), jnp.float32).at[g_idx, slot].set(
        top_w.reshape(G, T * k), mode="drop")

    # ---- expert compute (EP: E sharded over `model`) ------------------------
    xe = jnp.take_along_axis(xg, token_for_slot[..., None], axis=1)
    xe = xe.reshape(G, E, C, M)
    xe = constrain(xe, rules, None if decode else "batch", "expert", None, None)
    h = jnp.einsum("gecm,emf->gecf", xe, params["wi"].astype(xe.dtype))
    a = ACTS[cfg.mlp_act](h)
    if "wg" in params:
        a = a * jnp.einsum("gecm,emf->gecf", xe, params["wg"].astype(xe.dtype))
    ye = jnp.einsum("gecf,efm->gecm", a, params["wo"].astype(xe.dtype))
    ye = constrain(ye, rules, None if decode else "batch", "expert", None, None)

    # ---- combine (scatter-add; dropped slots carry weight 0) ----------------
    from repro.models.layers import _LOWP_COLLECTIVES
    acc_dt = x.dtype if _LOWP_COLLECTIVES else jnp.float32
    contrib = (w_for_slot[..., None].astype(acc_dt)
               * ye.reshape(G, E * C, M).astype(acc_dt))
    y = jnp.zeros((G, T, M), acc_dt).at[g_idx, token_for_slot].add(contrib)
    y = y.astype(x.dtype).reshape(B, S, M)

    # ---- always-on paths -----------------------------------------------------
    if "shared" in params:
        y = y + mlp(params["shared"], x, cfg.mlp_act, rules)
    if "dense" in params:
        y = y + mlp(params["dense"], x, cfg.mlp_act, rules)

    # ---- aux losses ----------------------------------------------------------
    frac_tokens = jnp.mean(oh.astype(jnp.float32), axis=(0, 1)) * k  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(frac_tokens * mean_prob) / k
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_lb": lb, "moe_z": zl}
    return constrain(y, rules, "batch", None, None), aux
