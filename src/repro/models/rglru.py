"""Griffin recurrent block with RG-LRU (recurrentgemma-9b).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
a_t = exp(-c · softplus(Λ) · r_t),  c = 8.

The gated linear recurrence is diagonal → computed with
``jax.lax.associative_scan`` (parallel prefix, TPU-friendly; state is only
(B,S,d_rnn) so full materialization is cheap, unlike mamba's ×d_state).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, constrain, dense
from repro.models.ssm import _causal_conv

_C = 8.0


def rglru_specs(cfg) -> dict[str, ParamSpec]:
    M, dr = cfg.d_model, cfg.d_rnn
    bw = cfg.rglru.block_width or dr
    nb = dr // bw
    pdt = jnp.dtype(cfg.param_dtype)
    return {
        "w_x": ParamSpec((M, dr), ("embed", "rnn"), pdt),
        "w_y": ParamSpec((M, dr), ("embed", "rnn"), pdt),
        "conv_w": ParamSpec((cfg.rglru.d_conv, dr), ("conv", "rnn"), pdt, scale=1.0),
        "conv_b": ParamSpec((dr,), ("rnn",), pdt, init="zeros"),
        # block-diagonal input/recurrence gates
        "w_i": ParamSpec((nb, bw, bw), ("rnn", None, None), pdt),
        "w_r": ParamSpec((nb, bw, bw), ("rnn", None, None), pdt),
        "b_i": ParamSpec((dr,), ("rnn",), pdt, init="zeros"),
        "b_r": ParamSpec((dr,), ("rnn",), pdt, init="zeros"),
        "lam": ParamSpec((dr,), ("rnn",), jnp.float32, init="ones"),
        "w_out": ParamSpec((dr, M), ("rnn", "embed"), pdt),
    }


def _block_diag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B,S,dr); w: (nb,bw,bw) block-diagonal matmul."""
    B, S, dr = x.shape
    nb, bw, _ = w.shape
    xb = x.reshape(B, S, nb, bw)
    y = jnp.einsum("bsnw,nwv->bsnv", xb, w.astype(x.dtype))
    return y.reshape(B, S, dr) + b.astype(x.dtype)


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array):
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + b_t via associative scan.
    a, b: (B,S,dr) fp32; h0: (B,dr). Returns (h_all (B,S,dr), h_last)."""
    # fold h0 into the first element: b_0' = a_0*h0 + b_0
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_block(params: dict, x: jax.Array, *, cfg, rules: dict,
                cache: Optional[dict] = None, return_cache: bool = False):
    """Griffin recurrent mixer. x: (B,S,M). Returns (y, new_cache)."""
    B, S, M = x.shape
    dr = cfg.d_rnn

    y_branch = jax.nn.gelu(dense(x, params["w_y"]))
    xb = dense(x, params["w_x"])
    xb = constrain(xb, rules, "batch", None, "rnn")
    conv_carry = cache["conv"] if cache is not None else None
    xb, new_conv = _causal_conv(xb, params["conv_w"], params["conv_b"], conv_carry)

    gate_i = jax.nn.sigmoid(_block_diag(xb, params["w_i"], params["b_i"]))
    gate_r = jax.nn.sigmoid(_block_diag(xb, params["w_r"], params["b_r"]))
    log_a = (-_C * jax.nn.softplus(params["lam"])) * gate_r.astype(jnp.float32)
    a = jnp.exp(log_a)
    # sqrt(1-a^2) computed in log space for stability near a→1
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated_x = beta * (gate_i.astype(jnp.float32) * xb.astype(jnp.float32))

    h0 = cache["h"] if cache is not None else jnp.zeros((B, dr), jnp.float32)
    if S == 1 and cache is not None:
        h_last = a[:, 0] * h0 + gated_x[:, 0]
        h = h_last[:, None]
    else:
        h, h_last = rglru_scan(a, gated_x, h0)

    merged = h.astype(x.dtype) * y_branch
    out = dense(merged, params["w_out"])
    new_cache = ({"conv": new_conv, "h": h_last}
                 if (cache is not None or return_cache) else None)
    return constrain(out, rules, "batch", None, None), new_cache
