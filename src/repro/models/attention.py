"""Attention: GQA with RoPE, chunked-flash prefill, banded local attention,
ring-buffer local KV cache, sequence-shardable global KV cache (SP decode).

Impl-switchable: the XLA path here is what the dry-run lowers; the Pallas
flash kernel (repro/kernels/flash_attention) is the TPU drop-in selected via
``impl="pallas"`` in ops dispatch.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (
    ParamSpec, constrain, dense, rms_norm, rope, softcap,
)

NEG_INF = -2.0e38  # fp32-safe large negative (avoid nan from inf-inf)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_specs(cfg) -> dict[str, ParamSpec]:
    """Projections are stored FLATTENED (M, H*D): the flattened width is
    divisible by the 16-way model axis for every assigned arch even when
    the head count is not (gemma3/paligemma: 8 heads) — GSPMD re-factorizes
    the (H, D) reshape, so attention TP always shards."""
    M, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pdt = jnp.dtype(cfg.param_dtype)
    specs = {
        "wq": ParamSpec((M, Hq * D), ("embed", "qkv"), pdt),
        "wk": ParamSpec((M, Hkv * D), ("embed", "kv_flat"), pdt),
        "wv": ParamSpec((M, Hkv * D), ("embed", "kv_flat"), pdt),
        "wo": ParamSpec((Hq * D, M), ("qkv", "embed"), pdt),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((Hq * D,), ("qkv",), pdt, init="zeros")
        specs["bk"] = ParamSpec((Hkv * D,), ("kv_flat",), pdt, init="zeros")
        specs["bv"] = ParamSpec((Hkv * D,), ("kv_flat",), pdt, init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((D,), ("head_dim",), init="ones")
        specs["k_norm"] = ParamSpec((D,), ("head_dim",), init="ones")
    return specs


# ---------------------------------------------------------------------------
# Mask predicates (absolute positions)
# ---------------------------------------------------------------------------


def make_mask_fn(*, causal: bool, window: int, prefix: int) -> Callable:
    """Returns mask_fn(q_pos (Q,), k_pos (K,)) -> bool (Q, K)."""

    def mask_fn(q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
        q_pos, k_pos = q_pos[:, None], k_pos[None, :]
        ok = k_pos <= q_pos if causal else jnp.ones_like(q_pos == k_pos)
        if window:
            ok &= (q_pos - k_pos) < window
        if prefix:
            ok |= k_pos < prefix  # prefix-LM: everything sees the prefix
        return ok

    return mask_fn


# ---------------------------------------------------------------------------
# Core attends
# ---------------------------------------------------------------------------


def _attend_dense(q, k, v, q_pos, k_pos, mask_fn, scale, cap):
    """q: (B,Q,Hk,G,D); k/v: (B,K,Hk,D). fp32 softmax. Returns (B,Q,Hk,G,D)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = softcap(s * scale, cap)
    mask = mask_fn(q_pos, k_pos)  # (Q, K)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF / 2)))
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def flash_attention_xla(q, k, v, *, mask_fn, scale, cap, chunk_q, chunk_k,
                        q_offset=0):
    """Memory-efficient chunked attention (online softmax), lax.map over query
    chunks + lax.scan over kv chunks. q: (B,Sq,Hk,G,D); k/v: (B,Sk,Hk,D)."""
    B, Sq, Hk, G, D = q.shape
    Sk = k.shape[1]
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    if Sq % cq or Sk % ck:  # pad; padded kv slots are masked via kv_len
        pq, pk = (-Sq) % cq, (-Sk) % ck
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        inner = functools.partial(
            flash_attention_xla, mask_fn=lambda qp, kp: mask_fn(qp, kp)
            & (kp < Sk)[None, :], scale=scale, cap=cap, chunk_q=cq,
            chunk_k=ck, q_offset=q_offset)
        return inner(q, k, v)[:, :Sq]
    nq, nk = Sq // cq, Sk // ck
    if nq == 1 and nk == 1:
        qp = q_offset + jnp.arange(Sq)
        return _attend_dense(q, k, v, qp, jnp.arange(Sk), mask_fn, scale, cap)

    qc = jnp.moveaxis(q.reshape(B, nq, cq, Hk, G, D), 1, 0)      # (nq,B,cq,Hk,G,D)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, Hk, D), 1, 0)         # (nk,B,ck,Hk,D)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, Hk, D), 1, 0)

    @jax.checkpoint  # flash backward = recompute; never save p/scores
    def per_q(args):
        qi, qb = args
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def body(carry, kin):
            ki, kb, vb = kin
            m, l, acc = carry
            k_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32)
            s = softcap(s * scale, cap)
            s = jnp.where(mask_fn(q_pos, k_pos)[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l, acc), None

        init = (
            jnp.full((B, Hk, G, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, Hk, G, cq), jnp.float32),
            jnp.zeros((B, Hk, G, cq, D), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)            # (B,cq,Hk,G,D)

    outs = jax.lax.map(per_q, (jnp.arange(nq), qc))               # (nq,B,cq,...)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hk, G, D)


def local_attention_xla(q, k, v, *, window, scale, cap, q_offset=0):
    """Banded sliding-window attention: queries in chunks of `window`, each
    attending the previous+current kv chunk only → O(S·2w) FLOPs (honest
    sub-quadratic cost in HLO). q: (B,S,Hk,G,D); k/v: (B,S,Hk,D)."""
    B, S, Hk, G, D = q.shape
    w = min(window, S)
    pad = (-S) % w
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    Sp = S + pad
    n = Sp // w
    qc = jnp.moveaxis(qp.reshape(B, n, w, Hk, G, D), 1, 0)        # (n,B,w,...)

    def windows(x):  # (B,Sp,Hk,D) -> (n,B,2w,Hk,D): [prev chunk | this chunk]
        xpad = jnp.pad(x, ((0, 0), (w, 0), (0, 0), (0, 0)))
        xc = xpad.reshape(B, n + 1, w, *x.shape[2:])
        return jnp.moveaxis(jnp.concatenate([xc[:, :-1], xc[:, 1:]], axis=2), 1, 0)

    kw, vw = windows(kp), windows(vp)
    base_mask = make_mask_fn(causal=True, window=w, prefix=0)

    def mask_fn(q_pos, k_pos):  # exclude the padded leading chunk (pos < 0)
        return base_mask(q_pos, k_pos) & (k_pos >= q_offset)[None, :]

    @jax.checkpoint  # never save the banded scores for backward
    def per_chunk(args):
        i, qb, kb, vb = args
        q_pos = q_offset + i * w + jnp.arange(w)
        k_pos = q_offset + (i - 1) * w + jnp.arange(2 * w)        # may be negative -> masked
        return _attend_dense(qb, kb, vb, q_pos, k_pos, mask_fn, scale, cap)

    outs = jax.lax.map(per_chunk, (jnp.arange(n), qc, kw, vw))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sp, Hk, G, D)
    return out[:, :S]


def decode_attention_xla(q, k_cache, v_cache, *, pos, cache_positions, scale,
                         cap, window=0):
    """One-token decode. q: (B,1,Hk,G,D); caches: (B,T,Hk,D);
    pos: (B,) absolute position of the new token;
    cache_positions: (B,T) absolute position stored in each cache slot
    (ring buffers make slot order != position order). Invalid slots < 0."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k_cache,
                   preferred_element_type=jnp.float32)
    s = softcap(s * scale, cap)
    valid = (cache_positions >= 0) & (cache_positions <= pos[:, None])
    if window:
        valid &= (pos[:, None] - cache_positions) < window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    # stable softmax over the cache axis (sharded over `data` in long_500k —
    # GSPMD inserts the all-reduce for these reductions: SP decode)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + dispatch + cache handling)
# ---------------------------------------------------------------------------


def attention(params: dict, x: jax.Array, *, cfg, rules: dict, kind: str,
              positions: jax.Array, cache: Optional[dict] = None,
              return_cache: bool = False, cache_len: int = 0):
    """kind: dense|global|local. x: (B,S,M). positions: (B,S) absolute.

    Modes:
      * train/prefill: cache is None; returns (y, new_cache|None)
      * decode:        cache is dict;  returns (y, updated_cache)
    """
    B, S, M = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = Hq // Hkv
    scale = cfg.query_scale or D ** -0.5
    window = cfg.attn_window if kind == "local" else 0
    theta = cfg.rope_theta if kind != "local" else min(cfg.rope_theta, 10_000.0)

    q = jnp.einsum("bsm,mf->bsf", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mf->bsf", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mf->bsf", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    decode = cache is not None and S == 1
    if decode:
        # decode: new-token q/k/v are tiny; pin them to the CACHE layout
        # (batch x kv_heads) so GSPMD reshards the token, not the cache
        q = constrain(q.reshape(B, S, Hq, D), rules,
                      "batch", None, "heads", "head_dim")
        k = constrain(k.reshape(B, S, Hkv, D), rules,
                      "batch", None, "kv_heads", "head_dim")
        v = constrain(v.reshape(B, S, Hkv, D), rules,
                      "batch", None, "kv_heads", "head_dim")
    else:
        q = constrain(q, rules, "batch", None, "qkv").reshape(B, S, Hq, D)
        k = constrain(k, rules, "batch", None, "kv_flat").reshape(B, S, Hkv, D)
        v = constrain(v, rules, "batch", None, "kv_flat").reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    qg = q.reshape(B, S, Hkv, G, D)

    # padded-MHA mode (train/prefill): when the head count doesn't divide
    # the TP axis, GSPMD splits mid-head and all-reduces SCORES. Instead:
    # pad q per kv-group to Hp (divisible), repeat kv, run scores in MHA
    # layout (per-head local), slice the inert pad heads off before wo —
    # mathematically exact (padded outputs are discarded).
    pad_mha = cfg.pad_heads_to > Hq and not (cache is not None and S == 1)
    if pad_mha:
        Gp = cfg.pad_heads_to // Hkv
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, Gp - G), (0, 0)))
        qg = qg.reshape(B, S, cfg.pad_heads_to, 1, D)
        qg = constrain(qg, rules, "batch", None, "heads_padded", None, None)
        k_att = jnp.repeat(k, Gp, axis=2)        # (B,S,Hp,D)
        v_att = jnp.repeat(v, Gp, axis=2)
        k_att = constrain(k_att, rules, "batch", None, "heads_padded", None)
        v_att = constrain(v_att, rules, "batch", None, "heads_padded", None)
    else:
        k_att, v_att = k, v

    if decode:  # one-token decode against the cache
        from repro.models.kvcache import cache_insert  # local import: no cycle
        cache = cache_insert(cache, k, v, positions[:, 0], window=window)
        T = cache["k"].shape[1]
        kc = cache["k"].reshape(B, T, Hkv, D)
        vc = cache["v"].reshape(B, T, Hkv, D)
        o = decode_attention_xla(
            qg, kc, vc, pos=positions[:, 0],
            cache_positions=cache["pos"], scale=scale, cap=cfg.attn_softcap,
            window=window)
        new_cache = cache
    else:  # train / prefill
        if kind == "local":
            o = local_attention_xla(qg, k_att, v_att, window=cfg.attn_window,
                                    scale=scale, cap=cfg.attn_softcap)
        else:
            mask_fn = make_mask_fn(causal=True, window=0, prefix=cfg.n_prefix
                                   if cfg.prefix_bidirectional else 0)
            o = flash_attention_xla(qg, k_att, v_att, mask_fn=mask_fn,
                                    scale=scale, cap=cfg.attn_softcap,
                                    chunk_q=cfg.attn_chunk,
                                    chunk_k=cfg.attn_chunk)
        if pad_mha:  # drop the inert pad heads: o (B,S,Hp,1,D)->(B,S,Hkv,G,D)
            o = o.reshape(B, S, Hkv, Gp, D)[:, :, :, :G]
        new_cache = None
        if return_cache:
            from repro.models.kvcache import cache_from_prefill
            new_cache = cache_from_prefill(k, v, positions,
                                           window=cfg.attn_window
                                           if kind == "local" else 0,
                                           max_len=cache_len)

    o = o.reshape(B, S, Hq * D)
    o = constrain(o, rules, "batch", None, "qkv")
    from repro.models.layers import prefer_dtype
    y = jnp.einsum("bsf,fm->bsm", o, params["wo"].astype(x.dtype),
                   preferred_element_type=prefer_dtype(x.dtype))
    return constrain(y, rules, "batch", None, None), new_cache
