"""Mamba-1 selective SSM block (falcon-mamba-7b).

XLA path: two-level chunked scan — outer ``lax.scan`` over sequence chunks
carrying the SSM state, inner per-step scan wrapped in ``jax.checkpoint`` so
backward recomputes per-step states (memory: chunk-boundary states only).
The Pallas twin (repro/kernels/ssm_scan) is the TPU hot-loop drop-in.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, constrain, dense


def mamba_specs(cfg) -> dict[str, ParamSpec]:
    s = cfg.ssm
    M, di, N = cfg.d_model, cfg.d_inner, s.d_state
    R = s.resolved_dt_rank(M)
    pdt = jnp.dtype(cfg.param_dtype)
    return {
        "in_proj": ParamSpec((M, 2 * di), ("embed", "inner"), pdt),
        "conv_w": ParamSpec((s.d_conv, di), ("conv", "inner"), pdt, scale=1.0),
        "conv_b": ParamSpec((di,), ("inner",), pdt, init="zeros"),
        "x_proj": ParamSpec((di, R + 2 * N), ("inner", "dt"), pdt),
        "dt_proj": ParamSpec((R, di), ("dt", "inner"), pdt),
        "dt_bias": ParamSpec((di,), ("inner",), pdt, init="zeros"),
        "A_log": ParamSpec((di, N), ("inner", "state"), jnp.float32, init="a_log"),
        "D": ParamSpec((di,), ("inner",), jnp.float32, init="ones"),
        "out_proj": ParamSpec((di, M), ("inner", "embed"), pdt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 carry: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq. x: (B,S,di); w: (K,di).
    carry: (B,K-1,di) previous inputs (decode) or None (zeros).
    Returns (y, new_carry)."""
    B, S, di = x.shape
    K = w.shape[0]
    if carry is None:
        carry = jnp.zeros((B, K - 1, di), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)          # (B, S+K-1, di)
    y = sum(xp[:, j:j + S] * w[j].astype(x.dtype) for j in range(K))
    new_carry = xp[:, -(K - 1):] if K > 1 else carry
    return y + b.astype(x.dtype), new_carry


def selective_scan(xi, dt, Bm, Cm, A, h0, *, chunk: int = 64):
    """h_t = exp(dt_t·A)⊙h_{t-1} + (dt_t·x_t)·B_t ;  y_t = h_t·C_t.

    xi, dt: (B,S,di); Bm, Cm: (B,S,N); A: (di,N) negative; h0: (B,di,N) fp32.
    Returns (y (B,S,di), h_last).
    """
    B, S, di = xi.shape
    N = A.shape[1]
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> identity step
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // c

    def to_chunks(x):  # (B,Sp,F) -> (nc, c, B, F)
        return jnp.moveaxis(x.reshape(B, nc, c, -1), (1, 2), (0, 1))

    xs = jax.tree.map(to_chunks, (xi, dt, Bm, Cm))

    @jax.checkpoint
    def chunk_fn(h, chunk_in):
        def step(h, t):
            xi_t, dt_t, B_t, C_t = t                  # (B,di) (B,di) (B,N) (B,N)
            dt32 = dt_t.astype(jnp.float32)
            decay = jnp.exp(dt32[:, :, None] * A)     # (B,di,N)
            inp = (dt32 * xi_t.astype(jnp.float32))[:, :, None] * \
                B_t.astype(jnp.float32)[:, None, :]
            h = decay * h + inp
            y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
            return h, y.astype(xi.dtype)

        xi_c, dt_c, B_c, C_c = chunk_in               # each (c, B, F)
        h, ys = jax.lax.scan(step, h, (xi_c, dt_c, B_c, C_c))
        return h, ys

    h, ys = jax.lax.scan(chunk_fn, h0, xs)            # ys: (nc, c, B, di)
    y = jnp.moveaxis(ys.reshape(nc * c, B, di), 0, 1)[:, :S]
    return y, h


def mamba_block(params: dict, x: jax.Array, *, cfg, rules: dict,
                cache: Optional[dict] = None, return_cache: bool = False):
    """x: (B,S,M). Returns (y, new_cache)."""
    B, S, M = x.shape
    s = cfg.ssm
    di, N = cfg.d_inner, s.d_state
    R = s.resolved_dt_rank(M)

    xz = dense(x, params["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, rules, "batch", None, "inner")
    conv_carry = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, params["conv_w"], params["conv_b"], conv_carry)
    xi = jax.nn.silu(xi)

    bcdt = dense(xi, params["x_proj"])
    dt_r, Bm, Cm = jnp.split(bcdt, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dense(dt_r, params["dt_proj"]) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                     # (di,N), negative

    h0 = cache["h"] if cache is not None else jnp.zeros((B, di, N), jnp.float32)
    if S == 1 and cache is not None:                  # decode fast path
        dt32 = dt[:, 0].astype(jnp.float32)
        decay = jnp.exp(dt32[:, :, None] * A)
        inp = (dt32 * xi[:, 0].astype(jnp.float32))[:, :, None] * \
            Bm[:, 0].astype(jnp.float32)[:, None, :]
        h = decay * h0 + inp
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
        y = y.astype(x.dtype)
    else:
        y, h = selective_scan(xi, dt, Bm, Cm, A, h0, chunk=max(cfg.attn_chunk // 16, 16))

    y = y + params["D"].astype(x.dtype) * xi
    y = y * jax.nn.silu(z)
    out = dense(y, params["out_proj"])
    new_cache = {"conv": new_conv, "h": h} if (cache is not None or return_cache) else None
    return constrain(out, rules, "batch", None, None), new_cache
