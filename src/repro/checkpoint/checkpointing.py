"""Checkpointing — asynchronous, riding the paper's staging path.

Backends:
  dir      — .npy shards + manifest.json in a directory (restore side).
  staging  — checkpoint shards ride the in-transit sink's TransferSession
             (any registered transport; rdma_staged by default): the write
             is asynchronous (paper's producer never blocks), lands in
             tmpfs, is forwarded to SAVIME by the FCFS pool, and is
             queryable as TARS arrays (a checkpoint you can *analyze* in
             place). A dir copy is kept for restore.

Restore is mesh-shape agnostic: leaves are device_put against the target
mesh's shardings (elastic restart: 512 -> 256 chips just works).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.intransit import InTransitSink
from repro.core.queues import FCFSPool

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, sink: Optional[InTransitSink] = None,
                 keep: int = 3, async_writes: bool = True):
        self.dir = directory
        self.sink = sink
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = FCFSPool(2, "ckpt-io") if async_writes else None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------
    def save(self, state: PyTree, step: int) -> str:
        """Non-blocking (async_writes): device->host copy happens here, file
        and staging I/O on background threads."""
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device_get
        cdir = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(cdir, exist_ok=True)
        manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                    for k, v in host.items()}
        with open(os.path.join(cdir, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)

        def write_all():
            for k, v in host.items():
                np.save(os.path.join(cdir, k.replace("/", "__") + ".npy"), v)
            with open(os.path.join(cdir, "COMMITTED"), "w") as f:
                f.write("ok")
            self._gc()

        if self._pool:
            self._pool.submit(write_all, name=f"ckpt-{step}")
        else:
            write_all()
        if self.sink is not None:  # analyzable checkpoint via SAVIME
            for k, v in host.items():
                if v.ndim >= 1 and v.size > 0:
                    self.sink.stage_array("ckpt_" + k.replace("/", "_"),
                                          v, step=step)
        return cdir

    def wait(self) -> None:
        if self._pool:
            self._pool.sync()
        if self.sink:
            self.sink.flush()

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "COMMITTED")):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, abstract_state: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> PyTree:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        cdir = os.path.join(self.dir, f"step_{step:08d}")
        flat_abs = _flatten(abstract_state)
        flat_sh = _flatten(shardings) if shardings is not None else None
        out = {}
        for k, spec in flat_abs.items():
            arr = np.load(os.path.join(cdir, k.replace("/", "__") + ".npy"))
            arr = arr.astype(spec.dtype).reshape(spec.shape)
            if flat_sh is not None:
                out[k] = jax.device_put(arr, flat_sh[k])  # reshard-on-restore
            else:
                out[k] = jax.numpy.asarray(arr)
        return _unflatten_like(abstract_state, out)

    def _gc(self) -> None:
        with self._lock:
            steps = sorted(
                int(d.split("_")[1]) for d in os.listdir(self.dir)
                if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "COMMITTED")))
            for s in steps[:-self.keep]:
                import shutil
                shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                              ignore_errors=True)


def _unflatten_like(tree: PyTree, flat: dict[str, Any]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
