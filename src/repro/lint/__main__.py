"""CLI: ``python -m repro.lint [paths...] [--strict] [--json] ...``.

Exit codes: 0 — clean (or all findings baselined); 1 — non-baselined
findings in ``--strict`` mode; 2 — bad invocation.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.lint.engine import lint_paths
from repro.lint.findings import Baseline


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Project-invariant static analysis for the in-transit stack.",
    )
    ap.add_argument("paths", nargs="*", default=["src"], help="files or directories (default: src)")
    ap.add_argument("--strict", action="store_true", help="exit 1 on any non-baselined finding")
    ap.add_argument("--json", action="store_true", dest="as_json", help="emit findings as JSON")
    ap.add_argument("--baseline", default="lint-baseline.json", help="baseline file (default: lint-baseline.json)")
    ap.add_argument("--no-baseline", action="store_true", help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true", help="write current findings to the baseline and exit 0")
    ap.add_argument("--rules", help="comma-separated rule ids to run (default: all)")
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = {r.strip() for r in args.rules.split(",") if r.strip()}

    findings = lint_paths(args.paths or ["src"], rules=rules)

    if args.write_baseline:
        Baseline.write(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = Baseline() if args.no_baseline else Baseline.load(args.baseline)
    new, old, stale = baseline.split(findings)

    if args.as_json:
        print(
            json.dumps(
                {
                    "findings": [f.to_json() for f in new],
                    "baselined": [f.to_json() for f in old],
                    "stale_baseline_entries": stale,
                },
                indent=2,
            )
        )
    else:
        for f in new:
            print(f.render())
        if old:
            print(f"-- {len(old)} baselined finding(s) suppressed", file=sys.stderr)
        for e in stale:
            print(
                f"-- stale baseline entry (fixed? run --write-baseline): "
                f"{e.get('rule')}: {e.get('path')}: {e.get('message')}",
                file=sys.stderr,
            )
        if not new:
            print(f"repro.lint: clean ({len(findings)} finding(s) total)", file=sys.stderr)

    if new and args.strict:
        print(f"repro.lint: {len(new)} non-baselined finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
