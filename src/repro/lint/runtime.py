"""Runtime lock-order sanitizer (``REPRO_LOCKCHECK=1``).

Wraps the ``threading.Lock``/``threading.RLock`` factories so every lock
created by *our* code (creation site inside a ``repro`` package or the
test tree) is tagged with a stable name (``file:line`` of the creating
statement).  Each thread keeps a stack of held checked locks; every
acquisition records held->acquired edges into a process-wide order
graph, and an acquisition whose reverse edge already exists is flagged
as an inversion — the dynamic complement of the static ``lock-order``
rule (which only sees ``with self._x`` nesting, not cross-object or
data-dependent orders).

Usage::

    from repro.lint import runtime
    runtime.install()            # no-op unless REPRO_LOCKCHECK=1 (or force=True)
    ...
    assert not runtime.inversions()

``tests/conftest.py`` installs it when ``REPRO_LOCKCHECK=1`` and fails
the session if any inversion was recorded.  Overhead is a few dict
operations per acquire/release — keep it out of perf runs.

Scope and honesty notes:

* Only locks created *after* ``install()`` from repro/tests code are
  checked; stdlib internals (queue.Queue, logging) keep raw locks.
* Lock identity is the creation site, mirroring the static rule's
  ``Class._attr`` abstraction — all instances created on one line share
  a node, so a reported inversion is a *potential* deadlock.
* ``threading.Condition`` composes correctly: ``Condition()`` (no arg)
  wraps a checked RLock via the patched factory; ``Condition(lock)``
  binds our ``acquire``/``release`` and — only when the inner lock
  provides them — the ``_release_save``/``_acquire_restore``/
  ``_is_owned`` trio, so ``wait()`` keeps the held-stack honest.
"""
from __future__ import annotations

import os
import sys
import threading
from dataclasses import dataclass, field

_raw_lock = threading.Lock
_raw_rlock = threading.RLock

_state_lock = _raw_lock()
_installed = False
_edges: dict[tuple[str, str], str] = {}  # (held, acquired) -> example
_reported: set[frozenset] = set()  # unordered pairs already reported
_inversions: list[dict] = []
_tls = threading.local()


@dataclass
class _Report:
    edges: dict = field(default_factory=dict)
    inversions: list = field(default_factory=list)


def _held() -> list:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


def _creation_site() -> str | None:
    """``file:line`` of the first frame outside threading/this module.

    Returns None (lock stays unchecked) when that frame is not our code.
    """
    f = sys._getframe(2)  # skip _creation_site and factory
    while f is not None:
        fn = f.f_code.co_filename.replace("\\", "/")
        if fn.endswith("lint/runtime.py") or fn.endswith("/threading.py"):
            f = f.f_back
            continue
        if "/repro/" in fn:
            return f"{fn.rsplit('/repro/', 1)[-1]}:{f.f_lineno}"
        if "/tests/" in fn or fn.endswith("conftest.py") or fn.rsplit("/", 1)[-1].startswith("test_"):
            return f"{fn.rsplit('/', 1)[-1]}:{f.f_lineno}"
        return None
    return None


class _CheckedLock:
    """Order-checking proxy over a raw Lock/RLock."""

    def __init__(self, inner, site: str):
        self._inner = inner
        self._site = site

    # -- order bookkeeping -------------------------------------------------
    def _record_acquire(self) -> None:
        stack = _held()
        me = self._site
        if stack and stack[-1] != me:
            tname = threading.current_thread().name
            with _state_lock:
                for h in stack:
                    if h == me:
                        continue
                    pair = frozenset((h, me))
                    if (me, h) in _edges and pair not in _reported:
                        _reported.add(pair)
                        _inversions.append(
                            {
                                "first": _edges[(me, h)],
                                "second": f"{h} -> {me} in thread {tname}",
                                "pair": tuple(sorted(pair)),
                            }
                        )
                    _edges.setdefault((h, me), f"{h} -> {me} in thread {tname}")
        stack.append(me)

    def _record_release(self) -> None:
        stack = _held()
        # RLock re-entry and Condition.wait release out of LIFO order:
        # drop the most recent entry for this site.
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == self._site:
                del stack[i]
                break

    # -- lock protocol -----------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._record_acquire()
        return got

    def release(self) -> None:
        self._inner.release()
        self._record_release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __getattr__(self, name: str):
        # Conditional protocol surface: expose _release_save /
        # _acquire_restore / _is_owned / locked only when the inner lock
        # has them, so threading.Condition's hasattr-style fallbacks keep
        # working for plain Locks.
        inner = object.__getattribute__(self, "_inner")
        attr = getattr(inner, name)  # AttributeError propagates, as required
        if name == "_release_save":
            def _release_save():
                state = attr()
                self._record_release()
                return state

            return _release_save
        if name == "_acquire_restore":
            def _acquire_restore(state):
                attr(state)
                self._record_acquire()

            return _acquire_restore
        return attr

    def __repr__(self) -> str:
        return f"<CheckedLock {self._site} over {self._inner!r}>"


def _make_factory(raw):
    def factory(*args, **kwargs):
        site = _creation_site()
        inner = raw(*args, **kwargs)
        if site is None:
            return inner
        return _CheckedLock(inner, site)

    return factory


def install(force: bool = False) -> bool:
    """Patch threading.Lock/RLock. Returns True if active.

    No-op unless ``REPRO_LOCKCHECK=1`` or ``force=True``; idempotent.
    """
    global _installed
    if _installed:
        return True
    if not force and os.environ.get("REPRO_LOCKCHECK") != "1":
        return False
    threading.Lock = _make_factory(_raw_lock)
    threading.RLock = _make_factory(_raw_rlock)
    _installed = True
    return True


def uninstall() -> None:
    global _installed
    threading.Lock = _raw_lock
    threading.RLock = _raw_rlock
    _installed = False


def installed() -> bool:
    return _installed


def reset() -> None:
    with _state_lock:
        _edges.clear()
        _reported.clear()
        _inversions.clear()


def inversions() -> list[dict]:
    with _state_lock:
        return list(_inversions)


def report() -> _Report:
    with _state_lock:
        return _Report(edges=dict(_edges), inversions=list(_inversions))
