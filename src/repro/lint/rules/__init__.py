"""Rule registry: each module exposes ``check(ctx) -> list[Finding]``."""
from repro.lint.rules import dispatch, guarded, hygiene, lifecycle, lockorder

ALL_RULES = {
    "guarded": guarded,
    "lockorder": lockorder,
    "lifecycle": lifecycle,
    "dispatch": dispatch,
    "hygiene": hygiene,
}

# rule-id -> family, for --rules filtering and docs
RULE_IDS = {
    "guarded-by": "guarded",
    "lock-order": "lockorder",
    "thread-join": "lifecycle",
    "socket-close": "lifecycle",
    "dispatch-return": "dispatch",
    "error-code": "dispatch",
    "bare-except": "hygiene",
    "mutable-default": "hygiene",
    "sleep-under-lock": "hygiene",
    "io-under-lock": "hygiene",
}
