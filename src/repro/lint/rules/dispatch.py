"""dispatch-return / error-code: wire-dispatch completeness.

dispatch-return — in server classes, every dispatch handler
(``_handle``, ``_handle_frame``, ``_op_*``) must produce a reply on
every control-flow path: each path ends in ``return <expr>`` or
``raise``; a fall-off-the-end path or a bare ``return`` replies None
and hangs/kills the peer's request.

error-code — wire error replies (dict literals with ``"ok": False`` and
an ``"error"`` key) must carry a machine-readable ``"code"`` tag so
clients can map them to typed exceptions (gateway/tenancy.py
``error_from_reply``).  Applies to every dict literal in the tree.
"""
from __future__ import annotations

import ast
import re

from repro.lint.context import FileContext
from repro.lint.findings import Finding

RETURN_RULE = "dispatch-return"
CODE_RULE = "error-code"
HANDLER_RE = re.compile(r"^(_handle(_\w+)?|_op_\w+)$")


def check(ctx: FileContext) -> list[Finding]:
    findings: list[Finding] = []
    for cls in ctx.classes:
        if not cls.name.endswith(("Server", "Engine")):
            continue
        for meth in cls.methods():
            if not HANDLER_RE.match(meth.name):
                continue
            qual = f"{cls.name}.{meth.name}"
            if not _terminates(meth.body):
                if not ctx.suppressed(meth.lineno, RETURN_RULE):
                    findings.append(
                        Finding(
                            rule=RETURN_RULE,
                            path=str(ctx.path),
                            line=meth.lineno,
                            col=meth.col_offset,
                            message=(
                                f"dispatch handler {meth.name} can fall off the end "
                                f"without returning a reply"
                            ),
                            scope=qual,
                        )
                    )
                continue
            for node in _walk_own(meth):
                if isinstance(node, ast.Return) and node.value is None:
                    if ctx.suppressed(node.lineno, RETURN_RULE):
                        continue
                    findings.append(
                        Finding(
                            rule=RETURN_RULE,
                            path=str(ctx.path),
                            line=node.lineno,
                            col=node.col_offset,
                            message=(
                                f"dispatch handler {meth.name} returns without a "
                                f"reply (bare return replies None)"
                            ),
                            scope=qual,
                        )
                    )
    findings.extend(_check_error_codes(ctx))
    return findings


def _walk_own(func):
    """Walk func's body without descending into nested defs/lambdas."""
    stack = list(func.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _terminates(stmts: list[ast.stmt]) -> bool:
    """True if every path through stmts ends in return/raise."""
    for stmt in stmts:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            return True
        if isinstance(stmt, ast.If):
            if stmt.orelse and _terminates(stmt.body) and _terminates(stmt.orelse):
                return True
        elif isinstance(stmt, ast.Try):
            if stmt.finalbody and _terminates(stmt.finalbody):
                return True
            body_t = _terminates(stmt.orelse) if stmt.orelse else _terminates(stmt.body)
            if body_t and all(_terminates(h.body) for h in stmt.handlers):
                return True
        elif isinstance(stmt, ast.With):
            if _terminates(stmt.body):
                return True
        elif isinstance(stmt, ast.While):
            if (
                isinstance(stmt.test, ast.Constant)
                and stmt.test.value
                and not _has_break(stmt)
            ):
                return True
        elif isinstance(stmt, ast.Match):
            has_catchall = any(
                isinstance(c.pattern, ast.MatchAs) and c.pattern.pattern is None
                for c in stmt.cases
            )
            if has_catchall and all(_terminates(c.body) for c in stmt.cases):
                return True
    return False


def _has_break(loop) -> bool:
    stack = list(loop.body)
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Break):
            return True
        if isinstance(node, (ast.For, ast.While, ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue  # break inside belongs to the inner loop
        stack.extend(ast.iter_child_nodes(node))
    return False


def _check_error_codes(ctx: FileContext) -> list[Finding]:
    findings = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Dict):
            continue
        keys = {
            k.value: v
            for k, v in zip(node.keys, node.values)
            if isinstance(k, ast.Constant) and isinstance(k.value, str)
        }
        ok = keys.get("ok")
        is_error_reply = (
            isinstance(ok, ast.Constant) and ok.value is False and "error" in keys
        )
        if not is_error_reply or "code" in keys:
            continue
        if ctx.suppressed(node.lineno, CODE_RULE):
            continue
        findings.append(
            Finding(
                rule=CODE_RULE,
                path=str(ctx.path),
                line=node.lineno,
                col=node.col_offset,
                message=(
                    'wire error reply ({"ok": False, "error": ...}) is missing a '
                    'machine-readable "code" tag'
                ),
                scope="",
            )
        )
    return findings
