"""Hygiene bans: bare-except, mutable-default, sleep/blocking-io under lock.

* ``bare-except`` — ``except:`` swallows KeyboardInterrupt/SystemExit;
  name the exception (and log it).
* ``mutable-default`` — list/dict/set literals (or calls) as parameter
  defaults are shared across calls.
* ``sleep-under-lock`` — ``time.sleep`` while holding a tracked self
  lock stalls every other thread contending for it.
* ``io-under-lock`` — blocking socket I/O (or a ``wire.*`` round-trip)
  while holding a tracked self lock turns a slow peer into a stalled
  server.  Deliberate I/O-serialisation locks (e.g. one-request-at-a-time
  client connections) suppress with ``# lint: ignore[io-under-lock]`` on
  the ``with`` line, which covers the whole block.
"""
from __future__ import annotations

import ast

from repro.lint.context import FileContext, iter_functions, walk_held
from repro.lint.findings import Finding

BARE_RULE = "bare-except"
DEFAULT_RULE = "mutable-default"
SLEEP_RULE = "sleep-under-lock"
IO_RULE = "io-under-lock"

SOCKET_BLOCKING = {
    "recv",
    "recv_into",
    "recvmsg",
    "recvfrom",
    "sendall",
    "sendmsg",
    "accept",
    "connect",
    "sendfile",
}
WIRE_BLOCKING = {
    "request",
    "send_frame",
    "recv_frame",
    "send_msg",
    "recv_msg",
    "read_exact",
}


def check(ctx: FileContext) -> list[Finding]:
    findings: list[Finding] = []
    findings.extend(_bare_excepts(ctx))
    findings.extend(_mutable_defaults(ctx))
    findings.extend(_under_lock(ctx))
    return findings


def _bare_excepts(ctx: FileContext) -> list[Finding]:
    findings = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            if ctx.suppressed(node.lineno, BARE_RULE):
                continue
            findings.append(
                Finding(
                    rule=BARE_RULE,
                    path=str(ctx.path),
                    line=node.lineno,
                    col=node.col_offset,
                    message=(
                        "bare `except:` swallows KeyboardInterrupt/SystemExit — "
                        "catch a named exception and log it"
                    ),
                )
            )
    return findings


def _is_mutable_literal(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("list", "dict", "set", "bytearray")
        and not node.args
        and not node.keywords
    )


def _mutable_defaults(ctx: FileContext) -> list[Finding]:
    findings = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for default in list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]:
            if _is_mutable_literal(default) and not ctx.suppressed(
                default.lineno, DEFAULT_RULE
            ):
                findings.append(
                    Finding(
                        rule=DEFAULT_RULE,
                        path=str(ctx.path),
                        line=default.lineno,
                        col=default.col_offset,
                        message=(
                            f"mutable default argument in {node.name}() is shared "
                            f"across calls — default to None and allocate inside"
                        ),
                        scope=node.name,
                    )
                )
    return findings


def _under_lock(ctx: FileContext) -> list[Finding]:
    findings: list[Finding] = []
    for cls, func, qual in iter_functions(ctx):
        if cls is None or not cls.lock_attrs:
            continue

        def on_node(node, held, _q=qual):
            if not held or not isinstance(node, ast.Call):
                return
            f = node.func
            if not isinstance(f, ast.Attribute):
                return
            rule = None
            if f.attr == "sleep" and isinstance(f.value, ast.Name) and f.value.id == "time":
                rule = SLEEP_RULE
                what = "time.sleep()"
            elif f.attr in SOCKET_BLOCKING:
                rule = IO_RULE
                what = f"blocking socket call .{f.attr}()"
            elif (
                f.attr in WIRE_BLOCKING
                and isinstance(f.value, ast.Name)
                and f.value.id == "wire"
            ):
                rule = IO_RULE
                what = f"blocking wire.{f.attr}() round-trip"
            if rule is None:
                return
            # suppression on the call line, or on any held lock's with line
            if ctx.suppressed(node.lineno, rule):
                return
            for ln in held.values():
                if ctx.suppressed(ln, rule):
                    return
            locks = ", ".join(f"self.{a}" for a in sorted(held))
            findings.append(
                Finding(
                    rule=rule,
                    path=str(ctx.path),
                    line=node.lineno,
                    col=node.col_offset,
                    message=f"{what} while holding {locks}",
                    scope=_q,
                )
            )

        walk_held(func, cls, on_node=on_node)
    return findings
