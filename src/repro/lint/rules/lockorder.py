"""lock-order: build the global lock-acquisition graph, fail on cycles.

Lock identity is ``ClassName._attr`` — the usual conservative
abstraction (all instances of a class share one node).  Edges come from
two sources:

* syntactic nesting: ``with self._a:`` ... ``with self._b:`` adds a->b;
* one level of call expansion: ``self.helper()`` while holding ``_a``
  adds a->x for every lock x that ``helper`` itself acquires with a
  ``with`` (minus its ``# holds:`` annotation) — this is what catches
  ``_inflight_lock -> _cond`` via ``_release_credit`` in the channels.

Re-acquiring a held non-reentrant lock (directly or through a callee)
is reported as a self-edge cycle.  RLock/Condition self-edges are fine.
"""
from __future__ import annotations

import ast
from collections import defaultdict

from repro.lint.context import FileContext, iter_functions, walk_held
from repro.lint.findings import Finding

RULE = "lock-order"


def _direct_acquires(ctx: FileContext) -> dict[tuple[str, str], set[str]]:
    """(class, method) -> lock attrs the method acquires via with itself."""
    out: dict[tuple[str, str], set[str]] = defaultdict(set)
    for cls, func, qual in iter_functions(ctx):
        if cls is None:
            continue
        pre = cls.holds.get(func.name, frozenset())

        def on_acquire(node, acquired, held, _k=(cls.name, func.name), _pre=pre):
            out[_k].update(a for a in acquired if a not in _pre)

        walk_held(func, cls, on_acquire=on_acquire)
    return out


def check_project(ctxs: list[FileContext]) -> list[Finding]:
    # edge (from_node, to_node) -> example site (path, line, qual)
    edges: dict[tuple[str, str], tuple[str, int, str]] = {}
    acquires: dict[tuple[str, str], set[str]] = {}
    for ctx in ctxs:
        acquires.update(_direct_acquires(ctx))

    def add_edge(a: str, b: str, site) -> None:
        edges.setdefault((a, b), site)

    for ctx in ctxs:
        for cls, func, qual in iter_functions(ctx):
            if cls is None:
                continue

            def on_acquire(node, acquired, held, _cls=cls, _q=qual, _ctx=ctx):
                if _ctx.suppressed(node.lineno, RULE):
                    return
                site = (str(_ctx.path), node.lineno, _q)
                for a in acquired:
                    na = f"{_cls.name}.{a}"
                    if a in held:
                        if a not in _cls.reentrant:
                            add_edge(na, na, site)
                        continue
                    for h in held:
                        if h != a:
                            add_edge(f"{_cls.name}.{h}", na, site)

            def on_node(node, held, _cls=cls, _q=qual, _ctx=ctx):
                # one-level expansion of self.method() calls under a lock
                if not held or not isinstance(node, ast.Call):
                    return
                f = node.func
                if not (
                    isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "self"
                ):
                    return
                callee = acquires.get((_cls.name, f.attr))
                if not callee or _ctx.suppressed(node.lineno, RULE):
                    return
                site = (str(_ctx.path), node.lineno, _q)
                for a in callee:
                    na = f"{_cls.name}.{a}"
                    if a in held:
                        if a not in _cls.reentrant:
                            add_edge(na, na, site)
                        continue
                    for h in held:
                        if h != a:
                            add_edge(f"{_cls.name}.{h}", na, site)

            walk_held(func, cls, on_node=on_node, on_acquire=on_acquire)

    return _cycles_to_findings(edges)


def _cycles_to_findings(edges) -> list[Finding]:
    graph: dict[str, set[str]] = defaultdict(set)
    for a, b in edges:
        graph[a].add(b)
    findings: list[Finding] = []
    for comp in _sccs(graph):
        cyclic = len(comp) > 1 or (len(comp) == 1 and comp[0] in graph[comp[0]])
        if not cyclic:
            continue
        nodes = sorted(comp)
        sites = sorted(
            site for (a, b), site in edges.items() if a in comp and b in comp
        )
        path, line, qual = sites[0]
        detail = "; ".join(f"{a}->{b} at {s[0]}:{s[1]}" for (a, b), s in sorted(edges.items()) if a in comp and b in comp)
        findings.append(
            Finding(
                rule=RULE,
                path=path,
                line=line,
                col=0,
                message=f"lock-order cycle among {{{', '.join(nodes)}}}: {detail}",
                scope=qual,
            )
        )
    return findings


def _sccs(graph: dict[str, set[str]]) -> list[list[str]]:
    """Tarjan strongly-connected components (iterative)."""
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    out: list[list[str]] = []
    counter = [0]
    nodes = set(graph) | {b for bs in graph.values() for b in bs}

    for root in sorted(nodes):
        if root in index:
            continue
        work = [(root, iter(sorted(graph.get(root, ()))))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(graph.get(w, ())))))
                    advanced = True
                    break
                if w in on_stack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                out.append(comp)
    return out
