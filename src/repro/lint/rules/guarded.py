"""guarded-by: flag guarded-attribute access outside the owning lock.

A class opts in by declaring a guard map (``_GUARDED_BY`` or trailing
``# guarded by:`` comments).  Every ``self.<attr>`` access in its
methods is then checked against the set of locks held at that point.
``__init__`` is exempt (no concurrent readers exist before the
constructor returns); methods may declare ``# holds: self._lock`` when
every caller acquires the lock for them.
"""
from __future__ import annotations

import ast

from repro.lint.context import FileContext, iter_functions, walk_held
from repro.lint.findings import Finding

RULE = "guarded-by"
EXEMPT_METHODS = {"__init__", "__del__", "__repr__"}


def check(ctx: FileContext) -> list[Finding]:
    findings: list[Finding] = []
    for cls, func, qual in iter_functions(ctx):
        if cls is None or not cls.guard_map:
            continue
        if func.name in EXEMPT_METHODS and "." not in qual.removeprefix(f"{cls.name}."):
            continue
        seen: set[tuple[int, int, str]] = set()

        def on_node(node, held, _f=findings, _s=seen, _cls=cls, _q=qual):
            if not (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
            ):
                return
            lock = _cls.guard_map.get(node.attr)
            if lock is None or lock in held:
                return
            key = (node.lineno, node.col_offset, node.attr)
            if key in seen or ctx.suppressed(node.lineno, RULE):
                return
            _s.add(key)
            _f.append(
                Finding(
                    rule=RULE,
                    path=str(ctx.path),
                    line=node.lineno,
                    col=node.col_offset,
                    message=(
                        f"self.{node.attr} is guarded by self.{lock} "
                        f"but accessed without holding it"
                    ),
                    scope=_q,
                )
            )

        walk_held(func, cls, on_node=on_node)
    return findings
