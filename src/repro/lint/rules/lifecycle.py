"""thread-join / socket-close: lifecycle rules.

thread-join — every ``threading.Thread`` a class creates must be
reachable from a stop-like method's ``join()`` (``stop``/``close``/
``shutdown``/``join``/``__exit__``, including one level of self-method
calls from those).  Recognised creation shapes:

* ``self._t = threading.Thread(...)``              (attr)
* ``t = threading.Thread(...); self._ts.append(t)`` (registered local)
* ``self._ts = [threading.Thread(...) for ...]``    (list comprehension)
* ``threading.Thread(...).start()``                 (always a finding)

Join detection follows one level of local aliasing
(``ts = list(self._ts)`` then ``for t in ts: t.join()``).

socket-close — a socket created locally (``socket.socket``,
``socket.create_connection``, ``sock.accept()``) that never escapes the
function (no call argument, return, yield, or store) must be closed via
``with`` or a ``finally``/unconditional ``close()``.
"""
from __future__ import annotations

import ast
import re

from repro.lint.context import FileContext, iter_functions
from repro.lint.findings import Finding

THREAD_RULE = "thread-join"
SOCKET_RULE = "socket-close"
STOP_RE = re.compile(r"^(stop|close|shutdown|join|__exit__|__del__)$|^(stop|close|shutdown)_")


def check(ctx: FileContext) -> list[Finding]:
    findings: list[Finding] = []
    for cls in ctx.classes:
        findings.extend(_check_threads(ctx, cls))
    for cls, func, qual in iter_functions(ctx):
        findings.extend(_check_sockets(ctx, func, qual))
    return findings


# -- thread-join ----------------------------------------------------------

def _is_thread_call(node: ast.expr) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "Thread"
        and isinstance(node.func.value, ast.Name)
        and node.func.value.id == "threading"
    )


def _check_threads(ctx: FileContext, cls) -> list[Finding]:
    findings: list[Finding] = []
    # attr -> creation site (line, col, qual); detached -> list of sites
    tracked: dict[str, tuple[int, int, str]] = {}
    detached: list[tuple[int, int, str, str]] = []

    for meth in cls.methods():
        qual = f"{cls.name}.{meth.name}"
        # local thread var -> created-here flag
        local_threads: dict[str, ast.Assign] = {}
        registered: set[str] = set()
        for node in ast.walk(meth):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
                if _is_thread_call(val):
                    if isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name) and tgt.value.id == "self":
                        tracked.setdefault(tgt.attr, (node.lineno, node.col_offset, qual))
                    elif isinstance(tgt, ast.Name):
                        local_threads[tgt.id] = node
                elif isinstance(val, ast.ListComp) and _is_thread_call(val.elt):
                    if isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name) and tgt.value.id == "self":
                        tracked.setdefault(tgt.attr, (node.lineno, node.col_offset, qual))
            elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
                f = node.func
                # self._ts.append(t) / self._ts[k] = handled below; dict: self._ts[key] = t
                if (
                    f.attr in ("append", "add")
                    and isinstance(f.value, ast.Attribute)
                    and isinstance(f.value.value, ast.Name)
                    and f.value.value.id == "self"
                    and len(node.args) == 1
                    and isinstance(node.args[0], ast.Name)
                ):
                    if node.args[0].id in local_threads:
                        tracked.setdefault(
                            f.value.attr,
                            (node.lineno, node.col_offset, qual),
                        )
                        registered.add(node.args[0].id)
                # threading.Thread(...).start() — never joinable
                if f.attr == "start" and _is_thread_call(f.value):
                    detached.append((node.lineno, node.col_offset, qual, "<anonymous>"))
            elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                pass
        # dict registration: self._ts[key] = t
        for node in ast.walk(meth):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt, val = node.targets[0], node.value
                if (
                    isinstance(tgt, ast.Subscript)
                    and isinstance(tgt.value, ast.Attribute)
                    and isinstance(tgt.value.value, ast.Name)
                    and tgt.value.value.id == "self"
                    and isinstance(val, ast.Name)
                    and val.id in local_threads
                ):
                    tracked.setdefault(tgt.value.attr, (node.lineno, node.col_offset, qual))
                    registered.add(val.id)
        for name, node in local_threads.items():
            if name not in registered:
                detached.append((node.lineno, node.col_offset, qual, name))

    if not tracked and not detached:
        return findings

    joined = _joined_attrs(cls)
    for ln, col, qual, name in detached:
        if ctx.suppressed(ln, THREAD_RULE):
            continue
        findings.append(
            Finding(
                rule=THREAD_RULE,
                path=str(ctx.path),
                line=ln,
                col=col,
                message=(
                    f"thread {name!r} is started but never stored or registered "
                    f"for join by a stop()/close() method"
                ),
                scope=qual,
            )
        )
    for attr, (ln, col, qual) in sorted(tracked.items()):
        if attr in joined or ctx.suppressed(ln, THREAD_RULE):
            continue
        findings.append(
            Finding(
                rule=THREAD_RULE,
                path=str(ctx.path),
                line=ln,
                col=col,
                message=(
                    f"thread(s) tracked in self.{attr} are never joined by a "
                    f"stop()/close()/shutdown() method of {cls.name}"
                ),
                scope=qual,
            )
        )
    return findings


def _joined_attrs(cls) -> set[str]:
    """Self attrs whose threads are join()ed from stop-like methods."""
    methods = {m.name: m for m in cls.methods()}
    stoppish = [m for n, m in methods.items() if STOP_RE.match(n)]
    # one level of expansion: self.helper() called from a stop-like method
    expanded = list(stoppish)
    for m in stoppish:
        for node in ast.walk(m):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
                and node.func.attr in methods
            ):
                expanded.append(methods[node.func.attr])
    joined: set[str] = set()
    for m in expanded:
        joined |= _joins_in(m)
    return joined


def _attrs_in(node: ast.AST, aliases: dict[str, set[str]]) -> set[str]:
    out: set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and isinstance(sub.value, ast.Name) and sub.value.id == "self":
            out.add(sub.attr)
        elif isinstance(sub, ast.Name) and sub.id in aliases:
            out |= aliases[sub.id]
    return out


def _joins_in(meth) -> set[str]:
    joined: set[str] = set()
    aliases: dict[str, set[str]] = {}

    def scan(stmts) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and isinstance(stmt.targets[0], ast.Name):
                attrs = _attrs_in(stmt.value, aliases)
                if attrs:
                    aliases[stmt.targets[0].id] = attrs
            if isinstance(stmt, ast.For):
                attrs = _attrs_in(stmt.iter, aliases)
                if attrs and isinstance(stmt.target, ast.Name):
                    aliases[stmt.target.id] = attrs
            for node in ast.walk(stmt):
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "join"
                ):
                    joined.update(_attrs_in(node.func.value, aliases))
            for body in _bodies(stmt):
                scan(body)

    scan(meth.body)
    return joined


def _bodies(stmt):
    for field in ("body", "orelse", "finalbody"):
        b = getattr(stmt, field, None)
        if b and isinstance(b, list) and b and isinstance(b[0], ast.stmt):
            yield b
    for h in getattr(stmt, "handlers", []) or []:
        yield h.body


# -- socket-close ---------------------------------------------------------

def _is_socket_create(val: ast.expr) -> bool:
    if not isinstance(val, ast.Call):
        return False
    f = val.func
    if (
        isinstance(f, ast.Attribute)
        and isinstance(f.value, ast.Name)
        and f.value.id == "socket"
        and f.attr in ("socket", "create_connection")
    ):
        return True
    return False


def _check_sockets(ctx: FileContext, func, qual: str) -> list[Finding]:
    created: dict[str, ast.Assign] = {}
    for node in ast.walk(func):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node is not func:
            continue
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
            if isinstance(tgt, ast.Name) and _is_socket_create(val):
                created[tgt.id] = node
            elif (
                isinstance(tgt, ast.Tuple)
                and tgt.elts
                and isinstance(tgt.elts[0], ast.Name)
                and isinstance(val, ast.Call)
                and isinstance(val.func, ast.Attribute)
                and val.func.attr == "accept"
            ):
                created[tgt.elts[0].id] = node

    if not created:
        return []

    escaped: set[str] = set()
    closed: set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            for arg in list(node.args) + [k.value for k in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Name) and sub.id in created:
                        escaped.add(sub.id)
        elif isinstance(node, (ast.Return, ast.Yield, ast.YieldFrom)) and node.value is not None:
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name) and sub.id in created:
                    escaped.add(sub.id)
        elif isinstance(node, ast.Assign):
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name):
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name) and sub.id in created:
                        escaped.add(sub.id)
        elif isinstance(node, ast.With):
            for item in node.items:
                for sub in ast.walk(item.context_expr):
                    if isinstance(sub, ast.Name) and sub.id in created:
                        closed.add(sub.id)
    # close()/shutdown() inside a finally block, or anywhere at all if the
    # function has no branching after creation — keep it simple: any
    # unconditional-looking close counts, a finally close always counts.
    for node in ast.walk(func):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "close"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id in created
        ):
            closed.add(node.func.value.id)

    findings = []
    for name, node in created.items():
        if name in escaped or name in closed:
            continue
        if ctx.suppressed(node.lineno, SOCKET_RULE):
            continue
        findings.append(
            Finding(
                rule=SOCKET_RULE,
                path=str(ctx.path),
                line=node.lineno,
                col=node.col_offset,
                message=(
                    f"socket {name!r} is created here but never closed on all "
                    f"paths (use `with` or close() in a finally block) and never "
                    f"handed off"
                ),
                scope=qual,
            )
        )
    return findings
