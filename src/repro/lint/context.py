"""Per-file analysis context: AST, trailing comments, class lock metadata.

Annotation syntax recognised here (see DESIGN.md §14):

* ``_GUARDED_BY = {"_attr": "_lock", ...}`` — class-level dict literal
  mapping attribute name -> owning lock attribute.
* ``self._attr = ... # guarded by: self._lock`` — trailing comment on an
  assignment anywhere in the class; equivalent to a ``_GUARDED_BY`` entry.
* ``def _helper(self): # holds: self._lock`` — trailing comment on a
  ``def`` line declaring that every caller already holds those locks
  (comma-separated); the method is analysed with them pre-held, and its
  own acquisitions of them are not re-counted for lock ordering.
* ``# lint: ignore[rule-a,rule-b]`` / ``# lint: ignore`` — per-line
  suppression.  On a ``with <lock>:`` line it also suppresses
  ``*-under-lock`` findings for calls made while that block holds the lock.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
REENTRANT_FACTORIES = {"RLock", "Condition"}  # Condition() wraps an RLock

_GUARDED_RE = re.compile(r"guarded\s+by:\s*self\.(\w+)")
_HOLDS_RE = re.compile(r"holds:\s*((?:self\.\w+\s*,?\s*)+)")
_IGNORE_RE = re.compile(r"lint:\s*ignore(?:\[([\w\-, ]*)\])?")


@dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    lock_attrs: set[str] = field(default_factory=set)
    reentrant: set[str] = field(default_factory=set)  # subset of lock_attrs
    guard_map: dict[str, str] = field(default_factory=dict)  # attr -> lock attr
    holds: dict[str, frozenset] = field(default_factory=dict)  # method -> locks

    def methods(self):
        for stmt in self.node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield stmt


class FileContext:
    def __init__(self, path: str | Path, source: str | None = None):
        self.path = Path(path)
        self.source = source if source is not None else self.path.read_text()
        self.tree = ast.parse(self.source, filename=str(self.path))
        self.comments: dict[int, str] = {}
        self._ignores: dict[int, set[str] | None] = {}  # line -> rules (None = all)
        self._scan_comments()
        self.classes: list[ClassInfo] = [
            self._class_info(n) for n in ast.walk(self.tree) if isinstance(n, ast.ClassDef)
        ]

    # -- comments / suppressions ------------------------------------------
    def _scan_comments(self) -> None:
        try:
            toks = tokenize.generate_tokens(io.StringIO(self.source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    line = tok.start[0]
                    self.comments[line] = tok.string
                    m = _IGNORE_RE.search(tok.string)
                    if m:
                        rules = m.group(1)
                        if rules is None or not rules.strip():
                            self._ignores[line] = None
                        else:
                            self._ignores[line] = {r.strip() for r in rules.split(",") if r.strip()}
        except tokenize.TokenError:
            pass

    def suppressed(self, line: int, rule: str) -> bool:
        rules = self._ignores.get(line, ...)
        if rules is ...:
            return False
        return rules is None or rule in rules

    # -- class metadata ---------------------------------------------------
    def _class_info(self, node: ast.ClassDef) -> ClassInfo:
        info = ClassInfo(name=node.name, node=node)
        # class-level _GUARDED_BY dict literal
        for stmt in node.body:
            if (
                isinstance(stmt, ast.Assign)
                and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and stmt.targets[0].id == "_GUARDED_BY"
                and isinstance(stmt.value, ast.Dict)
            ):
                for k, v in zip(stmt.value.keys, stmt.value.values):
                    if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                        info.guard_map[str(k.value)] = str(v.value)
        for meth in info.methods():
            m = _HOLDS_RE.search(self.comments.get(meth.lineno, ""))
            if m:
                info.holds[meth.name] = frozenset(
                    w.split(".")[1] for w in re.findall(r"self\.\w+", m.group(1))
                )
            for sub in ast.walk(meth):
                # self.X = threading.Lock()/RLock()/Condition(...) anywhere
                if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                    tgt = sub.targets[0]
                    if (
                        isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"
                    ):
                        fac = _lock_factory(sub.value)
                        if fac:
                            info.lock_attrs.add(tgt.attr)
                            if fac in REENTRANT_FACTORIES:
                                info.reentrant.add(tgt.attr)
                        # trailing "# guarded by: self._lock" comment
                        gm = _GUARDED_RE.search(self.comments.get(sub.lineno, ""))
                        if gm:
                            info.guard_map[tgt.attr] = gm.group(1)
        # guard-map values count as lock attrs even without a visible factory
        info.lock_attrs.update(info.guard_map.values())
        return info


def _lock_factory(value: ast.expr) -> str | None:
    if (
        isinstance(value, ast.Call)
        and isinstance(value.func, ast.Attribute)
        and isinstance(value.func.value, ast.Name)
        and value.func.value.id == "threading"
        and value.func.attr in LOCK_FACTORIES
    ):
        return value.func.attr
    return None


def self_lock_in_with(item: ast.withitem, lock_attrs: set[str]) -> str | None:
    """Return the lock attr name if this with-item acquires a self lock."""
    e = item.context_expr
    if (
        isinstance(e, ast.Attribute)
        and isinstance(e.value, ast.Name)
        and e.value.id == "self"
        and e.attr in lock_attrs
    ):
        return e.attr
    return None


def walk_held(
    func: ast.FunctionDef,
    cls: ClassInfo,
    on_node=None,
    on_acquire=None,
) -> None:
    """Walk ``func`` tracking which of ``cls``'s locks are held.

    ``on_node(node, held)`` fires for every expression/statement node with
    ``held`` mapping lock attr -> line of the acquiring ``with``
    (annotation-held locks map to the ``def`` line).  ``on_acquire(with_node,
    acquired_attrs, held_before)`` fires at each self-lock ``with``.
    Nested function definitions are not entered — the engine analyses them
    as separate functions with an empty held set.
    """
    initial = {a: func.lineno for a in cls.holds.get(func.name, frozenset())}

    def visit_expr(node: ast.AST, held: dict) -> None:
        stack = [node]
        while stack:
            sub = stack.pop()
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue  # analysed separately, with an empty held set
            if on_node:
                on_node(sub, held)
            stack.extend(ast.iter_child_nodes(sub))

    def visit_stmts(stmts, held: dict) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.With):
                acquired = {}
                for item in stmt.items:
                    attr = self_lock_in_with(item, cls.lock_attrs)
                    if attr is not None:
                        acquired[attr] = stmt.lineno
                    visit_expr(item.context_expr, held)
                    if item.optional_vars is not None:
                        visit_expr(item.optional_vars, held)
                if acquired and on_acquire:
                    on_acquire(stmt, list(acquired), dict(held))
                inner = dict(held)
                inner.update(acquired)
                visit_stmts(stmt.body, inner)
            elif isinstance(stmt, (ast.If, ast.While)):
                visit_expr(stmt.test, held)
                visit_stmts(stmt.body, held)
                visit_stmts(stmt.orelse, held)
            elif isinstance(stmt, ast.For):
                visit_expr(stmt.target, held)
                visit_expr(stmt.iter, held)
                visit_stmts(stmt.body, held)
                visit_stmts(stmt.orelse, held)
            elif isinstance(stmt, ast.Try):
                visit_stmts(stmt.body, held)
                for h in stmt.handlers:
                    if h.type is not None:
                        visit_expr(h.type, held)
                    visit_stmts(h.body, held)
                visit_stmts(stmt.orelse, held)
                visit_stmts(stmt.finalbody, held)
            elif isinstance(stmt, ast.ClassDef):
                continue
            else:
                visit_expr(stmt, held)

    visit_stmts(func.body, initial)


def iter_functions(ctx: FileContext):
    """Yield (cls_or_None, func, qualname) for every function in the file.

    Nested defs are yielded with their enclosing class (so self-lock
    metadata applies) but walked with an empty held set by walk_held.
    """

    def nested(func, cls, prefix):
        for sub in ast.walk(func):
            if sub is not func and isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield cls, sub, f"{prefix}.{sub.name}"

    seen = set()
    for cls in ctx.classes:
        for meth in cls.methods():
            qual = f"{cls.name}.{meth.name}"
            seen.add(id(meth))
            yield cls, meth, qual
            for c, f, q in nested(meth, cls, qual):
                seen.add(id(f))
                yield c, f, q
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and id(node) not in seen:
            yield None, node, node.name
