"""Drive the rule set over a file tree and apply the baseline."""
from __future__ import annotations

from pathlib import Path

from repro.lint.context import FileContext
from repro.lint.findings import Finding
from repro.lint.rules import ALL_RULES, RULE_IDS

SKIP_DIRS = {"__pycache__", ".git", "lint_fixtures"}


def collect_files(paths: list[str | Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(
                f
                for f in sorted(p.rglob("*.py"))
                if not (set(f.parts) & SKIP_DIRS)
            )
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(
    paths: list[str | Path],
    rules: set[str] | None = None,
) -> list[Finding]:
    """Lint the given files/dirs; ``rules`` filters by rule id (e.g.
    ``{"guarded-by", "lock-order"}``); None means all rules."""
    families = None
    if rules is not None:
        families = {RULE_IDS[r] for r in rules if r in RULE_IDS}

    contexts: list[FileContext] = []
    findings: list[Finding] = []
    for path in collect_files(paths):
        try:
            ctx = FileContext(path)
        except SyntaxError as exc:
            findings.append(
                Finding(
                    rule="parse-error",
                    path=str(path),
                    line=exc.lineno or 0,
                    col=exc.offset or 0,
                    message=f"could not parse: {exc.msg}",
                )
            )
            continue
        contexts.append(ctx)

    for name, mod in ALL_RULES.items():
        if families is not None and name not in families:
            continue
        if hasattr(mod, "check"):
            for ctx in contexts:
                findings.extend(mod.check(ctx))
        if hasattr(mod, "check_project"):
            findings.extend(mod.check_project(contexts))

    if rules is not None:
        findings = [f for f in findings if f.rule in rules or f.rule == "parse-error"]

    # nested defs are visited both standalone and through their enclosing
    # method — drop exact duplicates
    seen: set[tuple] = set()
    unique: list[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        key = (f.rule, f.path, f.line, f.col, f.message)
        if key in seen:
            continue
        seen.add(key)
        unique.append(f)
    return unique
