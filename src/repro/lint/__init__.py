"""reprolint — project-invariant static analysis for the in-transit stack.

The reproduction buys the paper's no-copy/no-context-switch win with
heavy concurrency (~60 threading primitives across the transport, core
and gateway layers), and every PR since the striped channels landed has
shipped hand-found race fixes.  This package turns those one-off fixes
into machine-checked invariants (DESIGN.md §14):

  * ``guarded-by``      — classes declare which lock protects which
                          attribute (``_GUARDED_BY`` map or ``# guarded
                          by: self._lock`` trailing comments); any access
                          outside the owning lock is a finding.
  * ``lock-order``      — nested ``with``-acquisitions build a global
                          lock graph; cycles are static deadlocks.
  * ``thread-join``     — every ``threading.Thread`` a class starts must
                          be joined (or registered for join) by its
                          ``stop()``/``close()``.
  * ``socket-close``    — sockets created and never handed off must be
                          closed on all paths (``with`` / ``finally``).
  * ``dispatch-return`` — every wire-dispatch handler (``_handle*`` /
                          ``_op_*``) replies on all control-flow paths.
  * ``error-code``      — wire error replies carry a typed ``code`` tag.
  * hygiene bans        — ``bare-except``, ``mutable-default``,
                          ``sleep-under-lock`` / ``io-under-lock``.

Run it with ``python -m repro.lint src/`` (``--strict`` for CI).  The
runtime half (:mod:`repro.lint.runtime`) wraps ``threading.Lock`` /
``RLock`` behind ``REPRO_LOCKCHECK=1`` and records per-thread
acquisition order during tier-1, failing on any inversion the static
graph did not predict.

Suppressions are per line: ``# lint: ignore[rule-id]`` (or a blanket
``# lint: ignore``).  Grandfathered findings live in a committed
baseline file (target: empty) — see ``--baseline`` / ``--write-baseline``.
"""
from repro.lint.engine import lint_paths
from repro.lint.findings import Baseline, Finding

__all__ = ["lint_paths", "Finding", "Baseline"]
