"""Finding records, stable fingerprints, and the baseline workflow.

A finding's fingerprint deliberately excludes line/column so that
unrelated edits above a grandfathered finding do not invalidate the
baseline.  It hashes (rule, relative path, enclosing scope, message);
messages therefore avoid embedding line numbers.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # repo-relative (or as-given) posix path
    line: int
    col: int
    message: str
    scope: str = ""  # "Class.method" / "module" — stabilises fingerprints

    @property
    def fingerprint(self) -> str:
        blob = "|".join((self.rule, self.path, self.scope, self.message))
        return hashlib.blake2b(blob.encode("utf-8"), digest_size=8).hexdigest()

    def render(self) -> str:
        where = f"{self.path}:{self.line}:{self.col}"
        scope = f" [{self.scope}]" if self.scope else ""
        return f"{where}: {self.rule}: {self.message}{scope}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "scope": self.scope,
            "fingerprint": self.fingerprint,
        }


@dataclass
class Baseline:
    """Committed set of grandfathered finding fingerprints (target: empty)."""

    path: Path | None = None
    fingerprints: dict[str, dict] = field(default_factory=dict)

    @classmethod
    def load(cls, path: str | Path) -> "Baseline":
        p = Path(path)
        if not p.exists():
            return cls(path=p)
        data = json.loads(p.read_text())
        fps = {e["fingerprint"]: e for e in data.get("findings", [])}
        return cls(path=p, fingerprints=fps)

    def __contains__(self, finding: Finding) -> bool:
        return finding.fingerprint in self.fingerprints

    def split(self, findings: list[Finding]) -> tuple[list[Finding], list[Finding], list[dict]]:
        """Partition into (new, grandfathered) and report stale entries."""
        new = [f for f in findings if f not in self]
        old = [f for f in findings if f in self]
        live = {f.fingerprint for f in findings}
        stale = [e for fp, e in self.fingerprints.items() if fp not in live]
        return new, old, stale

    @staticmethod
    def write(path: str | Path, findings: list[Finding]) -> None:
        entries = [
            {
                "fingerprint": f.fingerprint,
                "rule": f.rule,
                "path": f.path,
                "scope": f.scope,
                "message": f.message,
            }
            for f in sorted(findings, key=lambda f: (f.path, f.rule, f.scope, f.message))
        ]
        Path(path).write_text(json.dumps({"version": 1, "findings": entries}, indent=2) + "\n")
