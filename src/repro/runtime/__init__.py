from repro.runtime.fault_tolerance import (  # noqa: F401
    InjectedFailure, RestartBudgetExceeded, Supervisor, SupervisorConfig,
    plan_mesh,
)
