from repro.runtime.fault_tolerance import (  # noqa: F401
    InjectedFailure, Supervisor, SupervisorConfig, plan_mesh,
)
