"""Fault-tolerant training supervisor + elastic mesh planning.

Supervisor: periodic async checkpoints (through the staging path), restart
from the last committed checkpoint on step failure (bounded restarts),
fail-injection hooks for tests. Straggler mitigation for host-side I/O
lives in repro.core.queues (speculative re-execution); device-side
stragglers are an infra concern (the launcher restarts the slice).

Elastic: plan_mesh() re-derives a (pod, data, model) factorization from the
currently healthy device count; CheckpointManager.restore() reshard-on-
restore makes the new topology a device_put away.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax

from repro.checkpoint.checkpointing import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


class RestartBudgetExceeded(RuntimeError):
    """The supervisor burned through ``max_restarts``; carries enough to
    resume by hand (the last committed checkpoint step)."""

    def __init__(self, restarts: int, max_restarts: int,
                 last_checkpoint_step: Optional[int], cause: BaseException):
        self.restarts = restarts
        self.max_restarts = max_restarts
        self.last_checkpoint_step = last_checkpoint_step
        at = ("no checkpoint committed" if last_checkpoint_step is None
              else f"last checkpoint at step {last_checkpoint_step}")
        super().__init__(
            f"supervisor exceeded max_restarts={max_restarts} "
            f"({restarts} restarts; {at}): {cause}")


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_every: int = 50
    max_restarts: int = 3


class Supervisor:
    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 cfg: SupervisorConfig = SupervisorConfig()):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def run(self, state: Any, batches: Iterator[dict], n_steps: int,
            abstract_state: Any = None, shardings: Any = None,
            fail_at: Optional[set[int]] = None) -> Any:
        """Runs n_steps; on failure restores the last committed checkpoint
        and continues. fail_at injects failures (tests/examples)."""
        step_idx = int(jax.device_get(state["step"])) \
            if isinstance(state, dict) and "step" in state else 0
        while step_idx < n_steps:
            batch = next(batches)
            try:
                if fail_at and step_idx in fail_at:
                    fail_at.discard(step_idx)
                    raise InjectedFailure(f"injected at step {step_idx}")
                state, metrics, egress = self.step_fn(state, batch)
                step_idx += 1
            except (InjectedFailure, jax.errors.JaxRuntimeError) as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RestartBudgetExceeded(
                        self.restarts, self.cfg.max_restarts,
                        self.ckpt.latest_step(), e) from e
                self.ckpt.wait()
                if abstract_state is None:
                    raise RuntimeError("no abstract_state for restore") from e
                state = self.ckpt.restore(abstract_state,
                                          shardings=shardings)
                step_idx = int(jax.device_get(state["step"]))
                continue
            if step_idx % self.cfg.ckpt_every == 0:
                self.ckpt.save(state, step_idx)
            self.metrics_log.append(
                {k: float(v) for k, v in metrics.items()
                 if hasattr(v, "shape") and getattr(v, "shape", None) == ()})
        self.ckpt.save(state, step_idx)
        self.ckpt.wait()
        return state


# ---------------------------------------------------------------------------
# Elastic mesh planning
# ---------------------------------------------------------------------------


def plan_mesh(n_devices: int, *, model_parallel: int = 16,
              pod_size: int = 256) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest coherent (pod, data, model) mesh for the surviving devices.

    model_parallel is fixed by the model's sharding (must divide n);
    whole pods are preferred; a degraded partial pod falls back to a
    single-pod mesh of the remaining chips.
    """
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model_parallel={model_parallel}")
    n_pods = n_devices // pod_size
    if n_pods >= 2 and n_devices % pod_size == 0:
        return ((n_pods, pod_size // model_parallel, model_parallel),
                ("pod", "data", "model"))
    return ((n_devices // model_parallel, model_parallel),
            ("data", "model"))
