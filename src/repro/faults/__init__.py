"""repro.faults — deterministic, seeded fault injection (DESIGN.md §15).

Three pieces:

* :mod:`repro.faults.plan`   — ``FaultPlan`` / ``FaultRule``: the seeded
  schedule DSL (also what the ``--faults`` launcher flag parses).
* :mod:`repro.faults.inject` — ``FaultInjector``: the wire-level hook
  that drops / delays / duplicates / corrupts / partitions traffic on
  registered client connections.
* :mod:`repro.faults.sched`  — ``FaultScheduler``: scripted process
  kills (staging / SAVIME / gateway) at plan-relative times.

Typical test usage::

    plan = FaultPlan.parse("seed=7;drop:op=stripe,nth=3")
    with injected(plan) as inj:
        ... run a transfer; the client retries/replays ...
    assert inj.fired["drop"] == 1
"""
from repro.faults.plan import KINDS, FaultPlan, FaultRule
from repro.faults.inject import FaultInjector, injected, install, uninstall
from repro.faults.sched import FaultScheduler

__all__ = [
    "KINDS",
    "FaultPlan",
    "FaultRule",
    "FaultInjector",
    "FaultScheduler",
    "injected",
    "install",
    "uninstall",
]
