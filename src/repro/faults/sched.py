"""FaultScheduler — the process-death half of the fault harness.

Runs the plan's ``kill`` rules on a wall-clock schedule relative to
:meth:`FaultScheduler.start`: at ``at_s`` seconds, invoke the registered
kill hook for the rule's ``target`` (``staging:0``, ``savime:1``,
``gateway`` — whatever the caller registered).  ``StagingPool.with_faults``
wires the pool's backends in automatically.

The scheduler owns one daemon thread, joined in :meth:`stop` — callers
must pair ``start``/``stop`` (the ``with_faults`` context manager does).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict

from repro.faults.plan import FaultPlan


class FaultScheduler:
    """Scripted kills: sleeps to each rule's ``at_s``, fires its hook."""

    def __init__(self, plan: FaultPlan,
                 targets: Dict[str, Callable[[], None]]):
        self._rules = sorted(plan.kill_rules, key=lambda r: r.at_s)
        self._targets = dict(targets)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.killed: list[str] = []

    def start(self) -> "FaultScheduler":
        if self._rules and self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="fault-sched", daemon=True)
            self._thread.start()
        return self

    def _run(self) -> None:
        t0 = time.monotonic()
        for rule in self._rules:
            wait = rule.at_s - (time.monotonic() - t0)
            if wait > 0 and self._stop.wait(wait):
                return
            if self._stop.is_set():
                return
            hook = self._targets.get(rule.target)
            if hook is None:
                continue
            try:
                hook()
            except (OSError, RuntimeError):
                pass        # the target died on its own first — that's fine
            self.killed.append(rule.target)

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5)
            self._thread = None
